"""SSD algorithm vs naive recurrence; MoE dispatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # property tests need the dev extra
    from hypothesis_stub import given, settings, st

from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.ssm import SSMConfig, ssd_chunked, ssm_block, ssm_decode_step, ssm_init


def _ssd_naive(x, dt, A, B, C):
    """Token-by-token reference recurrence: h_t = exp(dt_t A) h_{t-1} +
    dt_t x_t B_t ; y_t = C_t . h_t  (groups broadcast over heads)."""
    b, s, hh, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = hh // g
    Bf = np.repeat(np.asarray(B), rep, axis=2)
    Cf = np.repeat(np.asarray(C), rep, axis=2)
    xn, dtn, An = np.asarray(x, np.float64), np.asarray(dt, np.float64), np.asarray(A, np.float64)
    h = np.zeros((b, hh, p, n))
    ys = np.zeros((b, s, hh, p))
    for t in range(s):
        dA = np.exp(dtn[:, t] * An[None, :])                     # (b, h)
        upd = (dtn[:, t, :, None] * xn[:, t])[..., None] * Bf[:, t, :, None, :]
        h = h * dA[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bhn->bhp", h, Cf[:, t])
    return ys, h


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), chunk=st.sampled_from([4, 8]))
def test_ssd_chunked_matches_recurrence(seed, chunk):
    r = np.random.default_rng(seed)
    b, s, h, p, g, n = 2, 16, 4, 8, 1, 8
    x = jnp.asarray(r.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(r.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    A = jnp.asarray(-r.uniform(0.5, 2.0, h), jnp.float32)
    B = jnp.asarray(r.standard_normal((b, s, g, n)), jnp.float32)
    C = jnp.asarray(r.standard_normal((b, s, g, n)), jnp.float32)
    y, final = ssd_chunked(x, dt, A, B, C, chunk)
    y_ref, h_ref = _ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(final), h_ref, rtol=1e-3, atol=1e-3)


def test_ssm_block_prefill_state_feeds_decode():
    """Prefill final state + decode steps == running the block on the full
    sequence (the serve-path invariant)."""
    cfg = SSMConfig(d_state=8, headdim=8, expand=2, chunk=4)
    d_model = 16
    params = ssm_init(jax.random.PRNGKey(0), d_model, cfg, jnp.float32)
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((2, 12, d_model)) * 0.3, jnp.float32)

    y_full = ssm_block(params, x, cfg, d_model)
    y_pre, st, cs = ssm_block(params, x[:, :8], cfg, d_model, return_state=True)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :8]),
                               rtol=1e-4, atol=1e-4)
    ys = []
    state, conv = st, cs
    for t in range(8, 12):
        y, state, conv = ssm_decode_step(params, x[:, t:t + 1], cfg, d_model,
                                         state, conv)
        ys.append(y)
    got = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full[:, 8:]),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 50), E=st.sampled_from([4, 8, 16]),
       K=st.sampled_from([1, 2, 4]), cf=st.sampled_from([1.0, 1.25, 2.0]))
def test_moe_sort_equals_scatter(seed, E, K, cf):
    cfg_s = MoEConfig(E, K, 8, capacity_factor=cf, dispatch="sort")
    cfg_c = MoEConfig(E, K, 8, capacity_factor=cf, dispatch="scatter")
    p = moe_init(jax.random.PRNGKey(seed), 8, cfg_s, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 8), jnp.float32)
    o1, a1 = moe_apply(p, x, cfg_s)
    o2, a2 = moe_apply(p, x, cfg_c)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), atol=1e-6)


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor some tokens must be dropped (output 0
    contribution) but nothing NaNs."""
    cfg = MoEConfig(4, 2, 8, capacity_factor=0.25, dispatch="sort")
    p = moe_init(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8), jnp.float32)
    o, _ = moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(o)).all()
