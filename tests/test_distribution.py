"""Distribution layer: sharding rules, HLO analysis, host-mesh pjit runs.

Tests that need >1 device run in a subprocess with
--xla_force_host_platform_device_count=8 (the main process must keep 1
device for the rest of the suite).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import base as cb

pytest.importorskip("repro.dist")  # distribution layer not present in all builds
from repro.dist import sharding as SH
from repro.dist.hloanalysis import HLOModule
from repro.launch import shapes as SHP

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_build_for_all_archs():
    """Every parameter of every assigned arch gets a rank-consistent spec
    on the production mesh shapes (structure-only — no devices needed)."""
    from repro.models import transformer as T

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for arch in cb.ASSIGNED_ARCHS:
        cfg = cb.get(arch)
        sds = jax.eval_shape(lambda c=cfg: T.init_params(jax.random.PRNGKey(0), c))
        leaves = jax.tree_util.tree_flatten_with_path(sds)[0]
        for path, leaf in leaves:
            spec = SH.param_spec(SH._path_str(path), tuple(leaf.shape),
                                 FakeMesh(), fsdp=True)
            assert len(tuple(spec)) <= len(leaf.shape), (arch, path)
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is not None:
                    size = 16 if isinstance(ax, str) else 256
                    assert dim % (16 if isinstance(ax, str) else 256) == 0, \
                        (arch, SH._path_str(path), spec, leaf.shape)


def test_input_specs_cover_all_cells():
    n = 0
    for arch in cb.ASSIGNED_ARCHS:
        cfg = cb.get(arch)
        for shape in SHP.SHAPES:
            if not SHP.cell_applicable(cfg, shape):
                continue
            specs = SHP.input_specs(cfg, shape)
            assert "tokens" in specs
            n += 1
    assert n == 33          # 40 cells - 7 archs skipping long_500k


def test_long_500k_policy():
    for arch, expect in [("mamba2_2_7b", True), ("zamba2_2_7b", True),
                         ("mixtral_8x7b", True), ("llama3_405b", False),
                         ("gemma2_27b", False), ("whisper_medium", False)]:
        assert SHP.cell_applicable(cb.get(arch), "long_500k") == expect, arch


def test_hlo_parser_trip_count_correction():
    """Parsed scan FLOPs must match the unrolled module (the parser's reason
    to exist: cost_analysis does not multiply loop bodies)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, AxisType
        from repro.dist.hloanalysis import HLOModule
        mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(AxisType.Auto,)*2)
        D,F,B,S,L = 128, 256, 4, 32, 8
        def step(params, x):
            def loss_fn(p):
                def body(c, w):
                    h = jnp.einsum('bsd,df->bsf', c, w[0])
                    return jnp.einsum('bsf,fd->bsd', jax.nn.relu(h), w[1]), None
                y,_ = jax.lax.scan(body, x, p)
                return jnp.mean(y**2)
            return jax.value_and_grad(loss_fn)(params)
        def mk(unroll):
            def step_u(params, x):
                def loss_fn(p):
                    def body(c, w):
                        h = jnp.einsum('bsd,df->bsf', c, w[0])
                        return jnp.einsum('bsf,fd->bsd', jax.nn.relu(h), w[1]), None
                    y,_ = jax.lax.scan(body, x, p, unroll=unroll)
                    return jnp.mean(y**2)
                return jax.value_and_grad(loss_fn)(params)
            params = (jax.ShapeDtypeStruct((L,D,F), jnp.float32),
                      jax.ShapeDtypeStruct((L,F,D), jnp.float32))
            x = jax.ShapeDtypeStruct((B,S,D), jnp.float32)
            ps = jax.NamedSharding(mesh, P(None,None,"model"))
            xs = jax.NamedSharding(mesh, P("data",None,None))
            return jax.jit(step_u, in_shardings=((ps,ps),xs)).lower(params,x).compile()
        f_scan = HLOModule(mk(1).as_text()).entry_costs().flops
        f_unroll = HLOModule(mk(8).as_text()).entry_costs().flops
        print(json.dumps({"scan": f_scan, "unroll": f_unroll}))
    """ % os.path.abspath(SRC))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    d = json.loads(r.stdout.strip().splitlines()[-1])
    assert d["scan"] > 0
    assert abs(d["scan"] - d["unroll"]) / d["unroll"] < 0.1, d


def test_host_mesh_train_and_ckpt_reshard():
    """Real pjit train steps on an 8-device host mesh + checkpoint save /
    elastic restore onto a different mesh shape."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import dataclasses, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import base as cb
        from repro.models import transformer as T
        from repro.dist import sharding as SH
        from repro.launch import steps as ST
        from repro.ckpt.manager import CheckpointManager
        from jax.sharding import AxisType

        cfg = cb.get("chatglm3_6b").reduced()
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        opt_name, opt = ST.optimizer_for(cfg)
        opt_state = opt.init(params)
        p_sh = SH.make_param_shardings(mesh, params)
        o_sh = ST.make_opt_shardings(mesh, params, opt_name)
        params = jax.device_put(params, p_sh)
        aspec = ST.make_aspec(mesh, 8)
        fn = ST.make_train_step(cfg, opt, aspec=aspec)
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32) + 3,
                 "labels": jnp.ones((8, 32), jnp.int32)}
        with mesh:
            step = jax.jit(fn, in_shardings=(p_sh, o_sh, SH.make_batch_shardings(mesh, batch)))
            losses = []
            for _ in range(4):
                params, opt_state, loss = step(params, opt_state, batch)
                losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        # checkpoint, then elastic restore on a DIFFERENT mesh (4x2)
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(4, params)
        mesh2 = jax.make_mesh((4, 2), ("data", "model"),
                              axis_types=(AxisType.Auto,)*2)
        p_sh2 = SH.make_param_shardings(mesh2, jax.eval_shape(lambda: params))
        step_r, restored = mgr.restore_latest(jax.eval_shape(lambda: params), p_sh2)
        assert step_r == 4
        a = jax.device_get(jax.tree.leaves(params)[0])
        b = jax.device_get(jax.tree.leaves(restored)[0])
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        print("HOSTMESH-OK")
    """ % os.path.abspath(SRC))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "HOSTMESH-OK" in r.stdout
