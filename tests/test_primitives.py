"""Conv primitives vs the lax.conv oracle + DLT + executor."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # property tests need the dev extra
    from hypothesis_stub import given, settings, st

from repro.models import cnn_zoo
from repro.primitives import layouts as L
from repro.primitives.conv import (PRIMITIVE_NAMES, REGISTRY, RUNNABLE,
                                   reference_conv, run_primitive)
from repro.primitives.executor import execute, make_weights

_CASES = [(4, 3, 16, 1, 3), (8, 5, 14, 1, 1), (6, 4, 19, 2, 3),
          (3, 2, 13, 1, 5), (5, 7, 16, 2, 5), (2, 3, 9, 4, 3),
          (7, 3, 11, 1, 7)]


@pytest.mark.parametrize("name", RUNNABLE)
def test_primitive_matches_oracle(name, rng):
    p = REGISTRY[name]
    tested = 0
    for (k, c, im, s, f) in _CASES:
        if not p.applicable(k, c, im, s, f):
            continue
        x = jnp.asarray(rng.standard_normal((c, im, im)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, c, f, f)), jnp.float32)
        ref = reference_conv(x, w, s)
        got = run_primitive(name, x, w, s)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-3)
        tested += 1
    assert tested > 0


@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 12), c=st.integers(1, 8), im=st.integers(7, 24),
       s=st.sampled_from([1, 2, 4]), f=st.sampled_from([1, 3, 5]),
       seed=st.integers(0, 100))
def test_primitives_property_shapes(k, c, im, s, f, seed):
    if f > im:
        return
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((c, im, im)), jnp.float32)
    w = jnp.asarray(r.standard_normal((k, c, f, f)), jnp.float32)
    ref = reference_conv(x, w, s)
    for name in ("im2col-copy-ab-ki", "direct-sum2d", "mec-col"):
        if REGISTRY[name].applicable(k, c, im, s, f):
            got = run_primitive(name, x, w, s)
            np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-3)


def test_registry_covers_paper_families():
    fams = {p.family for p in REGISTRY.values()}
    assert fams == {"direct", "im2", "kn2", "wino3", "wino5", "c1x1", "mec"}
    assert len(PRIMITIVE_NAMES) >= 45          # Table 6 scale
    assert len(RUNNABLE) >= 15


def test_dlt_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((3, 5, 5)), jnp.float32)
    for src in L.LAYOUTS:
        for dst in L.LAYOUTS:
            y = L.transform(L.from_chw(x, src), src, dst)
            np.testing.assert_allclose(L.to_chw(y, dst), x)


def test_executor_matches_composed_reference(rng):
    """Run AlexNet under a mixed assignment; outputs must equal the pure
    lax.conv composition regardless of which primitives were selected."""
    spec = cnn_zoo.get("alexnet")
    weights = make_weights(spec, seed=0)
    assignment = {0: "im2col-copy-ab-ki", 1: "mec-col", 2: "winograd-2x2-3x3",
                  3: "kn2row", 4: "direct-sum2d"}
    x0 = jnp.asarray(rng.standard_normal((3, 224, 224)), jnp.float32) * 0.1
    rep = execute(spec, assignment, weights, x=x0)
    # compose reference
    h = x0
    for i, layer in enumerate(spec.nodes):
        h = reference_conv(h, weights[i], layer.s)
    np.testing.assert_allclose(np.asarray(rep.outputs[4]), np.asarray(h),
                               rtol=1e-3, atol=1e-3)


def test_executor_handles_branching(rng):
    spec = cnn_zoo.get("squeezenet")
    assignment = {}
    for i, node in enumerate(spec.nodes):
        if hasattr(node, "k"):
            assignment[i] = ("conv-1x1-gemm-ab-ki" if node.f == 1
                             else "im2col-copy-ab-ki")
        else:
            assignment[i] = "chw"
    rep = execute(spec, assignment)
    out = rep.outputs[len(spec.nodes) - 1]
    assert np.isfinite(np.asarray(out)).all()
