"""Performance model: normalizer, masked loss, training, factor correction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # property tests need the dev extra
    from hypothesis_stub import given, settings, st

from repro.core.normalize import LogStandardizer, mdrae
from repro.core.perfmodel import (PerfModel, factor_correct, fit_perf_model,
                                  init_mlp, masked_mse)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(4, 60), d=st.integers(1, 5))
def test_normalizer_roundtrip(seed, n, d):
    rng = np.random.default_rng(seed)
    x = np.exp(rng.normal(0, 2, (n, d)))
    nrm = LogStandardizer(log=True).fit(x)
    back = nrm.inverse(nrm.transform(x))
    np.testing.assert_allclose(back, x, rtol=1e-5)


def test_normalizer_handles_nan():
    x = np.array([[1.0, np.nan], [2.0, 4.0], [4.0, 16.0]])
    nrm = LogStandardizer().fit(x)
    t = nrm.transform(x)
    assert np.isnan(t[0, 1])
    assert np.isfinite(t[:, 0]).all()


def test_masked_loss_ignores_undefined_and_their_gradient():
    key = jax.random.PRNGKey(0)
    params = init_mlp(key, (3, 8, 2))
    x = jnp.ones((4, 3))
    y = jnp.array([[1.0, 0.0]] * 4)
    mask_full = jnp.ones((4, 2))
    mask_half = jnp.array([[1.0, 0.0]] * 4)
    # gradient with the second column masked == gradient when that column's
    # labels are garbage (masking kills value AND gradient)
    y_garbage = y.at[:, 1].set(1e6)
    g1 = jax.grad(masked_mse)(params, x, y, mask_half)
    g2 = jax.grad(masked_mse)(params, x, y_garbage, mask_half)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=1e-6)
    # and differs from the full loss
    g3 = jax.grad(masked_mse)(params, x, y, mask_full)
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in
               zip(jax.tree.leaves(g1), jax.tree.leaves(g3)))
    assert diff > 0


def _synthetic(rng, n=400, noise=0.0):
    """Monomial runtime surfaces: t_j = c_j * k^a * c^b (log-linear)."""
    feats = np.exp(rng.uniform(0, 3, (n, 5)))
    coef = rng.uniform(0.5, 2.0, (5, 3))
    times = np.exp(np.log(feats) @ coef) * 1e-6
    if noise:
        times *= np.exp(rng.normal(0, noise, times.shape))
    times[rng.random((n, 3)) < 0.1] = np.nan    # undefined entries
    return feats, times


def test_lin_fits_log_linear_surface_exactly():
    rng = np.random.default_rng(0)
    f, t = _synthetic(rng)
    m = fit_perf_model("lin", f[:300], t[:300], f[300:], t[300:])
    assert m.mdrae(f[300:], t[300:]) < 0.01


def test_nn2_fits_and_beats_chance():
    rng = np.random.default_rng(1)
    f, t = _synthetic(rng, noise=0.02)
    m = fit_perf_model("nn2", f[:300], t[:300], f[300:350], t[300:350],
                       max_iters=1500, patience=150)
    err = m.mdrae(f[350:], t[350:])
    # chance is MdRAE ~1; the exact fit error is jax-version dependent
    # (this env lands at ~0.151), so leave margin above the typical value
    assert err < 0.2, err


def test_factor_correction_fixes_constant_scale():
    rng = np.random.default_rng(2)
    f, t = _synthetic(rng)
    m = fit_perf_model("lin", f[:300], t[:300], f[300:], t[300:])
    scale = np.array([2.0, 5.0, 0.5])
    t_target = t * scale                       # "new platform" = scaled times
    mc = factor_correct(m, f[300:320], t_target[300:320])
    assert mc.mdrae(f[320:], t_target[320:]) < 0.02
    assert m.mdrae(f[320:], t_target[320:]) > 0.5


@pytest.mark.parametrize("kind", ["lin", "nn1", "nn2", "factor-lin", "factor-nn2"])
def test_save_load_roundtrip(tmp_path, kind):
    rng = np.random.default_rng(3)
    f, t = _synthetic(rng)
    base_kind = kind.removeprefix("factor-")
    m = fit_perf_model(base_kind, f[:300], t[:300], f[300:], t[300:],
                       max_iters=60, patience=40)
    if kind.startswith("factor-"):
        m = factor_correct(m, f[:40], t[:40] * 3.7)
    p = str(tmp_path / "model.npz")
    m.save(p)
    m2 = PerfModel.load(p)
    assert m2.kind == kind
    assert list(m2.columns) == list(m.columns)
    # byte-identical parameters and predictions — a factor-corrected model
    # must round-trip as factor-corrected (log_factor preserved)
    s1, s2 = m.to_state(), m2.to_state()
    assert s1["header"] == s2["header"]
    assert sorted(s1["arrays"]) == sorted(s2["arrays"])
    for name in s1["arrays"]:
        np.testing.assert_array_equal(s1["arrays"][name], s2["arrays"][name])
    np.testing.assert_allclose(m.predict(f[:10]), m2.predict(f[:10]), rtol=1e-6)


def test_save_is_not_pickle(tmp_path):
    rng = np.random.default_rng(4)
    f, t = _synthetic(rng, n=80)
    m = fit_perf_model("lin", f[:60], t[:60], f[60:], t[60:])
    p = str(tmp_path / "model.npz")
    m.save(p)
    with open(p, "rb") as fh:
        magic = fh.read(2)
    assert magic == b"PK"       # npz = zip archive, not a pickle stream


@pytest.mark.parametrize("kind", ["lin", "nn1", "nn2", "factor-lin"])
def test_subset_columns_matches_sliced_predictions(kind):
    rng = np.random.default_rng(5)
    f, t = _synthetic(rng, n=120)
    base_kind = kind.removeprefix("factor-")
    m = fit_perf_model(base_kind, f[:90], t[:90], f[90:], t[90:],
                       columns=["a", "b", "c"], max_iters=60, patience=40)
    if kind.startswith("factor-"):
        m = factor_correct(m, f[:20], t[:20] * 2.0)
    sub = m.subset_columns(["c", "a"])
    assert list(sub.columns) == ["c", "a"] and sub.n_outputs == 2
    assert sub.kind == kind
    full = m.predict(f[:12])
    np.testing.assert_allclose(sub.predict(f[:12]), full[:, [2, 0]],
                               rtol=1e-5)
    assert m.subset_columns(["a", "b", "c"]) is m       # no-op passthrough
    with pytest.raises(ValueError):
        m.subset_columns(["a", "z"])


def test_fingerprint_ignores_wall_clock():
    rng = np.random.default_rng(6)
    f, t = _synthetic(rng, n=80)
    m = fit_perf_model("lin", f[:60], t[:60], f[60:], t[60:])
    fp = m.fingerprint()
    m.train_seconds = m.train_seconds + 123.0
    assert m.fingerprint() == fp
