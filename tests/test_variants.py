"""PR 9 (DESIGN.md §13): variant-aware lowering, epilogue fusion, and the
serving dispatch fast path.

Covers every entry of all four kernel VARIANTS dicts numerically (vs the
base impl / reference), plan-level variant + epilogue-fusion equivalence on
edge_cnn and a winograd-bearing net, EltwiseLayer folding, plan-cache keying
by (variant, epilogue flag), selection-surface filtering
(``is_runnable``/``tile_columns``), and plan-cache / jit-cache eviction on
``hot_swap``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import VARIANTS as FA_VARIANTS
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.im2col_gemm.ops import VARIANTS as CONV_VARIANTS
from repro.kernels.im2col_gemm.ops import (conv_im2col_batch_op,
                                           conv_im2col_op)
from repro.kernels.matmul.ops import VARIANTS as MM_VARIANTS
from repro.kernels.matmul.ops import matmul_batch_op, matmul_op
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.winograd.ops import VARIANTS as WINO_VARIANTS
from repro.kernels.winograd.ops import (winograd_conv_batch,
                                        winograd_conv_batch_op)
from repro.kernels.winograd.ref import conv3x3_ref
from repro.models import cnn_zoo
from repro.primitives.conv import (REGISTRY, is_runnable, reference_conv_batch,
                                   supports_epilogue, tile_columns,
                                   variant_compatible)
from repro.primitives.executor import (_JIT_CACHE, evict_prim_entries, execute,
                                       make_weights)
from repro.primitives.plan import (_PLAN_CACHE, compile_plan, evict_plans,
                                   heuristic_assignment, lower)
from repro.primitives.variants import conv_variant_call

TOL = dict(rtol=2e-3, atol=2e-3)


def _conv_inputs(rng, n=2, c=6, im=14, k=8, f=3):
    x = jnp.asarray(rng.standard_normal((n, c, im, im)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, c, f, f)) / (f * np.sqrt(c)),
                    jnp.float32)
    return x, w


# ---------------------------------------------------------------------------
# Every VARIANTS entry, numerically, vs base/reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", sorted(MM_VARIANTS))
def test_matmul_variants_single_and_batch(variant, rng):
    x = jnp.asarray(rng.standard_normal((150, 70)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((70, 90)), jnp.float32)
    np.testing.assert_allclose(matmul_op(x, y, variant=variant, interpret=True),
                               matmul_ref(x, y), rtol=1e-4, atol=1e-4)
    xb = jnp.asarray(rng.standard_normal((3, 150, 70)), jnp.float32)
    yb = jnp.broadcast_to(y, (3,) + y.shape)
    got = matmul_batch_op(xb, yb, variant=variant, interpret=True)
    ref = jnp.einsum("bmk,kn->bmn", xb, y)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("variant", sorted(CONV_VARIANTS))
def test_im2col_gemm_variants(variant, rng):
    x, w = _conv_inputs(rng)
    ref = reference_conv_batch(x, w, 1)
    got = conv_im2col_batch_op(x, w, 1, variant=variant, interpret=True)
    np.testing.assert_allclose(got, ref, **TOL)
    got1 = conv_im2col_op(x[0], w, 1, variant=variant, interpret=True)
    np.testing.assert_allclose(got1, ref[0], **TOL)


@pytest.mark.parametrize("variant", sorted(WINO_VARIANTS))
def test_winograd_variants(variant, rng):
    x, w = _conv_inputs(rng)
    ref = reference_conv_batch(x, w, 1)
    got = winograd_conv_batch_op(x, w, variant=variant, interpret=True)
    np.testing.assert_allclose(got, ref, **TOL)
    np.testing.assert_allclose(got[0], conv3x3_ref(x[0], w), **TOL)


@pytest.mark.parametrize("variant", sorted(FA_VARIANTS))
def test_flash_attention_variants(variant, rng):
    q, k, v = (jnp.asarray(rng.standard_normal((2, 128, 4, 32)), jnp.float32)
               for _ in range(3))
    got = flash_attention_op(q, k, v, variant=variant, interpret=True)
    B, S, H, d = q.shape
    ref = attention_ref(q.transpose(0, 2, 1, 3).reshape(B * H, S, d),
                        k.transpose(0, 2, 1, 3).reshape(B * H, S, d),
                        v.transpose(0, 2, 1, 3).reshape(B * H, S, d),
                        causal=True).reshape(B, H, S, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# conv_variant_call: every lowerable (base, variant) family pair
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("base,variant", [
    ("im2col-copy-ab-ki", "mm-256x128x128"),
    ("im2col-scan-ab-ki", "mm-128x256x128"),
    ("im2col-copy-ab-ki", "conv-bk64"),
    ("im2col-scan-ab-ki", "conv-bk128"),
    ("conv-1x1-gemm-ab-ki", "mm-128x128x256"),
    ("conv-1x1-gemm-ab-ki", "conv-bk256"),
    ("winograd-2x2-3x3", "wino-256x128"),
    ("winograd-4x4-3x3", "wino-128x256"),
    ("winograd-2x2-3x3", "mm-128x128x128"),
])
def test_conv_variant_call_matches_reference(base, variant, rng):
    prim = REGISTRY[base]
    f = 1 if prim.family == "c1x1" else 3
    stride = 2 if prim.family == "c1x1" else 1
    x, w = _conv_inputs(rng, f=f)
    ref = reference_conv_batch(x, w, stride)
    got = conv_variant_call(prim, variant, x, w, stride)
    np.testing.assert_allclose(got, ref, **TOL)
    # epilogue path: bias -> residual -> relu on top of the same conv
    bias = jnp.asarray(rng.standard_normal(w.shape[0]), jnp.float32)
    res = jnp.asarray(rng.standard_normal(ref.shape), jnp.float32)
    got_ep = conv_variant_call(prim, variant, x, w, stride,
                               bias=bias, residual=res, relu=True)
    ref_ep = jnp.maximum(ref + bias[:, None, None] + res, 0.0)
    np.testing.assert_allclose(got_ep, ref_ep, **TOL)


def test_conv_variant_call_rejects_incompatible(rng):
    x, w = _conv_inputs(rng)
    with pytest.raises(ValueError):
        conv_variant_call(REGISTRY["winograd-2x2-3x3"], "conv-bk64", x, w, 1)


def test_fuse_store_in_kernel_epilogue(rng):
    """fuse_store=True forces the epilogue into the kernel's store step —
    numerics must match the wrapper-level default exactly both ways."""
    from repro.kernels.im2col_gemm.im2col_gemm import conv_im2col_batch
    from repro.kernels.matmul.matmul import matmul
    x = jnp.asarray(rng.standard_normal((150, 70)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((70, 90)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal(150), jnp.float32)
    res = jnp.asarray(rng.standard_normal((150, 90)), jnp.float32)
    ref = jnp.maximum(x @ y + bias[:, None] + res, 0.0)
    for fuse in (True, False):
        got = matmul(x, y, bm=64, bk=64, bn=64, bias=bias, residual=res,
                     relu=True, interpret=True, fuse_store=fuse)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    xc, wc = _conv_inputs(rng)
    cref = reference_conv_batch(xc, wc, 1)
    cbias = jnp.asarray(rng.standard_normal(wc.shape[0]), jnp.float32)
    cres = jnp.asarray(rng.standard_normal(cref.shape), jnp.float32)
    want = jnp.maximum(cref + cbias[:, None, None] + cres, 0.0)
    for fuse in (True, False):
        got = conv_im2col_batch(xc, wc, 1, bk=64, bias=cbias, residual=cres,
                                relu=True, interpret=True, fuse_store=fuse)
        np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# Selection surface: is_runnable / tile_columns / traits
# ---------------------------------------------------------------------------

def test_variant_compatibility_filters():
    assert variant_compatible("im2col-copy-ab-ki", "mm-128x128x128")
    assert variant_compatible("im2col-copy-ab-ki", "conv-bk64")
    assert not variant_compatible("im2col-copy-ab-ki", "wino-128x128")
    assert variant_compatible("winograd-2x2-3x3", "wino-256x128")
    assert variant_compatible("winograd-4x4-3x3", "mm-256x128x128")
    assert not variant_compatible("winograd-2x2-3x3", "conv-bk64")
    assert not variant_compatible("conv-1x1-gemm-ab-ki", "wino-128x128")
    assert not variant_compatible("im2col-copy-ab-ki", "bogus-tile")


def test_is_runnable_consults_variant():
    assert is_runnable("im2col-copy-ab-ki@conv-bk64")
    assert not is_runnable("im2col-copy-ab-ki@wino-128x128")
    assert not is_runnable("winograd-2x2-3x3@conv-bk128")


def test_tile_columns_cross_product_filtered():
    cols = tile_columns(("im2col-copy-ab-ki", "winograd-2x2-3x3"),
                        list(CONV_VARIANTS) + list(WINO_VARIANTS))
    assert cols == ["im2col-copy-ab-ki@conv-bk64",
                    "im2col-copy-ab-ki@conv-bk128",
                    "im2col-copy-ab-ki@conv-bk256",
                    "winograd-2x2-3x3@wino-128x128",
                    "winograd-2x2-3x3@wino-256x128",
                    "winograd-2x2-3x3@wino-128x256"]
    # the default (matmul-variant) pool is the full cross product: every
    # mm-* block config lowers through every GEMM-shaped base
    from repro.core.autotune import PALLAS_CONV_BASES, pallas_columns
    assert len(pallas_columns()) == len(PALLAS_CONV_BASES) * len(MM_VARIANTS)


def test_epilogue_traits():
    assert supports_epilogue("im2col-copy-ab-ki")
    assert supports_epilogue("winograd-2x2-3x3@wino-128x128")
    assert not supports_epilogue("direct-sum2d")


# ---------------------------------------------------------------------------
# Plan-level: variants + epilogue fusion on edge_cnn and a winograd net
# ---------------------------------------------------------------------------

def _wino_spec():
    """A small residual net whose convs are all 3x3 stride-1 — every one
    can carry a winograd assignment, and the add join can fuse."""
    b = cnn_zoo._Builder("wino_res")
    c0 = b.conv(8, 4, 16, 1, 3)               # out 14
    c1 = b.conv(8, 8, 14, 1, 3)               # out 12
    c2 = b.conv(8, 8, 12, 1, 3)               # out 10 == the join size
    b.join("add", 8, 10, [c1, c2])
    return b.build()


def test_variant_plan_matches_base_edge_cnn(rng):
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic_assignment(spec)
    asg_v = {i: (v + "@mm-256x128x128"
                 if v.startswith(("im2col", "conv-1x1")) else v)
             for i, v in asg.items()}
    w = make_weights(spec)
    x = jnp.asarray(rng.standard_normal((2, 3, 32, 32)), jnp.float32)
    base = compile_plan(spec, asg)(x, w)
    tiled = compile_plan(spec, asg_v)(x, w)
    for k in base:
        np.testing.assert_allclose(np.asarray(base[k]), np.asarray(tiled[k]),
                                   **TOL)


def test_fused_vs_unfused_edge_cnn(rng):
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic_assignment(spec)
    w = make_weights(spec)
    x = jnp.asarray(rng.standard_normal((2, 3, 32, 32)), jnp.float32)
    fused = compile_plan(spec, asg, epilogues=True)
    unfused = compile_plan(spec, asg, epilogues=False)
    assert fused.epilogue_signature, "edge_cnn's add joins should fuse"
    assert all(ops == ("residual",)
               for _, _, ops in fused.epilogue_signature)
    assert unfused.epilogue_signature == ()
    of, ou = fused(x, w), unfused(x, w)
    for k in of:
        np.testing.assert_allclose(np.asarray(of[k]), np.asarray(ou[k]),
                                   **TOL)


def test_fused_vs_unfused_winograd_net(rng):
    spec = _wino_spec()
    asg = {i: ("winograd-2x2-3x3@wino-128x128"
               if isinstance(n, cnn_zoo.ConvLayer) else "chw")
           for i, n in enumerate(spec.nodes)}
    w = make_weights(spec)
    x = jnp.asarray(rng.standard_normal((2, 4, 16, 16)), jnp.float32)
    fused = compile_plan(spec, asg, epilogues=True)
    unfused = compile_plan(spec, asg, epilogues=False)
    assert fused.epilogue_signature == ((2, 3, ("residual",)),)
    of, ou = fused(x, w), unfused(x, w)
    for k in of:
        np.testing.assert_allclose(np.asarray(of[k]), np.asarray(ou[k]),
                                   **TOL)
    # and against the interpreted oracle
    rep = execute(spec, asg, w, x=np.asarray(x[0]), compiled=False)
    np.testing.assert_allclose(np.asarray(of[3][0]),
                               np.asarray(rep.outputs[3]), **TOL)


def test_eltwise_bias_relu_fold_into_conv(rng):
    b = cnn_zoo._Builder("tiny_ep")
    b.conv(8, 4, 12, 1, 3)
    b.eltwise("bias", 8, 10)
    b.eltwise("relu", 8, 10)
    spec = b.build()
    asg = {0: "im2col-copy-ab-ki@conv-bk64", 1: "chw", 2: "chw"}
    w = make_weights(spec)
    x = jnp.asarray(rng.standard_normal((3, 4, 12, 12)), jnp.float32)
    plan = compile_plan(spec, asg, epilogues=True)
    assert plan.epilogue_signature == ((0, 2, ("bias", "relu")),)
    assert len(plan.steps) == 1            # conv + bias + relu -> one step
    out = plan(x, w)
    rep = execute(spec, asg, w, x=np.asarray(x[0]), compiled=False)
    np.testing.assert_allclose(np.asarray(out[2][0]),
                               np.asarray(rep.outputs[2]), **TOL)
    assert np.asarray(out[2]).min() >= 0.0    # the ReLU really applied


def test_eltwise_unfused_when_base_lacks_epilogue(rng):
    b = cnn_zoo._Builder("tiny_nf")
    b.conv(8, 4, 12, 1, 3)
    b.eltwise("relu", 8, 10)
    spec = b.build()
    asg = {0: "direct-sum2d", 1: "chw"}        # no epilogue trait
    steps, _ = lower(spec, asg, epilogues=True)
    assert len(steps) == 2                      # EltwiseStep stays separate
    w = make_weights(spec)
    x = jnp.asarray(rng.standard_normal((2, 4, 12, 12)), jnp.float32)
    out = compile_plan(spec, asg, epilogues=True)(x, w)
    rep = execute(spec, asg, w, x=np.asarray(x[0]), compiled=False)
    np.testing.assert_allclose(np.asarray(out[1][0]),
                               np.asarray(rep.outputs[1]), **TOL)


def test_lower_rejects_incompatible_tile():
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic_assignment(spec)
    conv = next(i for i, n in enumerate(spec.nodes)
                if isinstance(n, cnn_zoo.ConvLayer)
                and asg[i].startswith("im2col"))
    asg[conv] = asg[conv] + "@wino-128x128"
    with pytest.raises(ValueError):
        lower(spec, asg)


# ---------------------------------------------------------------------------
# Cache keys + eviction
# ---------------------------------------------------------------------------

def test_plan_cache_keys_variant_and_epilogues(rng):
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic_assignment(spec)
    asg_v = dict(asg)
    conv = next(i for i, v in asg.items() if v.startswith("im2col"))
    asg_v[conv] = asg_v[conv] + "@mm-256x128x128"
    p1 = compile_plan(spec, asg, epilogues=True)
    p2 = compile_plan(spec, asg, epilogues=False)
    p3 = compile_plan(spec, asg_v, epilogues=True)
    assert p1 is not p2 and p1 is not p3
    assert p1 is compile_plan(spec, asg, epilogues=True)        # cache hit
    assert p3 is compile_plan(spec, asg_v, epilogues=True)
    st = next(s for s in p3.steps
              if getattr(s, "node", None) == conv)
    assert st.variant == "mm-256x128x128"
    # "all" plans never fuse: they are the interpreted oracle surface
    pa = compile_plan(spec, asg, outputs="all", epilogues=True)
    assert pa.epilogue_signature == ()


def test_evict_plans_drops_all_entries_for_assignment():
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic_assignment(spec)
    compile_plan(spec, asg, (1, 3, 32, 32))
    compile_plan(spec, asg, (2, 3, 32, 32), epilogues=False)
    other = dict(asg)
    other[0] = "direct-sum2d"
    compile_plan(spec, other, (1, 3, 32, 32))
    assert evict_plans(spec, asg) >= 2
    akey = tuple(sorted(asg.items()))
    assert not any(k[1] == akey for k in _PLAN_CACHE)
    assert evict_plans(spec, asg) == 0          # idempotent
    assert evict_plans(spec, other) >= 1        # the other entry survived


def test_jit_cache_eviction_by_column(rng):
    from repro.primitives import layouts as L
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic_assignment(spec)
    execute(spec, asg, make_weights(spec), compiled=False)
    cols = {v for v in asg.values() if v not in L.LAYOUTS}
    assert any(k[0] == "prim" and k[1] in cols for k in _JIT_CACHE)
    assert evict_prim_entries(cols) > 0
    assert not any(k[0] == "prim" and k[1] in cols for k in _JIT_CACHE)
    assert evict_prim_entries(cols) == 0


def test_hot_swap_evicts_retired_generation(rng):
    from repro.service.pipeline import OptimisedNetwork
    from repro.service.server import OptimisedServer
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic_assignment(spec)
    asg2 = dict(asg)
    asg2[0] = "direct-sum2d"
    akey = tuple(sorted(asg.items()))
    server = OptimisedServer(max_batch=2, latency_budget_ms=float("inf"))
    server.register(OptimisedNetwork.from_assignment(spec, asg))
    x = np.random.default_rng(0).standard_normal((2, 3, 32, 32)).astype(np.float32)
    server.serve("edge_cnn", x)
    assert any(k[1] == akey for k in _PLAN_CACHE)
    assert len(server._plan_handles) == 1
    assert server.hot_swap("edge_cnn",
                           OptimisedNetwork.from_assignment(spec, asg2))
    # the retired generation's plans are gone, the new one's are live
    assert not any(k[1] == akey for k in _PLAN_CACHE)
    akey2 = tuple(sorted(asg2.items()))
    assert any(k[1] == akey2 for k in _PLAN_CACHE)
    assert len(server._plan_handles) == 1
    server.serve("edge_cnn", x)                 # still serves correctly
    server.stop()
