"""Checkpoint manager + optimizers."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.train import optim


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layers": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros((8,))},
            "step_arr": jnp.asarray(3, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t, extra={"loss": 1.5})
    step, restored = mgr.restore_latest(jax.eval_shape(lambda: t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_allclose(a, b)
    assert mgr.manifest(10)["extra"]["loss"] == 1.5


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]


def test_corrupt_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    # corrupt step 2's arrays: manifest checksum no longer matches
    with open(os.path.join(str(tmp_path), "step_2", "arrays.npz"), "ab") as f:
        f.write(b"garbage")
    assert mgr.steps() == [1]
    step, _ = mgr.restore_latest(jax.eval_shape(lambda: _tree()))
    assert step == 1


@pytest.mark.parametrize("name", ["sgd", "adam", "adamw", "adafactor"])
def test_optimizers_descend_quadratic(name):
    opt = optim.make_optimizer(name, 0.1 if name != "adafactor" else 0.5)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(q["x"] ** 2))(p)
        return opt.update(p, g, s)

    for _ in range(60):
        params, state = step(params, state)
    assert float(jnp.sum(params["x"] ** 2)) < 0.5


def test_adafactor_factored_state_is_small():
    opt = optim.adafactor(1e-2)
    params = {"w": jnp.zeros((256, 512))}
    st = opt.init(params)
    v = st["v"]["w"]
    assert v["v"] is None and v["vr"].shape == (256,) and v["vc"].shape == (512,)


def test_schedules():
    s = optim.warmup_cosine_schedule(1.0, 10, 110)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(110)) < 1e-6
    d = optim.step_decay_schedule(1.0, 0.1, 100)
    assert abs(float(d(250)) - 0.01) < 1e-9


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(optim.global_norm(clipped)) - 1.0) < 1e-5
