"""Fleet-scale calibration sharing: a deterministic multi-host soak
(DESIGN.md §14). Three simulated hosts on one fake clock share a single
faulty object-store bucket:

  * host A optimises cold, serves, drifts 4x, recalibrates from its own
    served evidence and publishes it to the pool — through a torn first
    upload that the publish retry must absorb;
  * host B warm-starts byte-identically from A's artifacts, serves healthy
    traffic, then pool-polls and hot-swaps from A's published evidence
    with ZERO freshly profiled configs;
  * host C warm-starts and never serves before its pool poll: it
    recalibrates from fleet evidence alone, profiling nothing;
  * host D crashes between staged upload and manifest commit — readers
    never see the partial entry and ``sweep`` collects the orphan.

Plan execution advances the shared fake clock (the PacedServer idiom from
test_serving.py), so drift detection, windows, and store mtimes are all
deterministic — no wall-clock sleeps in the serving path. The only real
waiting is for background recalibration threads to finish.
"""
import time

import numpy as np
import pytest

from repro.service import (ArtifactStore, BackendError, ObjectStoreBackend,
                           OptimisedServer, ScriptedFaults, layer_profile,
                           make_recalibrator, optimise)
from repro.service.platforms import SimulatedPlatform


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


class _FleetServer(OptimisedServer):
    """Deterministic host: real plan execution, but dispatch *timing* is
    the shared fake clock advanced by the host's true per-image cost ×
    the platform's ``time_scale`` — the observed/predicted drift ratio is
    exact, not wall-clock noise."""

    def __init__(self, fake_clock, base_cost_s, **kw):
        super().__init__(clock=fake_clock, **kw)
        self._fake = fake_clock
        self._base_cost_s = base_cost_s

    def _run_plan(self, opt, xs, weights):
        out = super()._run_plan(opt, xs, weights)
        scale = getattr(opt.platform, "time_scale", 1.0) or 1.0
        self._fake.advance(self._base_cost_s * xs.shape[0] * scale)
        return out


def _requests(spec, n, seed=0):
    n0 = spec.nodes[0]
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n0.c, n0.im, n0.im)).astype(np.float32)


def _pump_batch(server, net, xs, tickets):
    batch = [server.submit(net, x) for x in xs]
    tickets.extend(batch)
    server.pump()
    return batch


def _wait_recal(server, timeout_s=120.0):
    deadline = time.time() + timeout_s
    while not server.recalibrations_idle() and time.time() < deadline:
        time.sleep(0.01)
    assert server.recalibrations_idle(), "recalibration thread hung"


def _count_profiles(platform):
    calls = []
    orig = platform.profile
    platform.profile = lambda cfgs: (calls.append(
        len(np.atleast_2d(np.asarray(cfgs)))), orig(cfgs))[1]
    return calls


def test_fleet_soak_pooled_recalibration(tmp_path):
    clock = FakeClock()
    shared = ObjectStoreBackend(clock=clock)

    storeA = ArtifactStore(backend=shared.share(), clock=clock)
    storeB = ArtifactStore(backend=shared.share(), clock=clock)
    storeC = ArtifactStore(backend=shared.share(), clock=clock)
    # A's recalibrator publishes through a view whose first staged upload
    # is torn mid-write: publish_drift's single retry must absorb it
    faultsA = ScriptedFaults([(("put", "stage."), "torn")])
    storeA_pub = ArtifactStore(backend=shared.share(faults=faultsA),
                               clock=clock)

    platformA = SimulatedPlatform("arm", max_triplets=16)
    platformB = SimulatedPlatform("arm", max_triplets=16)
    platformC = SimulatedPlatform("arm", max_triplets=16)
    fp = platformA.pool_fingerprint()
    assert platformB.fingerprint() == fp == platformC.fingerprint()

    # -- warm start across the shared backend ------------------------------
    optA = optimise("edge_cnn", platformA, store=storeA, executable=True,
                    max_iters=250)
    assert not optA.warm_selection          # cold: A paid the optimisation
    optB = optimise("edge_cnn", platformB, store=storeB, executable=True,
                    max_iters=250)
    optC = optimise("edge_cnn", platformC, store=storeC, executable=True,
                    max_iters=250)
    for warm in (optB, optC):
        assert warm.warm_models and warm.warm_selection and warm.warm
        assert warm.assignment == optA.assignment        # byte-identical
        assert warm.predicted_cost_s == optA.predicted_cost_s

    prof = layer_profile(optA)
    n_cfg = len({tuple(map(int, r)) for r in prof.feats})
    assert n_cfg > 0

    def mk_server(opt, store, host):
        return _FleetServer(
            clock, opt.predicted_cost_s,
            max_batch=4, latency_budget_ms=1e9,
            drift_threshold=1.5, drift_alpha=0.5, drift_calib_obs=2,
            recalibrate=make_recalibrator(store=store, sample_n=n_cfg,
                                          mode="factor", pool=True,
                                          host=host))

    serverA = mk_server(optA, storeA_pub, "A")
    serverB = mk_server(optB, storeB, "B")
    serverC = mk_server(optC, storeC, "C")
    serverA.register(optA)
    serverB.register(optB)
    serverC.register(optC)
    net = optA.net
    tickets = {"A": [], "B": [], "C": []}
    generations = []

    try:
        # -- healthy phase: A and B serve (compile + clean); C stays idle --
        for i in range(5):
            _pump_batch(serverA, net, _requests(optA.spec, 4, seed=i),
                        tickets["A"])
            _pump_batch(serverB, net, _requests(optB.spec, 4, seed=i),
                        tickets["B"])
            generations.append(serverA.stats(net)["generation"])
        assert serverA.stats(net)["observed_dispatches"] >= 2
        assert serverA.stats(net)["recalibrations"] == 0

        # -- host A drifts 4x and self-recalibrates from served evidence --
        platformA.time_scale = 4.0
        platformA.invalidate_datasets()
        for i in range(10):
            _pump_batch(serverA, net, _requests(optA.spec, 4, seed=10 + i),
                        tickets["A"])
            generations.append(serverA.stats(net)["generation"])
            _wait_recal(serverA)
            if serverA.stats(net)["recalibrations"]:
                break
        stA = serverA.stats(net)
        assert stA["recalibrations"] == 1 and stA["generation"] == 1
        assert stA["last_recal_error"] is None
        assert stA["recal_sample"]["fresh_rows"] == 0     # served covered all
        # the torn first upload fired and the publish retry landed anyway
        assert faultsA.pending == 0
        assert [f[2] for f in faultsA.fired] == ["torn"]
        assert [m["fields"]["host"]
                for m in storeB.drift_entries(fp)] == ["A"]

        # -- host D crashes between staged upload and manifest commit ------
        # (A's buffer was reset by its hot swap; D publishes B's evidence)
        dsA = serverB.served_sample(net)
        assert dsA is not None
        storeD = ArtifactStore(
            backend=shared.share(
                faults=ScriptedFaults([(("put", "manifest.json"), "raise")])),
            clock=clock)
        with pytest.raises(BackendError):
            storeD.put_dataset({"artifact": "drift_pool", "platform": fp,
                                "host": "D", "seq": 0,
                                "data": dsA.fingerprint()},
                               dsA, category="drift_pool")
        # the partial entry is invisible to every reader
        assert {m["fields"]["host"]
                for m in storeB.drift_entries(fp)} == {"A"}

        # -- host B pool-polls: hot-swap from A's evidence, zero profiling --
        callsB = _count_profiles(platformB)
        assert serverB.poll_pool(storeB, host="B") == 1
        _wait_recal(serverB)
        stB = serverB.stats(net)
        assert stB["recalibrations"] == 1 and stB["generation"] == 1
        assert stB["last_recal_error"] is None
        assert stB["recal_sample"]["fresh_rows"] == 0
        assert stB["recal_sample"]["pooled_sources"] == 1
        assert stB["recal_sample"]["served_rows"] > 0
        assert callsB == [], "pool recalibration profiled fresh configs"
        # B published its own evidence while recalibrating
        assert {m["fields"]["host"]
                for m in storeC.drift_entries(fp)} == {"A", "B"}

        # -- host C never served: fleet evidence alone, zero profiling -----
        callsC = _count_profiles(platformC)
        assert serverC.served_sample(net) is None
        assert serverC.poll_pool(storeC, host="C") == 1
        _wait_recal(serverC)
        stC = serverC.stats(net)
        assert stC["recalibrations"] == 1 and stC["generation"] == 1
        assert stC["last_recal_error"] is None
        assert stC["recal_sample"]["fresh_rows"] == 0
        assert stC["recal_sample"]["pooled_sources"] == 2
        assert callsC == [], "evidence-only recalibration profiled configs"

        # -- a second poll with nothing new schedules nothing --------------
        assert serverB.poll_pool(storeB, host="B") == 0
        assert serverC.poll_pool(storeC, host="C") == 0

        # -- post-swap traffic observes the new generation everywhere ------
        for srv, key in ((serverA, "A"), (serverB, "B"), (serverC, "C")):
            for i in (0, 1):
                _pump_batch(srv, net, _requests(optA.spec, 4, seed=30 + i),
                            tickets[key])
            assert srv.stats(net)["generation"] == 1
        generations.append(serverA.stats(net)["generation"])
    finally:
        for srv in (serverA, serverB, serverC):
            srv.stop(timeout=60.0)
        platformA.time_scale = 1.0

    # -- zero lost, zero duplicated tickets on every host ------------------
    for srv, key in ((serverA, "A"), (serverB, "B"), (serverC, "C")):
        ts = tickets[key]
        assert ts and all(t.wait(30.0) for t in ts)
        assert all(t.done and not t.rejected and t.error is None
                   and t.result is not None for t in ts)
        assert srv.stats(net)["images"] == len(ts)
    assert generations == sorted(generations)

    # -- sweep collects D's orphan; committed entries stay intact ----------
    keys = [k for k in shared.list("drift_pool/") if not k.endswith("/")]
    by_entry = {}
    for k in keys:
        by_entry.setdefault(k.rsplit("/", 1)[0], []).append(k)
    # D's crashed entry exists as a bare staged payload, no manifest
    orphans = [e for e, ks in by_entry.items()
               if not any(k.endswith("manifest.json") for k in ks)]
    assert len(orphans) == 1
    storeB.sweep(category="drift_pool", grace_s=-1.0)
    keys = [k for k in shared.list("drift_pool/") if not k.endswith("/")]
    by_entry = {}
    for k in keys:
        by_entry.setdefault(k.rsplit("/", 1)[0], []).append(k)
    assert orphans[0] not in by_entry
    # every surviving entry is exactly manifest + its live payload
    assert all(sorted(k.rsplit("/", 1)[1] for k in ks)[0] == "manifest.json"
               and len(ks) == 2 for ks in by_entry.values())
    assert {m["fields"]["host"]
            for m in storeB.drift_entries(fp)} == {"A", "B"}
