"""Fault-tolerant serving (DESIGN.md §11): deterministic fault injection,
retry → safe-plan degradation, per-backend circuit breakers with half-open
probing, supervised workers (hung-dispatch abandonment, zombie shedding),
canaried hot_swap with bounded rollback, and the chaos soak asserting the
system-level availability invariants — zero lost tickets, zero duplicated
tickets, ≥99% served under injected raise/hang/slowdown faults.

Determinism: fault plans match on (state key, generation, per-key dispatch
index) — no randomness; unit tests drive time through the injected fake
clock. The soak runs on the real clock (workers + supervisor are real
threads) but its fault schedule, routing preferences, and accounting
identities are exact, not statistical.
"""
import threading
import time

import numpy as np
import pytest

from repro.models import cnn_zoo
from repro.primitives.plan import heuristic_assignment
from repro.service import (CircuitBreaker, CorruptOutput, Fault, FaultError,
                           FaultInjector, OptimisedNetwork, OptimisedServer,
                           safe_assignment)
from repro.service.platforms import SimulatedPlatform
from repro.service.serving.faults import classify, validate_output


class FakeClock:
    """Deterministic injectable clock: time moves only when a test says so."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


# ---------------------------------------------------------------------------
# Fixtures / helpers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def spec():
    return cnn_zoo.get("edge_cnn")


def _net(spec, *, net="edge_cnn", predicted=2e-3):
    return OptimisedNetwork.from_assignment(spec, heuristic_assignment(spec),
                                            net=net, predicted_cost_s=predicted)


def _requests(spec, n, seed=0):
    n0 = spec.nodes[0]
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n0.c, n0.im, n0.im)).astype(np.float32)


def _wait_for(pred, timeout=30.0, what=""):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return
        time.sleep(0.005)
    raise AssertionError(f"timed out waiting for {what or pred}")


# ---------------------------------------------------------------------------
# Fault plans are deterministic (pure, no server)
# ---------------------------------------------------------------------------

def test_fault_matching_and_injection_log():
    f = Fault("raise", net="n#a", generation=1, first=2, last=8, every=3)
    assert not f.matches("n#b", 1, 2)          # wrong key
    assert not f.matches("n#a", 0, 2)          # wrong generation
    assert f.matches("n#a", None, 2)           # generation unknown: matches
    assert [i for i in range(10) if f.matches("n#a", 1, i)] == [2, 5]
    with pytest.raises(ValueError):
        Fault("explode")
    with pytest.raises(ValueError):
        Fault("raise", every=0)

    inj = FaultInjector([Fault("raise", net="n", first=1, last=2)])
    assert inj.run("n", 0, lambda: np.zeros(1)) is not None     # index 0
    with pytest.raises(FaultError):
        inj.run("n", 0, lambda: np.zeros(1))                    # index 1
    assert inj.run("m", 0, lambda: np.zeros(1)) is not None     # other key
    assert inj.count("n") == 2 and inj.count("m") == 1
    assert inj.injected == [("n", 0, 1, "raise")]


def test_corrupt_fault_and_output_validation():
    inj = FaultInjector([Fault("corrupt", net="n")])
    out = inj.run("n", 0, lambda: np.ones((4, 3), np.float32))
    assert np.isnan(out[0]).all() and np.isfinite(out[1:]).all()
    with pytest.raises(CorruptOutput):
        validate_output(out, 4)
    with pytest.raises(CorruptOutput):
        validate_output(np.ones((2, 3)), 4)    # wrong leading dim
    assert validate_output(np.ones((4, 3)), 4).shape == (4, 3)
    assert classify(CorruptOutput("x")) == "corrupt"
    assert classify(FaultError("x")) == "fault"
    assert classify(ValueError("x")) == "error"


# ---------------------------------------------------------------------------
# Circuit breaker state machine (pure)
# ---------------------------------------------------------------------------

def test_breaker_trips_recovers_via_half_open_probe():
    br = CircuitBreaker(failures=3, cooldown_s=1.0, probes=1)
    for t in range(3):
        assert br.allow(float(t))
        br.record(False, float(t))
    assert br.state == "open" and br.opens == 1
    assert not br.allow(2.5)                   # cooling down
    assert br.allow(3.1)                       # cooldown over: probe granted
    assert br.state == "half_open" and br.inflight_probes == 1
    assert not br.allow(3.1)                   # probe quota exhausted
    br.record(False, 3.2)                      # probe failed: re-open
    assert br.state == "open" and br.opens == 2
    assert br.allow(4.3)                       # second probe
    br.record(True, 4.4)                       # probe succeeded: close
    assert br.state == "closed" and br.closes == 1
    assert br.inflight_probes == 0 and br.consecutive == 0
    snap = br.snapshot(4.5)
    assert snap["state"] == "closed" and snap["opens"] == 2


def test_breaker_window_rate_trip_and_probe_cancel():
    br = CircuitBreaker(failures=100, window=4, rate=0.5, cooldown_s=1.0)
    for ok in (True, False, True, False):      # 50% over a full window
        br.record(ok, 0.0)
    assert br.state == "open"
    assert br.allow(1.5) and br.inflight_probes == 1
    br.cancel_probe()                          # admitted but never dispatched
    assert br.inflight_probes == 0
    assert br.allow(1.5)                       # slot returned: re-grantable


# ---------------------------------------------------------------------------
# Retry and graceful degradation (synchronous pump, fake clock)
# ---------------------------------------------------------------------------

def test_transient_fault_costs_a_retry_not_degradation(spec):
    inj = FaultInjector([Fault("raise", net="edge_cnn", first=0, last=1)])
    server = OptimisedServer(max_batch=4, faults=inj, clock=FakeClock())
    server.register(_net(spec))
    ts = [server.submit("edge_cnn", x) for x in _requests(spec, 2)]
    server.pump()
    assert all(t.done and t.error is None and not t.degraded for t in ts)
    s = server.stats("edge_cnn")
    assert s["retries"] == 1 and s["failed_dispatches"] == 0
    assert s["dispatches"] == 1 and s["images"] == 2
    assert s["failures"] == {}                 # ledger: failed dispatches only
    assert s["breaker"]["state"] == "closed"


def test_persistent_fault_degrades_to_safe_plan(spec):
    from repro.primitives.executor import make_weights
    inj = FaultInjector([Fault("raise", net="edge_cnn", first=0, last=2)])
    server = OptimisedServer(max_batch=4, faults=inj, clock=FakeClock())
    weights = make_weights(spec)
    server.register(_net(spec), weights=weights)
    xs = _requests(spec, 2, seed=3)
    ts = [server.submit("edge_cnn", x) for x in xs]
    server.pump()
    assert all(t.done and t.error is None and t.degraded for t in ts)
    assert all(t.result is not None for t in ts)
    s = server.stats("edge_cnn")
    assert s["failed_dispatches"] == 1 and s["retries"] == 1
    assert s["fallback_dispatches"] == 1 and s["fallback_images"] == 2
    assert s["failed_tickets"] == 0 and s["images"] == 0
    assert s["failures"] == {"fault": 1}
    assert s["breaker"]["consecutive_failures"] == 1
    # the degraded answer is the same inference: the next dispatch (faults
    # exhausted) serves the identical input through the primary plan
    t2 = server.submit("edge_cnn", xs[0])
    server.pump()
    assert t2.error is None and not t2.degraded
    np.testing.assert_allclose(ts[0].result, t2.result, rtol=1e-2, atol=1e-3)


def test_corrupt_output_is_detected_and_rescued(spec):
    inj = FaultInjector([Fault("corrupt", net="edge_cnn", first=0, last=2)])
    server = OptimisedServer(max_batch=4, faults=inj, clock=FakeClock())
    server.register(_net(spec))
    t = server.submit("edge_cnn", _requests(spec, 1)[0])
    server.pump()
    assert t.done and t.error is None and t.degraded
    assert np.isfinite(t.result).all()         # NaN never reached the client
    s = server.stats("edge_cnn")
    assert s["failures"] == {"corrupt": 1}


def test_no_fallback_fails_tickets_with_the_error(spec):
    inj = FaultInjector([Fault("raise", net="edge_cnn")])
    server = OptimisedServer(max_batch=4, faults=inj, fallback=False,
                             clock=FakeClock())
    server.register(_net(spec))
    ts = [server.submit("edge_cnn", x) for x in _requests(spec, 2)]
    server.pump()
    assert all(t.done and t.result is None for t in ts)
    assert all("injected fault" in t.error for t in ts)
    s = server.stats("edge_cnn")
    assert s["failed_tickets"] == 2 and s["fallback_images"] == 0
    # the claim settled: the in-flight slot is free and serving continues
    assert s["inflight"] == 0


# ---------------------------------------------------------------------------
# Breaker-aware routing: spill to healthy backends, recover via probe
# ---------------------------------------------------------------------------

def test_breaker_opens_spills_to_healthy_backend_and_recovers(spec):
    clock = FakeClock()
    inj = FaultInjector([Fault("raise", net="edge_cnn#a", first=0, last=4)],
                        clock=clock)
    server = OptimisedServer(max_batch=4, faults=inj, clock=clock,
                             breaker_failures=2, breaker_cooldown_ms=1000.0)
    # backend a predicts far cheaper, so the router prefers it while allowed
    server.register(_net(spec, predicted=1e-6), backend="a")
    server.register(_net(spec, predicted=1e-3), backend="b")
    xs = _requests(spec, 5, seed=1)

    t1 = server.submit("edge_cnn", xs[0]);  server.pump()
    t2 = server.submit("edge_cnn", xs[1]);  server.pump()
    st = server.stats("edge_cnn")["backends"]
    assert st["a"]["failed_dispatches"] == 2
    assert st["a"]["breaker"]["state"] == "open"
    assert t1.degraded and t2.degraded         # rescued, not lost

    t3 = server.submit("edge_cnn", xs[2]);  server.pump()
    st = server.stats("edge_cnn")["backends"]
    assert st["b"]["images"] == 1 and not t3.degraded    # spilled to b

    clock.advance(1.1)                         # cooldown elapses
    t4 = server.submit("edge_cnn", xs[3])      # half-open: probe lands on a
    assert server.stats("edge_cnn")["backends"]["a"]["breaker"]["state"] \
        == "half_open"
    t5 = server.submit("edge_cnn", xs[4])      # probe quota spent: goes to b
    server.pump()
    st = server.stats("edge_cnn")["backends"]
    assert st["a"]["breaker"]["state"] == "closed"       # probe succeeded
    assert st["a"]["breaker"]["opens"] == 1
    assert st["a"]["breaker"]["closes"] == 1
    assert st["a"]["images"] == 1 and st["b"]["images"] == 2
    assert all(t.done and t.error is None for t in (t3, t4, t5))
    agg = server.stats("edge_cnn")
    assert agg["failures"] == {"fault": 2}
    assert agg["images"] + agg["fallback_images"] == 5   # nothing lost/dup


# ---------------------------------------------------------------------------
# Supervised workers: hung dispatch abandoned, rescued, worker replaced
# ---------------------------------------------------------------------------

def test_hung_worker_is_abandoned_rescued_and_replaced(spec):
    clock = FakeClock()
    inj = FaultInjector(
        [Fault("hang", net="edge_cnn", first=0, last=1, seconds=5.0)],
        clock=clock)
    server = OptimisedServer(max_batch=4, workers=1, max_wait_ms=0.0,
                             exec_deadline_ms=100.0, faults=inj, clock=clock)
    server.register(_net(spec))
    xs = _requests(spec, 2, seed=2)
    try:
        t1 = server.submit("edge_cnn", xs[0])
        _wait_for(lambda: inj.count("edge_cnn") == 1, what="worker to claim")
        clock.advance(0.2)                     # past the execution deadline
        _wait_for(lambda: t1.done, what="supervisor rescue")
        assert t1.error is None and t1.degraded and t1.result is not None
        s = server.stats("edge_cnn")
        assert s["failures"] == {"deadline": 1}
        assert s["fallback_images"] == 1 and s["images"] == 0
        assert server._pool.restarts == 1 and server._pool.zombies == 1

        # the replacement worker serves fresh traffic immediately
        t2 = server.submit("edge_cnn", xs[1])
        _wait_for(lambda: t2.done, what="replacement worker")
        assert t2.error is None and not t2.degraded

        # un-stick the zombie: it completes, loses every settle/finish race,
        # and exits — the rescued ticket's answer must not change
        clock.advance(10.0)
        _wait_for(lambda: server._pool.zombies == 0, timeout=60.0,
                  what="zombie exit")
        assert t1.degraded and server.stats("edge_cnn")["images"] == 1
    finally:
        clock.advance(100.0)                   # free any residual stall
        server.stop(timeout=60.0)


def test_zombie_waking_mid_rescue_cannot_error_the_tickets(spec):
    # Race regression: the supervisor abandons a hung dispatch and starts
    # the (slow) fallback rescue; the zombie's plan completes while the
    # rescue is still in flight. The zombie's execute() lost the settle
    # race, so it owns nothing — it must return without touching the
    # tickets, or first-finish-wins turns its "internal serving error"
    # into the delivered outcome and locks the rescue out.
    clock = FakeClock()
    inj = FaultInjector(
        [Fault("hang", net="edge_cnn", first=0, last=1, seconds=5.0)],
        clock=clock)
    server = OptimisedServer(max_batch=4, workers=1, max_wait_ms=0.0,
                             exec_deadline_ms=100.0, faults=inj, clock=clock)
    server.register(_net(spec))
    rescue_started = threading.Event()
    rescue_resume = threading.Event()
    real_rescue = server._run_fallback

    def slow_rescue(batch, err):
        rescue_started.set()
        rescue_resume.wait(60.0)
        return real_rescue(batch, err)

    server._run_fallback = slow_rescue
    xs = _requests(spec, 2, seed=7)
    try:
        t1 = server.submit("edge_cnn", xs[0])
        _wait_for(lambda: inj.count("edge_cnn") == 1, what="worker to claim")
        clock.advance(0.2)                     # past the execution deadline
        _wait_for(rescue_started.is_set, what="supervisor rescue to start")
        assert not t1.done                     # rescue is deliberately stuck

        # wake the zombie mid-rescue; it must pass through execute()'s
        # cleanup without finishing t1. A follow-up ticket proves the
        # worker made it back to its claim loop.
        clock.advance(10.0)
        t2 = server.submit("edge_cnn", xs[1])
        _wait_for(lambda: t2.done, what="worker to serve fresh traffic")
        assert t2.error is None and not t2.degraded
        assert not t1.done                     # the zombie did not touch it

        rescue_resume.set()                    # rescue finishes the job
        _wait_for(lambda: t1.done, what="rescue to settle the ticket")
        assert t1.error is None and t1.degraded and t1.result is not None
        s = server.stats("edge_cnn")
        assert s["failures"] == {"deadline": 1}
        assert s["fallback_images"] == 1 and s["images"] == 1
    finally:
        rescue_resume.set()
        clock.advance(100.0)                   # free any residual stall
        server.stop(timeout=60.0)


# ---------------------------------------------------------------------------
# Canaried hot_swap and rollback
# ---------------------------------------------------------------------------

def test_canary_rejects_candidate_that_faults(spec):
    # the fault targets generation 1 — exactly the candidate's number — so
    # the live generation 0 keeps serving untouched before and after
    inj = FaultInjector([Fault("raise", net="edge_cnn", generation=1,
                               first=0, last=1)])
    server = OptimisedServer(max_batch=4, faults=inj, clock=FakeClock())
    server.register(_net(spec))
    cand = _net(spec)
    assert not server.hot_swap("edge_cnn", cand, canary=True)
    s = server.stats("edge_cnn")
    assert s["generation"] == 0 and s["canary_rejected"] == 1
    assert "canary failed" in s["last_canary"]
    assert s["failures"] == {"canary": 1}
    t = server.submit("edge_cnn", _requests(spec, 1)[0])
    server.pump()
    assert t.error is None and not t.degraded  # live generation unaffected
    # a clean candidate passes the same gate
    assert server.hot_swap("edge_cnn", cand, canary=True)
    assert server.stats("edge_cnn")["generation"] == 1


def test_canary_rejects_pathological_slowdown(spec):
    clock = FakeClock()
    slow = {}

    class PacedServer(OptimisedServer):
        def _run_plan(self, o, xs, weights):
            out = super()._run_plan(o, xs, weights)
            clock.advance(slow.get(id(o), 0.0) * xs.shape[0])
            return out

    server = PacedServer(max_batch=4, clock=clock, canary_slowdown=8.0)
    server.register(_net(spec, predicted=2e-3))   # baseline: predicted cost
    bad = _net(spec)
    slow[id(bad)] = 0.1                        # 50x the 2 ms baseline
    assert not server.hot_swap("edge_cnn", bad, canary=True)
    s = server.stats("edge_cnn")
    assert s["generation"] == 0 and "slowdown" in s["last_canary"]
    good = _net(spec)
    assert server.hot_swap("edge_cnn", good, canary=True)
    assert server.stats("edge_cnn")["generation"] == 1


def test_poisoned_recalibration_is_rejected_within_one_canary_batch(spec):
    # the drift loop's recalibration path (hot_swap with expect_generation)
    # hands back a poisoned candidate: its executions corrupt output under
    # the candidate generation. The canary gate must veto it pre-commit.
    bad = _net(spec)
    inj = FaultInjector([Fault("corrupt", net="edge_cnn", generation=1)])
    server = OptimisedServer(max_batch=4, faults=inj, canary=True,
                             recalibrate=lambda opt: bad, clock=FakeClock())
    server.register(_net(spec))
    server._recalibration_worker("edge_cnn", 0)
    s = server.stats("edge_cnn")
    assert s["generation"] == 0 and s["recalibrations"] == 0
    assert s["canary_rejected"] == 1
    t = server.submit("edge_cnn", _requests(spec, 1)[0])
    server.pump()
    assert t.error is None and not t.degraded  # serving never saw the poison


def test_auto_rollback_reverts_never_succeeded_generation(spec):
    inj = FaultInjector([Fault("raise", net="edge_cnn", generation=1)])
    server = OptimisedServer(max_batch=4, faults=inj, auto_rollback=2,
                             clock=FakeClock())
    server.register(_net(spec))
    xs = _requests(spec, 3, seed=5)
    t0 = server.submit("edge_cnn", xs[0]);  server.pump()
    assert not t0.degraded                     # generation 0 proven
    assert server.hot_swap("edge_cnn", _net(spec))      # -> generation 1
    t1 = server.submit("edge_cnn", xs[1]);  server.pump()
    assert server.stats("edge_cnn")["generation"] == 1  # one strike: held
    t2 = server.submit("edge_cnn", xs[2]);  server.pump()
    s = server.stats("edge_cnn")
    assert s["generation"] == 2 and s["rollbacks"] == 1  # reverted
    assert s["failures"]["rollback"] == 1 and s["failures"]["fault"] == 2
    assert t1.degraded and t2.degraded         # rescued while it failed
    # the restored assignment serves cleanly (fault matched generation 1)
    t3 = server.submit("edge_cnn", xs[0]);  server.pump()
    assert t3.error is None and not t3.degraded


def test_manual_rollback_ring_is_bounded(spec):
    server = OptimisedServer(max_batch=4, rollback_history=2,
                             clock=FakeClock())
    server.register(_net(spec))
    for _ in range(4):
        assert server.hot_swap("edge_cnn", _net(spec))
    assert server.stats("edge_cnn")["generation"] == 4
    assert server.rollback("edge_cnn") and server.rollback("edge_cnn")
    assert not server.rollback("edge_cnn")     # ring depth 2: history spent
    s = server.stats("edge_cnn")
    assert s["rollbacks"] == 2 and s["generation"] == 6


# ---------------------------------------------------------------------------
# Poisoned measurement rig (SimulatedPlatform profile hook)
# ---------------------------------------------------------------------------

def test_simulated_platform_profile_faults():
    inj = FaultInjector([
        Fault("corrupt", net="profile:arm", factor=100.0, first=0, last=1),
        Fault("raise", net="profile:arm", first=1, last=2)])
    from repro.profiler import pools
    clean = SimulatedPlatform("arm", noisy=False)
    poisoned = SimulatedPlatform("arm", noisy=False, faults=inj)
    cfgs = np.asarray(pools.config_pool()[:3])
    np.testing.assert_allclose(poisoned.profile(cfgs),
                               clean.profile(cfgs) * 100.0, rtol=1e-12)
    with pytest.raises(FaultError):
        poisoned.profile(cfgs)                 # the rig itself fails
    assert np.isfinite(poisoned.profile_dlt(
        np.asarray([[16, 32]]))).any()         # index 2: plan exhausted


# ---------------------------------------------------------------------------
# Chaos soak: raise + hang + slowdown on one backend of a routed pair
# ---------------------------------------------------------------------------

def test_chaos_soak_availability(spec):
    """One sustained run against a seeded fault plan poisoning backend a of
    a two-backend route: 3 dispatches raise (twice each — retry included),
    the first half-open probe hangs past the execution deadline, the second
    stalls past it after running; the third probe is clean and closes the
    breaker. Asserts the availability contract: zero lost tickets, zero
    duplicated tickets (exact accounting identity), 100% of accepted tickets
    served (primary, spill, or degraded fallback — the ≥99% CI gate with no
    slack needed), breaker opened and recovered via probing, hung workers
    replaced and their zombies drained."""
    from repro.primitives.executor import make_weights
    weights = make_weights(spec)
    imgs = _requests(spec, 4, seed=42)

    # warm the global plan cache so healthy dispatches never pay jit compile
    # against the execution deadline
    warm = OptimisedServer(max_batch=4)
    warm.register(_net(spec), weights=weights)
    for b in (1, 2, 4):
        warm.serve("edge_cnn", imgs[:b])

    inj = FaultInjector([
        Fault("raise", net="edge_cnn#a", first=0, last=6),
        Fault("hang", net="edge_cnn#a", first=6, last=7, seconds=0.75),
        Fault("slowdown", net="edge_cnn#a", first=7, last=8, seconds=0.3),
    ])
    server = OptimisedServer(
        max_batch=4, workers=2, max_wait_ms=0.0, queue_depth=10_000,
        exec_deadline_ms=60.0, breaker_failures=3, breaker_cooldown_ms=120.0,
        faults=inj)
    # a predicts far cheaper: preferred whenever its breaker allows, so the
    # fault schedule is hit deterministically; b is the healthy spill target
    server.register(_net(spec, predicted=1e-6), weights=weights, backend="a")
    server.register(_net(spec, predicted=1e-3), weights=weights, backend="b")

    tickets = []
    try:
        # closed-loop bursts until backend a's breaker has tripped AND
        # recovered through a successful probe (bounded by wall-clock)
        deadline = time.time() + 90.0
        while time.time() < deadline:
            burst = [server.submit("edge_cnn", imgs[len(tickets) % 4])
                     for _ in range(2)]
            tickets.extend(burst)
            for t in burst:
                assert t.wait(30.0), "lost ticket: never finished"
            br = server.stats("edge_cnn")["backends"]["a"]["breaker"]
            if br["closes"] >= 1 and br["state"] == "closed":
                break
            time.sleep(0.01)
        for _ in range(5):                     # post-recovery clean traffic
            burst = [server.submit("edge_cnn", imgs[len(tickets) % 4])
                     for _ in range(2)]
            tickets.extend(burst)
            for t in burst:
                assert t.wait(30.0)
    finally:
        server.stop(timeout=60.0)

    # -- the full injected fault schedule actually ran ---------------------
    kinds = {k for (_net_, _g, _i, k) in inj.injected}
    assert kinds == {"raise", "hang", "slowdown"}, inj.injected

    # -- zero lost tickets -------------------------------------------------
    assert tickets and all(t.done for t in tickets)
    assert not any(t.rejected for t in tickets)
    failed = [t for t in tickets if t.error is not None]
    served = [t for t in tickets if t.result is not None]
    assert len(failed) + len(served) == len(tickets)

    # -- availability: ≥99% of accepted tickets served ---------------------
    availability = len(served) / len(tickets)
    assert availability >= 0.99, f"availability {availability:.4f}"
    assert not failed                          # fallback rescued everything

    # -- zero duplicated tickets: exact accounting identity ----------------
    s = server.stats("edge_cnn")
    assert s["images"] + s["fallback_images"] == len(served)
    assert s["failed_tickets"] == len(failed)

    # -- breaker opened, spilled, and recovered via half-open probes -------
    ba = s["backends"]["a"]["breaker"]
    assert ba["opens"] >= 2 and ba["closes"] >= 1    # trip + failed probes,
    assert ba["state"] == "closed"                   # then a clean probe
    assert s["backends"]["b"]["images"] > 0          # spill served traffic
    assert s["backends"]["a"]["images"] > 0          # a recovered and served
    led = s["backends"]["a"]["failures"]
    assert led.get("fault", 0) >= 3 and led.get("deadline", 0) >= 2

    # -- hung workers were replaced; zombies drained -----------------------
    assert server._pool.restarts >= 2
    _wait_for(lambda: server._pool.zombies == 0, timeout=60.0,
              what="soak zombies to drain")
    # no spurious generation churn: both backends still on generation 0
    assert all(b["generation"] == 0 and b["rollbacks"] == 0
               for b in s["backends"].values())


# ---------------------------------------------------------------------------
# The safe plan itself
# ---------------------------------------------------------------------------

def test_safe_assignment_uses_reference_primitives_only(spec):
    from repro.models.cnn_zoo import ConvLayer
    asg = safe_assignment(spec)
    for i, node in enumerate(spec.nodes):
        if isinstance(node, ConvLayer):
            assert asg[i] == ("conv-1x1-gemm-ab-ki" if node.f == 1
                              else "direct-sum2d")
        else:
            assert asg[i] == "chw"


def test_canary_gate_counts_real_rows_not_pad(spec):
    """Regression: a non-pow2 ``canary_batch`` pads to the next pow2 bucket,
    and per-image cost must divide by the REAL row count. Dividing by the
    padded bucket shrank per-image cost by pad/bucket — here 3/4 — waving
    through candidates that are past the slowdown gate."""
    clock = FakeClock()
    slow = {}

    class PacedServer(OptimisedServer):
        def _run_plan(self, o, xs, weights):
            out = super()._run_plan(o, xs, weights)
            clock.advance(slow.get(id(o), 0.0) * xs.shape[0])
            return out

    server = PacedServer(max_batch=4, clock=clock, canary_batch=3,
                         canary_slowdown=8.0)
    server.register(_net(spec, predicted=2e-3))    # gate: 16 ms/img
    bad = _net(spec)
    # the canary serves 3 real rows padded to 4: 13 ms/row * 4 rows over
    # 3 real images = 17.3 ms/img > gate — but over the padded 4 it would
    # be 13 ms/img and (wrongly) pass
    slow[id(bad)] = 13e-3
    assert not server.hot_swap("edge_cnn", bad, canary=True)
    s = server.stats("edge_cnn")
    assert s["generation"] == 0 and "slowdown" in s["last_canary"]
    # a genuinely acceptable candidate still passes at the same settings
    ok = _net(spec)
    slow[id(ok)] = 2e-3                            # 2.7 ms/img, well under
    assert server.hot_swap("edge_cnn", ok, canary=True)
    assert server.stats("edge_cnn")["generation"] == 1
