"""Process-level serving front end (DESIGN.md §12): the shared-memory slab
pool (alloc/free ring, generation guards, concurrent producers), the
pre-assembled ``BatchGroup`` dispatch path through the serving core (byte
equivalence zero-copy vs copy, fault contracts, slab recycling), and the
multi-process ``ProcessFrontend`` end to end (spawn intake processes,
ingest round-trip, drive-mode accounting).

Deterministic tests carry the required coverage; the @given variants widen
the same invariants when ``hypothesis`` is installed and skip otherwise.
"""
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.models import cnn_zoo
from repro.primitives.plan import heuristic_assignment
from repro.service import (Fault, FaultInjector, OptimisedNetwork,
                           OptimisedServer, SlabPool)


@pytest.fixture(scope="module")
def spec():
    return cnn_zoo.get("edge_cnn")


def _net(spec, *, predicted=2e-3):
    return OptimisedNetwork.from_assignment(spec, heuristic_assignment(spec),
                                            predicted_cost_s=predicted)


def _requests(spec, n, seed=0):
    n0 = spec.nodes[0]
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n0.c, n0.im, n0.im)).astype(np.float32)


# ---------------------------------------------------------------------------
# Slab pool (pure, no server)
# ---------------------------------------------------------------------------

def test_slab_pool_alloc_free_roundtrip():
    pool = SlabPool((3, 4, 4), max_batch=8, slots=3)
    try:
        assert pool.buckets == [1, 2, 4, 8]
        h = pool.alloc(5)                      # rounds up the pow2 ladder
        assert h.bucket == 8
        v = pool.view(h)
        assert v.shape == (8, 3, 4, 4) and v.dtype == np.float32
        v[:] = 2.5
        assert (pool.view(h, rows=3) == 2.5).all()
        assert pool.available(8) == 2
        pool.free(h)
        assert pool.available(8) == 3
        # buckets are independent rings
        assert pool.available(1) == 3 and pool.available(4) == 3
    finally:
        pool.close()


def test_slab_pool_exhaustion_backpressure_and_refill():
    pool = SlabPool((2, 2, 2), max_batch=4, slots=2)
    try:
        a, b = pool.alloc(4), pool.alloc(4)
        assert a is not None and b is not None and a.slot != b.slot
        assert pool.alloc(4) is None           # ring empty: backpressure
        pool.free(a)
        c = pool.alloc(4)                      # refilled by the free
        assert c is not None and c.generation == a.generation + 1
        pool.free(b)
        pool.free(c)
        assert pool.available(4) == 2
    finally:
        pool.close()


def test_slab_pool_generation_guards_double_free_and_stale_view():
    pool = SlabPool((2, 2, 2), max_batch=2, slots=2)
    try:
        h = pool.alloc(2)
        pool.view(h)[:] = 1.0
        pool.free(h)
        with pytest.raises(ValueError):        # double free
            pool.free(h)
        with pytest.raises(ValueError):        # use-after-free
            pool.view(h)
        # the recycled slot is a NEW allocation: stale handle stays dead
        both = [pool.alloc(2), pool.alloc(2)]   # FIFO ring: drain it whole
        h2 = next(x for x in both if x.slot == h.slot)
        assert h2.generation > h.generation
        with pytest.raises(ValueError):
            pool.view(h)
        for x in both:
            pool.free(x)
    finally:
        pool.close()


def test_slab_pool_no_aliasing_across_generations():
    """Payloads written through one generation never leak into another:
    every live handle owns disjoint memory, and recycling bumps the
    generation so the old handle cannot read the new tenant's rows."""
    pool = SlabPool((1, 2, 2), max_batch=2, slots=4)
    try:
        live = {}
        for round_ in range(3):
            handles = [pool.alloc(2) for _ in range(4)]
            assert all(h is not None for h in handles)
            assert len({h.slot for h in handles}) == 4    # disjoint slots
            for i, h in enumerate(handles):
                pool.view(h)[:] = round_ * 10.0 + i
                live[(h.slot, h.generation)] = round_ * 10.0 + i
            for h in handles:
                assert (pool.view(h) == live[(h.slot, h.generation)]).all()
                pool.free(h)
    finally:
        pool.close()


def test_slab_pool_concurrent_producers():
    """N producer threads alloc/write/verify/free in a loop against one
    pool: no slab is ever handed to two producers at once (each verifies
    its own tag before freeing), and the ring is whole afterwards."""
    pool = SlabPool((2, 3, 3), max_batch=4, slots=4)
    errors = []

    def producer(tid):
        rng = np.random.default_rng(tid)
        try:
            for it in range(120):
                bucket = int(rng.choice([1, 2, 4]))
                h = pool.alloc(bucket)
                if h is None:
                    continue                   # transient exhaustion: fine
                tag = tid * 1000.0 + it
                v = pool.view(h)
                v[:] = tag
                if not (pool.view(h) == tag).all():
                    errors.append(f"aliased slab {h} (producer {tid})")
                pool.free(h)
        except Exception as e:                 # pragma: no cover
            errors.append(f"producer {tid}: {e!r}")

    threads = [threading.Thread(target=producer, args=(i,)) for i in range(4)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        assert not errors, errors
        for b in (1, 2, 4):
            assert pool.available(b) == 4      # every slab returned
    finally:
        pool.close()


def test_slab_pool_attach_shares_bytes_and_never_unlinks():
    pool = SlabPool((2, 2, 2), max_batch=2, slots=2)
    try:
        other = SlabPool.attach(pool.spec(), pool.lock)
        h = other.alloc(2)
        other.view(h)[:] = 9.0
        assert (pool.view(h) == 9.0).all()     # same physical memory
        pool.free(h)                           # either side may free
        assert other.available(2) == 2
        other.close()                          # non-owner: unmap only
        h2 = pool.alloc(2)                     # owner's segments still live
        pool.view(h2)[:] = 1.0
        pool.free(h2)
    finally:
        pool.close()


@given(st.lists(st.integers(min_value=0, max_value=5), min_size=1,
                max_size=60))
@settings(max_examples=40, deadline=None)
def test_slab_pool_random_alloc_free_invariants(ops):
    """Property: under any interleaving of allocs and frees, live handles
    are unique per (bucket, slot), available() counts exactly the free
    slabs, and every alloc after a free sees a bumped generation."""
    pool = SlabPool((1, 2, 2), max_batch=4, slots=3)
    live = []
    try:
        for op in ops:
            if op < 3:                         # alloc from ladder rung `op`
                bucket = 1 << op
                h = pool.alloc(bucket)
                if h is None:
                    assert pool.available(bucket) == 0
                else:
                    assert all(not (h.bucket == o.bucket and h.slot == o.slot)
                               for o in live), "slab handed out twice"
                    live.append(h)
            elif live:                         # free the oldest live handle
                h = live.pop(0)
                pool.free(h)
                with pytest.raises(ValueError):
                    pool.view(h)
        for b in pool.buckets:
            used = sum(1 for h in live if h.bucket == b)
            assert pool.available(b) == 3 - used
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# Group dispatch through the serving core (pump mode, no processes)
# ---------------------------------------------------------------------------

def test_group_bytes_identical_zero_copy_vs_copy(spec):
    """The same payload served through the zero-copy slab path and through
    the per-ticket copy path must produce byte-identical results."""
    server = OptimisedServer(max_batch=8, latency_budget_ms=50.0)
    server.register(_net(spec))
    pool = SlabPool((spec.nodes[0].c, spec.nodes[0].im, spec.nodes[0].im),
                    max_batch=8, slots=2)
    try:
        xs = _requests(spec, 3, seed=7)
        h = pool.alloc(4)
        buf = pool.view(h)
        buf[:3] = xs
        buf[3] = xs[2]                         # pow2 pad: replicate last row
        freed = []
        g = server._submit_group("edge_cnn", pool.view(h), 3,
                                 handle=h,
                                 on_done=lambda ts, out:
                                 (pool.free(h), freed.append(out)))
        assert server.pump() == 1
        assert all(t.done and t.error is None for t in g.tickets)
        assert freed and freed[0] is not None and freed[0].shape[0] == 4
        assert pool.available(4) == 2          # slab recycled by on_done
        ref = server.serve("edge_cnn", xs)     # copy path: np.stack + pad
        for i, t in enumerate(g.tickets):
            np.testing.assert_array_equal(t.result, ref[i])
            np.testing.assert_array_equal(freed[0][i], ref[i])
    finally:
        pool.close()
        server.stop()


def test_group_rejection_fires_on_done_and_finishes_tickets(spec):
    server = OptimisedServer(max_batch=4, queue_depth=2)
    server.register(_net(spec))
    xs = _requests(spec, 4)
    fired = []
    # over depth: the whole group is rejected, on_done still fires
    g = server._submit_group("edge_cnn", xs, 4,
                             on_done=lambda ts, out: fired.append(out))
    assert all(t.done and t.rejected for t in g.tickets)
    assert fired == [None]
    assert server.stats("edge_cnn")["rejected"] == 4
    # unknown net: same contract
    g2 = server._submit_group("nope", xs, 2,
                              on_done=lambda ts, out: fired.append(out))
    assert all(t.done and t.rejected for t in g2.tickets)
    assert fired == [None, None]
    server.stop()


def test_group_dispatch_degrades_per_ticket_under_faults(spec):
    """A slab dispatch hit by injected faults degrades to the fallback plan
    per ticket — the shm path changes where the bytes live, not the
    fault-tolerance contract — and on_done reports per-row results."""
    inj = FaultInjector([Fault("raise", net="edge_cnn", first=0, last=2)])
    server = OptimisedServer(max_batch=4, faults=inj)
    server.register(_net(spec))
    xs = _requests(spec, 2, seed=3)
    outs = []
    g = server._submit_group("edge_cnn", xs, 2,
                             on_done=lambda ts, out: outs.append(out))
    assert server.pump() == 1
    assert outs == [None]                      # primary failed: per-row path
    assert all(t.done and t.degraded and t.result is not None
               for t in g.tickets)
    s = server.stats("edge_cnn")
    assert s["fallback_images"] == 2 and s["failed_tickets"] == 0
    # accounting identity: nothing lost, nothing duplicated
    assert s["images"] + s["fallback_images"] == 2
    server.stop()


def test_group_and_loose_tickets_coexist_fifo(spec):
    """Loose submits and slab groups share one queue; a pending group
    dispatches whole and first (its window already ran in the intake)."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=50.0)
    server.register(_net(spec))
    xs = _requests(spec, 3)
    t_loose = server.submit("edge_cnn", xs[0])
    g = server._submit_group("edge_cnn", xs[1:3], 2)
    assert len(server._nets["edge_cnn"].queue) == 3
    dispatches = server.pump()
    assert dispatches == 2                     # the group whole + the loose
    assert t_loose.done and t_loose.error is None
    assert all(t.done and t.error is None for t in g.tickets)
    server.stop()


# ---------------------------------------------------------------------------
# ProcessFrontend end to end (spawn processes + worker pool)
# ---------------------------------------------------------------------------

def test_process_frontend_ingest_and_drive(spec):
    """Full path: intake processes assemble slab batches, the dispatcher
    hands them to the worker pool by reference, results ship back per
    batch. ``ingest`` payloads round-trip byte-identically vs the thread
    front end; ``drive`` accounting loses nothing."""
    server = OptimisedServer(max_batch=8, latency_budget_ms=50.0, workers=2,
                             max_wait_ms=2.0, frontend_procs=2)
    server.register(_net(spec))
    xs = _requests(spec, 4, seed=11)
    server.serve("edge_cnn", xs)               # warm the bucket-4 plan
    fe = server.frontend()
    try:
        tickets = fe.ingest("edge_cnn", xs)
        for t in tickets:
            assert t.wait(120.0), "ingest ticket never finished"
            assert t.error is None, t.error
        ref = server.serve("edge_cnn", xs)
        for t, r in zip(tickets, ref):
            np.testing.assert_array_equal(t.result, r)

        agg = fe.drive("edge_cnn", 24, seed=5)
        assert agg["requests"] == 24
        assert (agg["served"] + agg["failed"] + agg["rejected"]
                == 24), f"lost tickets: {agg}"
        assert agg["served"] >= 23             # ≥99% under no faults: all
        assert agg["failed"] == 0 and agg["rejected"] == 0
        assert fe.fatal is None
    finally:
        server.stop()
    # frontend stop released every slab and child
    assert not fe._children or all(not p.is_alive() for p in fe._children)


def test_frontend_requires_worker_pool(spec):
    with pytest.raises(ValueError):
        OptimisedServer(workers=0, frontend_procs=2)
    server = OptimisedServer(workers=0)
    server.register(_net(spec))
    with pytest.raises(ValueError):
        server.frontend(2)
    server.stop()


def test_slab_group_chaos_soak(spec):
    """The fault-tolerance gates hold on the shm path: slab groups routed
    across two backends while one raises — zero lost tickets, zero
    duplicates (accounting identity), ≥99% served, every slab recycled."""
    # indices 1 and 2: one dispatch loses its attempt AND its retry, so the
    # fallback degradation path runs on a slab batch; everything else clean
    inj = FaultInjector([
        Fault("raise", net="edge_cnn#a", first=1, last=3),
    ])
    server = OptimisedServer(max_batch=4, workers=2, max_wait_ms=1.0,
                             faults=inj, breaker_failures=3)
    server.register(_net(spec, predicted=1e-6), backend="a")   # preferred
    server.register(_net(spec, predicted=1e-3), backend="b")
    pool = SlabPool((spec.nodes[0].c, spec.nodes[0].im, spec.nodes[0].im),
                    max_batch=4, slots=8)
    groups, done = [], threading.Event()
    outstanding = [0]
    lock = threading.Lock()

    def make_done(h):
        def on_done(tickets, out):
            pool.free(h)
            with lock:
                outstanding[0] -= 1
                if outstanding[0] == 0:
                    done.set()
        return on_done

    try:
        rng = np.random.default_rng(0)
        for i in range(12):
            rows = int(rng.integers(1, 5))
            deadline = time.perf_counter() + 60.0
            while (h := pool.alloc(4)) is None:    # backpressure: frees
                assert time.perf_counter() < deadline  # refill the ring
                time.sleep(0.001)
            buf = pool.view(h)
            buf[:rows] = _requests(spec, rows, seed=i)
            buf[rows:] = buf[rows - 1] if rows < 4 else buf[rows:]
            with lock:
                outstanding[0] += 1
            g = server._submit_group("edge_cnn", pool.view(h), rows,
                                     handle=h, on_done=make_done(h))
            groups.append(g)
        assert done.wait(120.0), "groups never settled"
        tickets = [t for g in groups for t in g.tickets]
        assert all(t.done for t in tickets), "lost tickets"
        served = [t for t in tickets if t.error is None]
        assert not any(t.rejected for t in tickets)
        assert len(served) / len(tickets) >= 0.99
        sa, sb = (server.stats(f"edge_cnn#{b}") for b in ("a", "b"))
        # exactly-once: per-backend served images equal the settled tickets
        assert (sa["images"] + sa["fallback_images"] + sb["images"]
                + sb["fallback_images"]) == len(served)
        assert sa["failed_dispatches"] >= 1          # faults really fired
        assert sa["fallback_images"] >= 1            # rescued, not dropped
        assert pool.available(4) == 8                # every slab recycled
    finally:
        server.stop()
        pool.close()
