"""Concurrent serving core (DESIGN.md §8): deadline-aware batch windows,
worker-pool dispatch with backpressure, drift-triggered recalibration, and
served-sample telemetry. Window semantics are tested against an injected
fake clock — no wall-clock sleeps, no flakiness on loaded CI hosts."""
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.models import cnn_zoo
from repro.service import (OptimisedNetwork, OptimisedServer, layer_profile,
                           make_recalibrator, optimise)
from repro.service.platforms import SimulatedPlatform
from repro.service.serving.drift import DriftMonitor, LayerProfile
from repro.service.serving.queues import NetQueue, Ticket


class FakeClock:
    """Deterministic injectable clock: time moves only when a test says so."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_net():
    spec = cnn_zoo.get("edge_cnn")
    from repro.primitives.plan import heuristic_assignment
    return OptimisedNetwork.from_assignment(spec, heuristic_assignment(spec),
                                            predicted_cost_s=2e-3)


@pytest.fixture(scope="module")
def optimised_net():
    """A genuinely optimised network (models attached) — required by the
    served-observation buffer, which attributes dispatch timings through the
    model's per-layer predictions."""
    platform = SimulatedPlatform("arm", max_triplets=16)
    return optimise("edge_cnn", platform, executable=True, max_iters=250)


def _requests(spec, n, seed=0):
    n0 = spec.nodes[0]
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n0.c, n0.im, n0.im)).astype(np.float32)


# ---------------------------------------------------------------------------
# Queue policy (pure, no threads)
# ---------------------------------------------------------------------------

def test_netqueue_window_semantics():
    q = NetQueue(depth=4, batch_cap=2, max_wait_s=10.0)
    assert not q.ready(0.0)                       # empty
    t1 = Ticket(net="n", x=np.zeros(1), submitted_s=100.0)
    assert q.push(t1)
    assert not q.ready(100.0)                     # 1 < cap, window open
    assert q.ready(110.0)                         # window expired
    assert q.next_deadline() == 110.0
    q.push(Ticket(net="n", x=np.zeros(1), submitted_s=101.0))
    assert q.ready(101.0)                         # cap reached
    assert q.ready(100.5, drain=True) and len(q.take(5)) == 2
    assert q.next_deadline() is None


def test_netqueue_depth_bound():
    q = NetQueue(depth=2, batch_cap=8, max_wait_s=1.0)
    a = [Ticket(net="n", x=np.zeros(1)) for _ in range(3)]
    assert [q.push(t) for t in a] == [True, True, False]


# ---------------------------------------------------------------------------
# Window semantics on the injected clock (no sleeps, no timing flakiness)
# ---------------------------------------------------------------------------

def test_lone_request_dispatched_within_max_wait(served_net):
    """A single queued request must not starve waiting for batch peers —
    and must not dispatch before its window expires. Driven entirely by the
    fake clock: ``pump(drain=False)`` only claims *ready* batches."""
    clock = FakeClock()
    server = OptimisedServer(max_batch=8, latency_budget_ms=1e9,
                             max_wait_ms=25.0, clock=clock)
    server.register(served_net)
    t = server.submit(served_net.net, _requests(served_net.spec, 1)[0])
    assert server.pump(drain=False) == 0          # window open: nothing ready
    clock.advance(0.024)
    assert server.pump(drain=False) == 0          # still inside the window
    clock.advance(0.0011)
    assert server.pump(drain=False) == 1          # window expired: dispatched
    assert t.done and t.error is None and t.result is not None
    assert t.queue_wait_s == pytest.approx(0.0251)


def test_full_batch_dispatches_before_window(served_net):
    """cap requests at once must dispatch on batch-full, not after max_wait."""
    clock = FakeClock()
    server = OptimisedServer(max_batch=2, latency_budget_ms=1e9,
                             max_wait_ms=10_000.0, clock=clock)
    server.register(served_net)
    ts = [server.submit(served_net.net, x)
          for x in _requests(served_net.spec, 2)]
    assert server.pump(drain=False) == 1          # full batch, clock at 0
    assert all(t.done and t.error is None for t in ts)


def test_deadline_caps_window_below_max_wait():
    """The effective window is the latency budget minus the predicted
    execution time of the pending batch — a huge static max_wait must not
    hold a request past the point where its budget could still be met."""
    q = NetQueue(depth=8, batch_cap=8, max_wait_s=1.0,
                 budget_s=0.010, predicted_s=0.002)
    q.push(Ticket(net="n", x=np.zeros(1), submitted_s=100.0))
    assert q.effective_wait_s() == pytest.approx(0.008)   # 10ms - 1*2ms
    assert not q.ready(100.0079)
    assert q.ready(100.0081)
    assert q.next_deadline() == pytest.approx(100.008)
    # a growing batch predicts longer execution: the window tightens
    for k in range(3):
        q.push(Ticket(net="n", x=np.zeros(1), submitted_s=100.0))
    assert q.effective_wait_s() == pytest.approx(0.002)   # 10ms - 4*2ms
    assert q.ready(100.003)
    # predicted execution alone above budget: dispatch immediately
    q.predicted_s = 0.004
    assert q.effective_wait_s() == 0.0
    assert q.ready(100.0)


def test_deadline_window_through_server(served_net):
    """Server-level: with a tight budget the request dispatches at
    budget − predicted, far before the static max_wait."""
    clock = FakeClock()
    server = OptimisedServer(max_batch=2, latency_budget_ms=10.0,
                             max_wait_ms=1000.0, clock=clock)
    server.register(served_net)                   # predicted_cost_s = 2e-3
    t = server.submit(served_net.net, _requests(served_net.spec, 1)[0])
    assert server.pump(drain=False) == 0
    clock.advance(0.0081)                         # > 10ms - 2ms
    assert server.pump(drain=False) == 1
    assert t.done and t.error is None
    assert server.stats(served_net.net)["effective_wait_ms"] == \
        pytest.approx(8.0)


def test_window_scale_shrinks_and_recovers():
    """Queueing p99 above the budget halves the window cap; p99 back under
    half the budget restores it (drift monitor owns the policy)."""
    from repro.service.serving import drift as drift_mod
    mon = DriftMonitor()
    mon.reset("net", 0)
    budget = 0.010
    scales = [mon.observe_wait("net", 0, 0.025, budget)
              for _ in range(drift_mod.WAIT_EVERY)]
    changed = [s for s in scales if s is not None]
    assert changed == [0.5]
    assert mon.window_scale("net") == 0.5
    # keep overrunning: shrinks again (bounded below)
    scales = [mon.observe_wait("net", 0, 0.025, budget)
              for _ in range(drift_mod.WAIT_EVERY)]
    assert [s for s in scales if s is not None] == [0.25]
    # queue drains: waits fall under budget/2 and the cap recovers
    recovered = []
    for _ in range(4 * drift_mod.WAIT_EVERY):
        s = mon.observe_wait("net", 0, 0.001, budget)
        if s is not None:
            recovered.append(s)
    assert recovered == [0.5, 1.0]
    # no budget: waits recorded, never adjusted
    assert mon.observe_wait("net", 0, 1.0, None) is None
    # stale generation (claim racing a hot_swap's reset): ignored
    assert mon.observe_wait("net", 7, 1.0, budget) is None
    assert mon.observe_wait("missing", 0, 1.0, budget) is None


def test_claim_applies_window_scale(served_net):
    """The server propagates the monitor's shrunk scale onto the queue at
    claim time, so the next window is genuinely shorter."""
    from repro.service.serving import drift as drift_mod
    clock = FakeClock()
    server = OptimisedServer(max_batch=1, latency_budget_ms=20.0,
                             max_wait_ms=16.0, clock=clock)
    server.register(served_net)
    state = server._nets[served_net.net]
    # every dispatch waited 2x the budget: after WAIT_EVERY claims the
    # monitor halves the cap and the claim path applies it to the queue
    for _ in range(drift_mod.WAIT_EVERY):
        server.submit(served_net.net, _requests(served_net.spec, 1)[0])
        clock.advance(0.040)
        assert server.pump(drain=False) == 1
    assert state.queue.window_scale == 0.5
    assert server.stats(served_net.net)["window_scale"] == 0.5
    q = NetQueue(depth=1, batch_cap=2, max_wait_s=0.016)
    q.window_scale = 0.5
    q.push(Ticket(net="n", x=np.zeros(1), submitted_s=0.0))
    assert q.effective_wait_s() == pytest.approx(0.008)


# ---------------------------------------------------------------------------
# NetQueue invariants under arbitrary interleavings (property-based)
# ---------------------------------------------------------------------------

_OPS = st.lists(
    st.one_of(st.tuples(st.just("push")),
              st.tuples(st.just("advance"),
                        st.floats(min_value=1e-4, max_value=0.03)),
              st.tuples(st.just("dispatch"))),
    min_size=1, max_size=60)


@settings(max_examples=80, deadline=None)
@given(ops=_OPS, depth=st.integers(1, 6), cap=st.integers(1, 6),
       wait_s=st.floats(1e-3, 0.05),
       budget_s=st.one_of(st.none(), st.floats(1e-3, 0.05)),
       predicted_s=st.floats(0.0, 0.01))
def test_netqueue_invariants(ops, depth, cap, wait_s, budget_s, predicted_s):
    """Under arbitrary submit/advance/dispatch interleavings: FIFO order is
    preserved, no accepted ticket is ever rejected (and vice versa), depth
    is never exceeded, and ``ready`` fires iff the batch is full or the
    oldest ticket's age reached the effective window."""
    q = NetQueue(depth=depth, batch_cap=cap, max_wait_s=wait_s,
                 budget_s=budget_s, predicted_s=predicted_s)
    now = 0.0
    accepted, rejected, dispatched = [], [], []

    def check():
        assert len(q) <= depth
        oldest = q._q[0].submitted_s if len(q) else None
        expect = (len(q) > 0
                  and (len(q) >= cap
                       or now - oldest >= q.effective_wait_s()))
        assert q.ready(now) == expect
        if len(q):
            assert q.next_deadline() == pytest.approx(
                oldest + q.effective_wait_s())
        else:
            assert q.next_deadline() is None

    for op in ops:
        if op[0] == "push":
            t = Ticket(net="n", x=np.zeros(1), submitted_s=now)
            (accepted if q.push(t) else rejected).append(t)
        elif op[0] == "advance":
            now += op[1]
        elif op[0] == "dispatch" and q.ready(now):
            dispatched.extend(q.take(cap))
        check()
    dispatched.extend(q.take(cap) if q.ready(now, drain=True) else [])
    # FIFO: dispatches are exactly a prefix of the accepted order
    assert [id(t) for t in dispatched] == \
        [id(t) for t in accepted[:len(dispatched)]]
    # accepted and rejected are disjoint; nothing is both dispatched and
    # rejected
    assert not (set(map(id, accepted)) & set(map(id, rejected)))
    assert not (set(map(id, dispatched)) & set(map(id, rejected)))


# ---------------------------------------------------------------------------
# Worker pool serving
# ---------------------------------------------------------------------------


def test_concurrent_submits_pad_and_slice_correctly(served_net):
    """Results delivered under concurrent submitters match the single-image
    plan: padded tail rows are sliced off, nothing is crossed between
    tickets."""
    import jax.numpy as jnp
    from repro.primitives.executor import make_weights
    from repro.primitives.plan import compile_plan

    weights = make_weights(served_net.spec)
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9,
                             workers=2, max_wait_ms=3.0)
    server.register(served_net, weights=weights)
    xs = _requests(served_net.spec, 9)
    tickets = [None] * len(xs)

    def submit(i):
        tickets[i] = server.submit(served_net.net, xs[i])

    try:
        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(len(xs))]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert all(t.wait(60.0) for t in tickets)
        assert all(t.error is None for t in tickets)
        for i, t in enumerate(tickets):
            plan = compile_plan(served_net.spec, served_net.assignment,
                                (1,) + xs[i].shape)
            want = np.asarray(plan(jnp.asarray(xs[i][None]),
                                   weights)[plan.sinks[-1]])[0]
            np.testing.assert_allclose(t.result, want, rtol=2e-4, atol=1e-5)
        s = server.stats(served_net.net)
        assert s["images"] == len(xs)
        assert s["queue_wait_p99_ms"] >= s["queue_wait_p50_ms"] >= 0.0
    finally:
        server.stop()


def test_backpressure_rejects_beyond_queue_depth(served_net):
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9,
                             queue_depth=2)          # workers=0: nothing drains
    server.register(served_net)
    ts = [server.submit(served_net.net, x)
          for x in _requests(served_net.spec, 5)]
    rejected = [t for t in ts if t.rejected]
    assert len(rejected) == 3
    assert all(t.done and "backpressure" in t.error for t in rejected)
    assert server.stats(served_net.net)["rejected"] == 3
    server.pump()                                    # queued ones still serve
    accepted = [t for t in ts if not t.rejected]
    assert all(t.done and t.error is None and t.result is not None
               for t in accepted)


def test_pump_mode_unchanged(served_net):
    """workers=0 keeps the synchronous contract: submit then pump drains."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9)
    server.register(served_net)
    ts = [server.submit(served_net.net, x)
          for x in _requests(served_net.spec, 7)]
    assert not any(t.done for t in ts)
    assert server.pump() == 2                        # 7 requests / cap 4 -> 4+3
    assert all(t.done and t.error is None for t in ts)
    assert server.stats(served_net.net)["padded"] == 1   # tail 3 padded to 4


def test_sync_serve_burst_larger_than_queue_depth(served_net):
    """In pump mode the serve() caller is the drain: a burst beyond
    queue_depth drains mid-submission instead of tripping backpressure."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9,
                             queue_depth=4)
    server.register(served_net)
    out = server.serve(served_net.net, _requests(served_net.spec, 11))
    assert len(out) == 11 and all(r is not None for r in out)
    assert server.stats(served_net.net)["images"] == 11


def test_reregister_rejects_stale_queue_not_strands_it(served_net):
    """Replacing a live registration must finish its queued tickets (as
    rejected), never leave them waiting forever."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9)
    server.register(served_net)
    ts = [server.submit(served_net.net, x)
          for x in _requests(served_net.spec, 3)]
    server.register(served_net)                      # e.g. redeploy same net
    assert all(t.done and t.rejected for t in ts)
    out = server.serve(served_net.net, _requests(served_net.spec, 2))
    assert all(r is not None for r in out)


# ---------------------------------------------------------------------------
# Drift monitor (unit: deterministic observations)
# ---------------------------------------------------------------------------

def test_drift_monitor_one_trigger_per_excursion():
    mon = DriftMonitor(threshold=1.5, alpha=0.5, calib_obs=2)
    mon.reset("net", 0)
    pred = 1e-3
    # calibration: observed runs 3x predicted (platform-to-host scale)
    assert not any(mon.observe("net", 0, 3e-3, pred) for _ in range(2))
    # steady state at the reference: no trigger
    assert not any(mon.observe("net", 0, 3e-3, pred) for _ in range(5))
    assert mon.ratio("net") == pytest.approx(1.0, abs=1e-6)
    # the platform drifts 4x slower: exactly ONE trigger for the excursion
    fired = [mon.observe("net", 0, 12e-3, pred) for _ in range(6)]
    assert fired.count(True) == 1 and fired[fired.index(True):].count(True) == 1
    assert mon.ratio("net") > 1.5
    # recovery below threshold/2 re-arms; a second excursion fires again
    for _ in range(12):
        mon.observe("net", 0, 3e-3, pred)
    assert mon.ratio("net") < 1.25
    fired2 = [mon.observe("net", 0, 12e-3, pred) for _ in range(6)]
    assert fired2.count(True) == 1
    assert mon.stats("net").triggers == 2


def test_drift_monitor_generation_and_garbage():
    mon = DriftMonitor(threshold=1.5, alpha=0.5, calib_obs=1)
    mon.reset("net", 0)
    assert not mon.observe("net", 1, 1e-3, 1e-3)     # stale generation
    assert not mon.observe("net", 0, float("nan"), 1e-3)
    assert not mon.observe("net", 0, 1e-3, 0.0)
    assert mon.ratio("missing") == 1.0
    with pytest.raises(ValueError):
        DriftMonitor(threshold=1.0)


def test_drift_monitor_clamps_single_spike():
    """One pathological dispatch (GC pause) must not fake a sustained drift."""
    mon = DriftMonitor(threshold=3.0, alpha=0.2, calib_obs=1)
    mon.reset("net", 0)
    mon.observe("net", 0, 1e-3, 1e-3)
    assert not mon.observe("net", 0, 1.0, 1e-3)      # 1000x spike, clamped
    for _ in range(3):
        assert not mon.observe("net", 0, 1e-3, 1e-3)


# ---------------------------------------------------------------------------
# Served-observation buffer (§8.5): the free recalibration sample
# ---------------------------------------------------------------------------

def _profile2() -> LayerProfile:
    feats = np.array([[16, 3, 32, 1, 3], [32, 16, 30, 1, 3]], np.float64)
    return LayerProfile(feats=feats, columns=("kn2row", "mec-col"),
                        predicted=np.array([1e-3, 2e-3]))


def test_observation_buffer_bounded_eviction():
    clock = FakeClock()
    mon = DriftMonitor(calib_obs=1, obs_cap=4, clock=clock)
    mon.reset("net", 0, layers=_profile2())
    for i in range(7):
        clock.advance(1.0)
        mon.observe("net", 0, 1e-3 * (i + 1), 1e-3, batch=2)
    obs = mon.observations("net")
    assert len(obs) == 4                           # bounded: oldest evicted
    assert [o.t for o in obs] == [4.0, 5.0, 6.0, 7.0]
    assert all(o.batch == 2 for o in obs)


def test_observation_buffer_gating():
    """Only attributable, in-generation, batch-carrying observations land in
    the buffer (the server passes ``batch`` only for cleanly-timed, i.e.
    non-compile, dispatches)."""
    mon = DriftMonitor(calib_obs=1)
    mon.reset("nolayers", 0)                       # no attribution profile
    mon.observe("nolayers", 0, 1e-3, 1e-3, batch=1)
    assert mon.observations("nolayers") == []
    mon.reset("net", 0, layers=_profile2())
    mon.observe("net", 0, 1e-3, 1e-3)              # drift-only (compile path)
    mon.observe("net", 1, 1e-3, 1e-3, batch=1)     # stale generation
    assert mon.observations("net") == [] and mon.coverage("net") == 0
    mon.observe("net", 0, 1e-3, 1e-3, batch=1)
    assert len(mon.observations("net")) == 1
    # one clean dispatch times the whole plan => covers every config
    assert mon.coverage("net") == 2
    mon.reset("net", 1, layers=_profile2())        # hot swap clears the buffer
    assert mon.observations("net") == []


def test_observation_coverage_counts_distinct_configs():
    feats = np.array([[16, 3, 32, 1, 3], [16, 3, 32, 1, 3],
                      [32, 16, 30, 1, 3]], np.float64)
    prof = LayerProfile(feats=feats, columns=("kn2row", "mec-col", "kn2row"),
                        predicted=np.array([1e-3, 1e-3, 2e-3]))
    mon = DriftMonitor(calib_obs=1)
    mon.reset("net", 0, layers=prof)
    mon.observe("net", 0, 1e-3, 1e-3, batch=1)
    assert mon.coverage("net") == 2                # two layers share a config


def test_compile_dispatch_excluded_from_buffer(optimised_net):
    """The first execution of each (generation, bucket) pays jit compile and
    must not enter the served-sample buffer."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9,
                             drift_calib_obs=1)
    server.register(optimised_net)
    spec = optimised_net.spec
    net = optimised_net.net
    server.serve(net, _requests(spec, 1))          # bucket-1 first: compile
    assert server.stats(net)["observed_dispatches"] == 0
    server.serve(net, _requests(spec, 1))
    assert server.stats(net)["observed_dispatches"] == 1
    server.serve(net, _requests(spec, 2))          # bucket-2 first: compile
    assert server.stats(net)["observed_dispatches"] == 1
    server.serve(net, _requests(spec, 2))
    assert server.stats(net)["observed_dispatches"] == 2


def test_served_sample_roundtrip_byte_stable(optimised_net, tmp_path):
    """observation buffer -> attributed PerfDataset is deterministic, and
    the dataset round-trips through save/load byte-identically."""
    from repro.profiler.dataset import PerfDataset
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9,
                             drift_calib_obs=1)
    server.register(optimised_net)
    spec, net = optimised_net.spec, optimised_net.net
    assert server.served_sample(net) is None       # nothing buffered yet
    for _ in range(3):
        server.serve(net, _requests(spec, 2))
        server.serve(net, _requests(spec, 1))
    ds1 = server.served_sample(net)
    ds2 = server.served_sample(net)
    assert ds1 is not None
    assert ds1.fingerprint() == ds2.fingerprint()  # same buffer, same bytes
    prof = layer_profile(optimised_net)
    n_cfg = len({tuple(r) for r in prof.feats.tolist()})
    assert ds1.n == 2 * n_cfg                      # buckets {1, 2} × configs
    assert np.isfinite(ds1.times).any(axis=1).all()   # every row measured
    assert set(ds1.columns) == set(prof.columns)
    path = str(tmp_path / "served.npz")
    ds1.save(path)
    back = PerfDataset.load(path)
    assert back.fingerprint() == ds1.fingerprint()
    np.testing.assert_array_equal(back.feats, ds1.feats)
    np.testing.assert_array_equal(back.times, ds1.times)


def test_compose_sample_tops_up_only_missing_configs():
    """Platform-side composition: configs the buffer covers are never
    re-profiled; fresh top-up measures only the remainder — and full
    coverage costs zero profiling."""
    from repro.profiler.dataset import PerfDataset
    platform = SimulatedPlatform("arm", max_triplets=12)
    pool = np.asarray(platform._sample_pool(), np.int64)
    covered = pool[:3]
    col = platform.columns[0]
    times = np.full((3, 1), 5e-4)
    served = PerfDataset(np.asarray(covered, np.float64), times, [col],
                         ["k", "c", "im", "s", "f"], platform.name)
    calls = []
    orig = platform.profile
    platform.profile = lambda cfgs: calls.append(np.atleast_2d(cfgs)) or orig(cfgs)
    sample, info = platform.compose_sample(served, n=5, seed=0)
    assert info == {"served_rows": 3, "fresh_rows": 2,
                    "served_fraction": 0.6, "covered_configs": 3,
                    "requested_n": 5}
    assert sample.n == 5 and sample.columns == platform.columns
    fresh_cfgs = {tuple(map(int, r)) for r in calls[0]}
    assert not fresh_cfgs & {tuple(map(int, r)) for r in covered}
    # served entries embedded at the right column, NaN elsewhere
    j = platform.columns.index(col)
    assert np.all(sample.times[:3, j] == 5e-4)
    other = np.delete(sample.times[:3], j, axis=1)
    assert np.all(~np.isfinite(other))
    # full coverage: zero profiling
    calls.clear()
    sample2, info2 = platform.compose_sample(served, n=3, seed=0)
    assert info2["fresh_rows"] == 0 and info2["served_fraction"] == 1.0
    assert sample2.n == 3 and not calls


# ---------------------------------------------------------------------------
# Drifted platform end to end: detect -> calibrate -> re-select -> hot_swap
# ---------------------------------------------------------------------------

class _DriftingServer(OptimisedServer):
    """Emulates the serving machine slowing down by the platform's
    ``time_scale``: plan execution is padded with a sleep proportional to the
    excess scale, so observed per-image latency rises exactly like it would
    on a genuinely slower host."""

    def _run_plan(self, opt, xs, weights):
        out = super()._run_plan(opt, xs, weights)
        scale = getattr(opt.platform, "time_scale", 1.0)
        if scale != 1.0:
            time.sleep(0.004 * xs.shape[0] * (scale - 1.0))
        return out


def test_drifted_platform_recalibrates_and_hot_swaps():
    platform = SimulatedPlatform("arm", max_triplets=16)
    opt = optimise("edge_cnn", platform, executable=True, max_iters=250)
    assert opt.predicted_cost_s > 0
    pred0 = opt.predicted_cost_s

    server = _DriftingServer(
        max_batch=4, latency_budget_ms=1e9, workers=2, max_wait_ms=3.0,
        drift_threshold=1.5, drift_alpha=0.5, drift_calib_obs=2,
        recalibrate=make_recalibrator(sample_n=12, mode="factor"))
    server.register(opt)
    spec = opt.spec
    try:
        # establish the reference ratio on the healthy platform
        for _ in range(4):
            server.serve(opt.net, _requests(spec, 4))
        assert server.stats(opt.net)["recalibrations"] == 0

        # the platform drifts: profiling AND execution get 4x slower
        platform.time_scale = 4.0
        platform.invalidate_datasets()

        tickets = []
        deadline = time.time() + 60.0
        while (server.stats(opt.net)["recalibrations"] == 0
               and time.time() < deadline):
            tickets += [server.submit(opt.net, x) for x in _requests(spec, 4)]
            for t in tickets[-4:]:
                t.wait(30.0)
        st = server.stats(opt.net)
        assert st["recalibrations"] == 1, f"no recalibration: {st}"
        assert st["generation"] == 1

        # the swap happened mid-stream: nothing dropped, nothing corrupted
        tickets += [server.submit(opt.net, x)
                    for x in _requests(spec, 8, seed=1)]
        assert all(t.wait(30.0) for t in tickets)
        assert all(t.done and t.error is None and t.result is not None
                   for t in tickets)

        # recalibration really went through platform.calibrate on fresh
        # (drifted) measurements: factor-corrected model, ~4x prediction
        with server._cond:
            new_opt = server._nets[opt.net].opt
        assert new_opt.models.prim.kind.startswith("factor-")
        assert 1.5 < new_opt.predicted_cost_s / pred0 < 12.0
        assert new_opt.assignment  # re-selected, plan-compilable assignment
        server.serve(opt.net, _requests(spec, 2, seed=2))
    finally:
        server.stop()
    # exactly one excursion -> exactly one recalibration
    assert server.stats(opt.net)["recalibrations"] == 1


# ---------------------------------------------------------------------------
# Failure paths (DESIGN.md §11): ticket expiry, batch failure, client deadline
# ---------------------------------------------------------------------------

def test_ticket_wait_timeout_expiry():
    t = Ticket(net="n", x=np.zeros(1))
    assert not t.wait(0.01)                    # expires: not finished
    assert not t.done
    assert t.finish(result=np.ones(1))
    assert t.wait(0.0) and t.done
    # first finish wins: a late settle attempt must not change the answer
    assert not t.finish(error="late loser")
    assert t.error is None and t.result is not None


def test_batch_failure_finishes_tickets_and_releases_inflight(served_net):
    class BrokenServer(OptimisedServer):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.broken = True

        def _run_plan(self, opt, xs, weights):
            if self.broken:
                raise RuntimeError("device wedged")
            return super()._run_plan(opt, xs, weights)

    server = BrokenServer(max_batch=8, fallback=False, clock=FakeClock())
    server.register(served_net)
    ts = [server.submit(served_net.net, x)
          for x in _requests(served_net.spec, 3)]
    server.pump()
    assert all(t.done and t.result is None for t in ts)
    assert all("device wedged" in t.error for t in ts)
    s = server.stats(served_net.net)
    assert s["failed_dispatches"] == 1 and s["retries"] == 1
    assert s["failed_tickets"] == 3
    assert s["inflight"] == 0                  # the claim settled: no leak
    server.broken = False                      # serving resumes afterwards
    t = server.submit(served_net.net, _requests(served_net.spec, 1)[0])
    server.pump()
    assert t.done and t.error is None and t.result is not None


def test_serve_raises_on_client_deadline(served_net):
    from repro.service.serving.faults import Fault, FaultInjector
    clock = FakeClock()
    inj = FaultInjector([Fault("hang", net=served_net.net, seconds=1e6)],
                        clock=clock)
    server = OptimisedServer(max_batch=8, workers=1, max_wait_ms=0.0,
                             faults=inj, clock=clock)
    server.register(served_net)
    try:
        with pytest.raises(TimeoutError):
            server.serve(served_net.net, _requests(served_net.spec, 1),
                         timeout=0.3)
    finally:
        clock.advance(2e6)                     # un-stick the hung dispatch
        server.stop(timeout=60.0)


# ---------------------------------------------------------------------------
# Batch-shape-aware cost model (DESIGN.md §12.3)
# ---------------------------------------------------------------------------

def test_bucket_scale_head_fit_monotone_interp_clamp():
    from repro.core.perfmodel import BucketScaleHead
    obs = []
    for _ in range(4):                         # nonlinear synthetic curve
        obs += [(1, 0.6), (4, 0.0), (16, -0.4)]
    head = BucketScaleHead.fit(obs, normalize=False)
    assert head.buckets() == [1, 4, 16]
    np.testing.assert_allclose(head.scale(1), np.exp(0.6))
    np.testing.assert_allclose(head.scale(16), np.exp(-0.4))
    assert head.scale(1) > head.scale(2) > head.scale(4) > head.scale(16)
    np.testing.assert_allclose(head.scale(2), np.exp(0.3))  # log2 interp
    assert head.scale(64) == head.scale(16)    # clamped extrapolation
    # count-weighted normalisation: the head carries shape only
    norm = BucketScaleHead.fit(obs, normalize=True)
    logs = np.log([norm.scale(b) for b in (1, 4, 16)])
    np.testing.assert_allclose(np.average(logs, weights=[4, 4, 4]), 0.0,
                               atol=1e-12)
    # min_obs drops noise buckets; nothing kept -> None
    assert BucketScaleHead.fit([(8, 0.1)], min_obs=2) is None
    assert BucketScaleHead.fit([]) is None


def test_netqueue_effective_wait_uses_bucket_scale():
    clock = FakeClock()
    q = NetQueue(depth=16, batch_cap=8, max_wait_s=20e-3, budget_s=10e-3,
                 predicted_s=1e-3)
    for i in range(2):
        q.push(Ticket(net="n", x=np.zeros(1), submitted_s=clock(),
                      clock=clock))
    assert q.effective_wait_s() == pytest.approx(8e-3)   # 10 - 1e-3*2
    q.bucket_scale = lambda b: 2.0             # super-linear bucket: window
    assert q.effective_wait_s() == pytest.approx(6e-3)   # 10 - 2e-3*2
    q.bucket_scale = lambda b: 10.0            # execution alone > budget
    assert q.effective_wait_s() == 0.0


def test_bucket_head_fitted_from_served_traffic(optimised_net):
    """Superlinear pacing: per-image cost grows with the pow2 bucket. After
    enough clean dispatches the server fits the scale head from the served
    buffer and threads it through predict_per_image and stats."""
    clock = FakeClock()
    pred = optimised_net.predicted_cost_s

    class PacedServer(OptimisedServer):
        def _run_plan(self, o, xs, weights):
            out = super()._run_plan(o, xs, weights)
            b = xs.shape[0]
            clock.advance(pred * (1.0 + np.log2(b)) * b)
            return out

    # a roomy budget keeps the initial cap at 4 so bucket-4 bursts
    # dispatch whole regardless of the model's predicted cost
    server = PacedServer(max_batch=4, clock=clock, drift_threshold=50.0,
                         latency_budget_ms=10000.0)
    server.register(optimised_net)
    net = optimised_net.net
    xs = _requests(optimised_net.spec, 4)
    for b in (1, 2, 4):                        # 1 warm + 3 clean each
        for _ in range(4):
            server.serve(net, xs[:b])
    s = server.stats(net)
    scales = s["bucket_scales"]
    assert scales is not None and set(scales) == {1, 2, 4}
    assert scales[4] > scales[2] > scales[1] > 0
    # the public prediction is bucket-conditioned through the head
    assert (server.predict_per_image(net, 4)
            > server.predict_per_image(net, 1) > 0)
    assert server.predict_per_image(net) == pytest.approx(
        server.predict_per_image(net, 1) / scales[1])
    # the served sample surfaces the batch-shape mix it was drawn from
    ds = server.served_sample(net)
    assert ds is not None and set(ds.served_info["buckets"]) == {1, 2, 4}
    server.stop()


def test_bucket_batch_cap_tightens_and_stats_surface(served_net):
    from repro.core.perfmodel import BucketScaleHead
    server = OptimisedServer(max_batch=32, latency_budget_ms=16.0)
    state = server.register(served_net)        # predicted 2 ms/img
    with server._cond:
        linear = server._bucket_batch_cap_locked(state)
    assert linear == 8                         # 16 ms / 2 ms, pow2 floor
    # super-linear head: scale(1)=1, scale(8)=4 (log2-interpolated between)
    state.bucket_head = BucketScaleHead.fit([(1, 0.0), (8, np.log(4.0))],
                                            normalize=False)
    with server._cond:
        cap = server._bucket_batch_cap_locked(state)
    # 2ms*scale(4)*4 = 20ms > 16ms; 2ms*scale(2)*2 ≈ 6.3ms fits
    assert cap == 2
    s = server.stats(served_net.net)
    assert s["latency_budget_ms"] == pytest.approx(16.0)
    assert s["predicted_per_image_ms"] > 0
    assert s["bucket_scales"] == {1: pytest.approx(1.0),
                                  8: pytest.approx(4.0)}
    server.stop()


def test_router_score_is_bucket_conditioned(served_net):
    from repro.core.perfmodel import BucketScaleHead
    server = OptimisedServer(max_batch=8)
    server.register(served_net, backend="a")
    server.register(served_net, backend="b")
    # same predicted cost, but backend a's bucket-1 dispatches are 3x:
    # the next request (bucket 1) must route to b
    server._nets["edge_cnn#a"].bucket_head = BucketScaleHead.fit(
        [(1, np.log(3.0))], normalize=False)
    t = server.submit("edge_cnn", _requests(served_net.spec, 1)[0])
    assert t.net == "edge_cnn#b"
    server.pump()
    assert t.done
    server.stop()


def test_pump_idle_backoff(served_net):
    """``pump(drain=False, idle_wait_s=...)`` parks on the condvar instead
    of hot-polling — and wakes early for a submit or an expiring window, so
    dispatch latency is unchanged."""
    server = OptimisedServer(max_batch=4, workers=0, max_wait_ms=40.0)
    server.register(served_net)
    xs = _requests(served_net.spec, 4)
    # empty queue: waits out the idle budget, no dispatch
    t0 = time.perf_counter()
    assert server.pump(drain=False, idle_wait_s=0.15) == 0
    assert time.perf_counter() - t0 >= 0.1
    # default remains the exact non-blocking poll
    t0 = time.perf_counter()
    assert server.pump(drain=False) == 0
    assert time.perf_counter() - t0 < 0.05
    # a pending window: sleeps to the deadline, then dispatches — far
    # before the idle budget
    server.submit(served_net.net, xs[0])
    t0 = time.perf_counter()
    assert server.pump(drain=False, idle_wait_s=30.0) == 1
    assert time.perf_counter() - t0 < 5.0
    # a submit while parked wakes the pump immediately
    def late_submit():
        time.sleep(0.2)
        for x in xs:                           # full batch: ready at once
            server.submit(served_net.net, x)
    th = threading.Thread(target=late_submit)
    th.start()
    t0 = time.perf_counter()
    assert server.pump(drain=False, idle_wait_s=30.0) == 1
    assert time.perf_counter() - t0 < 10.0
    th.join()
    server.stop()
