"""Concurrent serving core (DESIGN.md §8): timed batch windows, worker-pool
dispatch with backpressure, and drift-triggered recalibration."""
import threading
import time

import numpy as np
import pytest

from repro.models import cnn_zoo
from repro.service import (OptimisedNetwork, OptimisedServer, make_recalibrator,
                           optimise)
from repro.service.platforms import SimulatedPlatform
from repro.service.serving.drift import DriftMonitor
from repro.service.serving.queues import NetQueue, Ticket


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_net():
    spec = cnn_zoo.get("edge_cnn")
    from repro.primitives.plan import heuristic_assignment
    return OptimisedNetwork.from_assignment(spec, heuristic_assignment(spec),
                                            predicted_cost_s=2e-3)


def _requests(spec, n, seed=0):
    n0 = spec.nodes[0]
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n0.c, n0.im, n0.im)).astype(np.float32)


# ---------------------------------------------------------------------------
# Queue policy (pure, no threads)
# ---------------------------------------------------------------------------

def test_netqueue_window_semantics():
    q = NetQueue(depth=4, batch_cap=2, max_wait_s=10.0)
    assert not q.ready(0.0)                       # empty
    t1 = Ticket(net="n", x=np.zeros(1), submitted_s=100.0)
    assert q.push(t1)
    assert not q.ready(100.0)                     # 1 < cap, window open
    assert q.ready(110.0)                         # window expired
    assert q.next_deadline() == 110.0
    q.push(Ticket(net="n", x=np.zeros(1), submitted_s=101.0))
    assert q.ready(101.0)                         # cap reached
    assert q.ready(100.5, drain=True) and len(q.take(5)) == 2
    assert q.next_deadline() is None


def test_netqueue_depth_bound():
    q = NetQueue(depth=2, batch_cap=8, max_wait_s=1.0)
    a = [Ticket(net="n", x=np.zeros(1)) for _ in range(3)]
    assert [q.push(t) for t in a] == [True, True, False]


# ---------------------------------------------------------------------------
# Worker pool serving
# ---------------------------------------------------------------------------

def test_lone_request_dispatched_within_max_wait(served_net):
    """A single queued request must not starve waiting for batch peers."""
    server = OptimisedServer(max_batch=8, latency_budget_ms=1e9,
                             workers=1, max_wait_ms=25.0)
    server.register(served_net)
    try:
        server.serve(served_net.net, _requests(served_net.spec, 1))  # warm b=1
        t = server.submit(served_net.net, _requests(served_net.spec, 1)[0])
        assert t.wait(10.0) and t.error is None
        # claimed by window expiry, not by a full batch: the wait must be at
        # least ~max_wait but far below the no-window forever-starve
        assert 0.015 <= t.queue_wait_s < 5.0
    finally:
        server.stop()


def test_full_batch_dispatches_before_window(served_net):
    """cap requests at once must dispatch on batch-full, not after max_wait."""
    server = OptimisedServer(max_batch=2, latency_budget_ms=1e9,
                             workers=1, max_wait_ms=10_000.0)
    server.register(served_net)
    try:
        server.serve(served_net.net, _requests(served_net.spec, 2))  # warm b=2
        t0 = time.perf_counter()
        out = server.serve(served_net.net, _requests(served_net.spec, 2))
        assert len(out) == 2
        assert time.perf_counter() - t0 < 5.0    # << the 10s window
    finally:
        server.stop()


def test_concurrent_submits_pad_and_slice_correctly(served_net):
    """Results delivered under concurrent submitters match the single-image
    plan: padded tail rows are sliced off, nothing is crossed between
    tickets."""
    import jax.numpy as jnp
    from repro.primitives.executor import make_weights
    from repro.primitives.plan import compile_plan

    weights = make_weights(served_net.spec)
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9,
                             workers=2, max_wait_ms=3.0)
    server.register(served_net, weights=weights)
    xs = _requests(served_net.spec, 9)
    tickets = [None] * len(xs)

    def submit(i):
        tickets[i] = server.submit(served_net.net, xs[i])

    try:
        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(len(xs))]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert all(t.wait(60.0) for t in tickets)
        assert all(t.error is None for t in tickets)
        for i, t in enumerate(tickets):
            plan = compile_plan(served_net.spec, served_net.assignment,
                                (1,) + xs[i].shape)
            want = np.asarray(plan(jnp.asarray(xs[i][None]),
                                   weights)[plan.sinks[-1]])[0]
            np.testing.assert_allclose(t.result, want, rtol=2e-4, atol=1e-5)
        s = server.stats(served_net.net)
        assert s["images"] == len(xs)
        assert s["queue_wait_p99_ms"] >= s["queue_wait_p50_ms"] >= 0.0
    finally:
        server.stop()


def test_backpressure_rejects_beyond_queue_depth(served_net):
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9,
                             queue_depth=2)          # workers=0: nothing drains
    server.register(served_net)
    ts = [server.submit(served_net.net, x)
          for x in _requests(served_net.spec, 5)]
    rejected = [t for t in ts if t.rejected]
    assert len(rejected) == 3
    assert all(t.done and "backpressure" in t.error for t in rejected)
    assert server.stats(served_net.net)["rejected"] == 3
    server.pump()                                    # queued ones still serve
    accepted = [t for t in ts if not t.rejected]
    assert all(t.done and t.error is None and t.result is not None
               for t in accepted)


def test_pump_mode_unchanged(served_net):
    """workers=0 keeps the synchronous contract: submit then pump drains."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9)
    server.register(served_net)
    ts = [server.submit(served_net.net, x)
          for x in _requests(served_net.spec, 7)]
    assert not any(t.done for t in ts)
    assert server.pump() == 2                        # 7 requests / cap 4 -> 4+3
    assert all(t.done and t.error is None for t in ts)
    assert server.stats(served_net.net)["padded"] == 1   # tail 3 padded to 4


def test_sync_serve_burst_larger_than_queue_depth(served_net):
    """In pump mode the serve() caller is the drain: a burst beyond
    queue_depth drains mid-submission instead of tripping backpressure."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9,
                             queue_depth=4)
    server.register(served_net)
    out = server.serve(served_net.net, _requests(served_net.spec, 11))
    assert len(out) == 11 and all(r is not None for r in out)
    assert server.stats(served_net.net)["images"] == 11


def test_reregister_rejects_stale_queue_not_strands_it(served_net):
    """Replacing a live registration must finish its queued tickets (as
    rejected), never leave them waiting forever."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9)
    server.register(served_net)
    ts = [server.submit(served_net.net, x)
          for x in _requests(served_net.spec, 3)]
    server.register(served_net)                      # e.g. redeploy same net
    assert all(t.done and t.rejected for t in ts)
    out = server.serve(served_net.net, _requests(served_net.spec, 2))
    assert all(r is not None for r in out)


# ---------------------------------------------------------------------------
# Drift monitor (unit: deterministic observations)
# ---------------------------------------------------------------------------

def test_drift_monitor_one_trigger_per_excursion():
    mon = DriftMonitor(threshold=1.5, alpha=0.5, calib_obs=2)
    mon.reset("net", 0)
    pred = 1e-3
    # calibration: observed runs 3x predicted (platform-to-host scale)
    assert not any(mon.observe("net", 0, 3e-3, pred) for _ in range(2))
    # steady state at the reference: no trigger
    assert not any(mon.observe("net", 0, 3e-3, pred) for _ in range(5))
    assert mon.ratio("net") == pytest.approx(1.0, abs=1e-6)
    # the platform drifts 4x slower: exactly ONE trigger for the excursion
    fired = [mon.observe("net", 0, 12e-3, pred) for _ in range(6)]
    assert fired.count(True) == 1 and fired[fired.index(True):].count(True) == 1
    assert mon.ratio("net") > 1.5
    # recovery below threshold/2 re-arms; a second excursion fires again
    for _ in range(12):
        mon.observe("net", 0, 3e-3, pred)
    assert mon.ratio("net") < 1.25
    fired2 = [mon.observe("net", 0, 12e-3, pred) for _ in range(6)]
    assert fired2.count(True) == 1
    assert mon.stats("net").triggers == 2


def test_drift_monitor_generation_and_garbage():
    mon = DriftMonitor(threshold=1.5, alpha=0.5, calib_obs=1)
    mon.reset("net", 0)
    assert not mon.observe("net", 1, 1e-3, 1e-3)     # stale generation
    assert not mon.observe("net", 0, float("nan"), 1e-3)
    assert not mon.observe("net", 0, 1e-3, 0.0)
    assert mon.ratio("missing") == 1.0
    with pytest.raises(ValueError):
        DriftMonitor(threshold=1.0)


def test_drift_monitor_clamps_single_spike():
    """One pathological dispatch (GC pause) must not fake a sustained drift."""
    mon = DriftMonitor(threshold=3.0, alpha=0.2, calib_obs=1)
    mon.reset("net", 0)
    mon.observe("net", 0, 1e-3, 1e-3)
    assert not mon.observe("net", 0, 1.0, 1e-3)      # 1000x spike, clamped
    for _ in range(3):
        assert not mon.observe("net", 0, 1e-3, 1e-3)


# ---------------------------------------------------------------------------
# Drifted platform end to end: detect -> calibrate -> re-select -> hot_swap
# ---------------------------------------------------------------------------

class _DriftingServer(OptimisedServer):
    """Emulates the serving machine slowing down by the platform's
    ``time_scale``: plan execution is padded with a sleep proportional to the
    excess scale, so observed per-image latency rises exactly like it would
    on a genuinely slower host."""

    def _run_plan(self, opt, xs, weights):
        out = super()._run_plan(opt, xs, weights)
        scale = getattr(opt.platform, "time_scale", 1.0)
        if scale != 1.0:
            time.sleep(0.004 * xs.shape[0] * (scale - 1.0))
        return out


def test_drifted_platform_recalibrates_and_hot_swaps():
    platform = SimulatedPlatform("arm", max_triplets=16)
    opt = optimise("edge_cnn", platform, executable=True, max_iters=250)
    assert opt.predicted_cost_s > 0
    pred0 = opt.predicted_cost_s

    server = _DriftingServer(
        max_batch=4, latency_budget_ms=1e9, workers=2, max_wait_ms=3.0,
        drift_threshold=1.5, drift_alpha=0.5, drift_calib_obs=2,
        recalibrate=make_recalibrator(sample_n=12, mode="factor"))
    server.register(opt)
    spec = opt.spec
    try:
        # establish the reference ratio on the healthy platform
        for _ in range(4):
            server.serve(opt.net, _requests(spec, 4))
        assert server.stats(opt.net)["recalibrations"] == 0

        # the platform drifts: profiling AND execution get 4x slower
        platform.time_scale = 4.0
        platform.invalidate_datasets()

        tickets = []
        deadline = time.time() + 60.0
        while (server.stats(opt.net)["recalibrations"] == 0
               and time.time() < deadline):
            tickets += [server.submit(opt.net, x) for x in _requests(spec, 4)]
            for t in tickets[-4:]:
                t.wait(30.0)
        st = server.stats(opt.net)
        assert st["recalibrations"] == 1, f"no recalibration: {st}"
        assert st["generation"] == 1

        # the swap happened mid-stream: nothing dropped, nothing corrupted
        tickets += [server.submit(opt.net, x)
                    for x in _requests(spec, 8, seed=1)]
        assert all(t.wait(30.0) for t in tickets)
        assert all(t.done and t.error is None and t.result is not None
                   for t in tickets)

        # recalibration really went through platform.calibrate on fresh
        # (drifted) measurements: factor-corrected model, ~4x prediction
        with server._cond:
            new_opt = server._nets[opt.net].opt
        assert new_opt.models.prim.kind.startswith("factor-")
        assert 1.5 < new_opt.predicted_cost_s / pred0 < 12.0
        assert new_opt.assignment  # re-selected, plan-compilable assignment
        server.serve(opt.net, _requests(spec, 2, seed=2))
    finally:
        server.stop()
    # exactly one excursion -> exactly one recalibration
    assert server.stats(opt.net)["recalibrations"] == 1
