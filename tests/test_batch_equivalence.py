"""Batch/scalar equivalence of the vectorised estimation path (DESIGN.md §2.4).

Three layers of guarantees:
  * the public scalar APIs (``primitive_time`` / ``dlt_time``) delegate to
    1×1 batches, so they are *bit-identical* to the batched matrices;
  * the batched models match the independent pre-vectorisation scalar
    reference (``_primitive_time_scalar`` / ``_dlt_time_scalar``) to float64
    round-off (the reference computes with ``math.*``, the batch with numpy
    ufuncs — identical operation order, last-ulp transcendental differences);
  * ``build_pbqp`` produces graphs identical to the seed's per-pair edge
    loops (node vectors and edge matrices compared exactly).
"""
import numpy as np
import pytest

from repro.core import pbqp
from repro.core.selection import (SimulatedProvider, _DLT_COLS, _edge_tensor,
                                  _in_layout, _node_choices, _out_layout,
                                  build_pbqp)
from repro.models import cnn_zoo
from repro.models.cnn_zoo import ConvLayer
from repro.primitives import layouts as L
from repro.primitives.conv import PRIMITIVE_NAMES, REGISTRY, compile_traits
from repro.profiler.simulators import (PLATFORMS, _dlt_time_scalar,
                                       _primitive_time_scalar, dlt_time,
                                       dlt_time_batch, primitive_time,
                                       primitive_time_batch)

_DLT_NI = [(s, d) for (s, d) in L.dlt_pairs() if s != d]


def _random_configs(rng, n):
    return np.stack([rng.integers(1, 512, n), rng.integers(1, 512, n),
                     rng.integers(7, 230, n), rng.choice([1, 2, 4], n),
                     rng.choice([1, 3, 5, 7, 9, 11], n)], axis=1)


@pytest.mark.parametrize("platform", sorted(PLATFORMS))
@pytest.mark.parametrize("noisy", [True, False])
def test_primitive_batch_matches_scalar_reference(platform, noisy):
    plat = PLATFORMS[platform]
    cfgs = _random_configs(np.random.default_rng(hash(platform) % 2**32), 30)
    batch = primitive_time_batch(plat, cfgs, noisy=noisy)
    ref = np.array([[_primitive_time_scalar(plat, REGISTRY[n], *map(int, cfg),
                                            noisy=noisy)
                     for n in PRIMITIVE_NAMES] for cfg in cfgs])
    # NaN pattern == applicability, everywhere
    np.testing.assert_array_equal(np.isnan(batch), np.isnan(ref))
    mask = ~np.isnan(ref)
    np.testing.assert_allclose(batch[mask], ref[mask], rtol=1e-12)
    assert (batch[mask] > 0).all()


@pytest.mark.parametrize("platform", sorted(PLATFORMS))
def test_primitive_scalar_api_is_bitwise_batch(platform):
    """The public scalar API must agree with the batch matrix *exactly*."""
    plat = PLATFORMS[platform]
    cfgs = _random_configs(np.random.default_rng(7), 12)
    for noisy in (True, False):
        batch = primitive_time_batch(plat, cfgs, noisy=noisy)
        for i, cfg in enumerate(cfgs):
            for j, name in enumerate(PRIMITIVE_NAMES):
                v = primitive_time(plat, REGISTRY[name], *map(int, cfg),
                                   noisy=noisy)
                b = batch[i, j]
                assert (np.isnan(v) and np.isnan(b)) or v == b, (name, cfg)


def test_primitive_batch_row_independence():
    """Batching must not couple cells: any sub-batch reproduces the full
    matrix bit-for-bit (catches broadcasting/indexing bugs)."""
    plat = PLATFORMS["intel"]
    cfgs = _random_configs(np.random.default_rng(3), 20)
    full = primitive_time_batch(plat, cfgs)
    for sl in (slice(0, 1), slice(3, 11), slice(7, 20, 4)):
        part = primitive_time_batch(plat, cfgs[sl])
        np.testing.assert_array_equal(part, full[sl])
    # column subsets too
    cols = ("kn2row", "mec-col", "winograd-4x4-3x3", "im2row-scan-ab-ik")
    sub = primitive_time_batch(plat, cfgs, columns=cols)
    idx = [PRIMITIVE_NAMES.index(c) for c in cols]
    np.testing.assert_array_equal(sub, full[:, idx])


@pytest.mark.parametrize("platform", sorted(PLATFORMS))
@pytest.mark.parametrize("noisy", [True, False])
def test_dlt_batch_matches_scalar(platform, noisy):
    plat = PLATFORMS[platform]
    rng = np.random.default_rng(11)
    pairs = np.stack([rng.integers(1, 512, 25), rng.integers(7, 230, 25)], axis=1)
    batch = dlt_time_batch(plat, pairs, noisy=noisy)
    ref = np.array([[_dlt_time_scalar(plat, s, d, int(c), int(im), noisy=noisy)
                     for (s, d) in _DLT_NI] for (c, im) in pairs])
    np.testing.assert_allclose(batch, ref, rtol=1e-12)
    # public scalar API: bitwise
    for i, (c, im) in enumerate(pairs[:5]):
        for j, (s, d) in enumerate(_DLT_NI):
            assert dlt_time(plat, s, d, int(c), int(im), noisy=noisy) == batch[i, j]
    assert dlt_time(plat, "chw", "chw", 64, 56) == 0.0


def test_noise_deterministic_and_lognormal_scale():
    plat = PLATFORMS["arm"]
    cfgs = _random_configs(np.random.default_rng(5), 10)
    a = primitive_time_batch(plat, cfgs)
    b = primitive_time_batch(plat, cfgs)
    np.testing.assert_array_equal(a, b)
    clean = primitive_time_batch(plat, cfgs, noisy=False)
    m = ~np.isnan(clean)
    ratio = a[m] / clean[m]
    # multiplicative noise: bounded and centred around 1 (σ = 6%)
    assert (ratio > 0.6).all() and (ratio < 1.6).all()
    assert abs(np.log(ratio).mean()) < 0.05


def test_applicability_mask_matches_registry():
    cfgs = _random_configs(np.random.default_rng(13), 40)
    tr = compile_traits(tuple(PRIMITIVE_NAMES))
    mask = tr.applicable_mask(cfgs[:, 0], cfgs[:, 1], cfgs[:, 2],
                              cfgs[:, 3], cfgs[:, 4])
    ref = np.array([[REGISTRY[n].applicable(*map(int, cfg))
                     for n in PRIMITIVE_NAMES] for cfg in cfgs])
    np.testing.assert_array_equal(mask, ref)


def _build_pbqp_reference(spec, provider):
    """The seed's scalar graph construction (per-pair Python edge loops)."""
    columns = list(provider.columns)
    convs = [(i, n) for i, n in enumerate(spec.nodes) if isinstance(n, ConvLayer)]
    configs = np.array([n.config for _, n in convs], np.float64)
    cost_mat = (provider.primitive_cost_matrix(configs)
                if len(convs) else np.zeros((0, len(columns))))
    pair_list = sorted({_edge_tensor(spec.nodes[u]) for (u, v) in spec.edges})
    pair_idx = {p: i for i, p in enumerate(pair_list)}
    dlt_mat = (provider.dlt_cost_matrix(np.array(pair_list, np.float64))
               if pair_list else np.zeros((0, len(_DLT_COLS))))
    dlt_col = {name: j for j, name in enumerate(_DLT_COLS)}

    def dlt(src, dst, c, im):
        if src == dst:
            return 0.0
        return float(max(dlt_mat[pair_idx[(c, im)], dlt_col[L.dlt_name(src, dst)]], 0.0))

    g = pbqp.PBQPGraph()
    conv_cost = {i: cost_mat[r] for r, (i, _) in enumerate(convs)}
    for i, node in enumerate(spec.nodes):
        choices = _node_choices(node, columns)
        if isinstance(node, ConvLayer):
            vec = np.maximum(np.where(np.isfinite(conv_cost[i]),
                                      conv_cost[i], np.inf), 0.0)
        else:
            vec = np.zeros(len(choices))
        g.add_node(i, vec, labels=choices)
    for (u, v) in spec.edges:
        nu, nv = spec.nodes[u], spec.nodes[v]
        cu, cv = _node_choices(nu, columns), _node_choices(nv, columns)
        c, im = _edge_tensor(nu)
        m = np.zeros((len(cu), len(cv)))
        for a, pa in enumerate(cu):
            for b, pb in enumerate(cv):
                m[a, b] = dlt(_out_layout(nu, pa), _in_layout(nv, pb), c, im)
        g.add_edge(u, v, m)
    return g


@pytest.mark.parametrize("net", ["alexnet", "squeezenet", "googlenet"])
def test_build_pbqp_identical_to_seed_loops(net):
    """Vectorised graph construction: identical node vectors and edge
    matrices to the per-(primitive pair) Python loops, chains and joins."""
    spec = cnn_zoo.get(net)
    provider = SimulatedProvider("intel")
    fast = build_pbqp(spec, provider)
    ref = _build_pbqp_reference(spec, provider)
    assert set(fast.costs) == set(ref.costs)
    for n in fast.costs:
        np.testing.assert_array_equal(fast.costs[n], ref.costs[n])
        assert fast.labels[n] == ref.labels[n]
        assert set(fast.adj[n]) == set(ref.adj[n])
        for v in fast.adj[n]:
            np.testing.assert_array_equal(fast.adj[n][v], ref.adj[n][v])


def test_build_pbqp_solution_unchanged():
    """Same graphs => same optimal assignment cost through the worklist
    solver as through brute evaluation of the returned assignment."""
    spec = cnn_zoo.get("alexnet")
    provider = SimulatedProvider("intel", noisy=False)
    g = build_pbqp(spec, provider)
    sol = pbqp.solve(g)
    assert sol.optimal
    assert np.isclose(pbqp.evaluate(g, sol.assignment), sol.cost)
