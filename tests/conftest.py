"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benches must see 1 device (the dry-run sets its own count in-process).
Distribution tests that need a host mesh spawn subprocesses."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
