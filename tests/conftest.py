"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benches must see 1 device (the dry-run sets its own count in-process).
Distribution tests that need a host mesh spawn subprocesses."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    """Surface hypothesis-stub skips as their own summary line: a local run
    without the real engine must say how many property tests it silently
    skipped, so local green != property-tested (README "Tests")."""
    import sys
    stub = sys.modules.get("hypothesis_stub")
    if stub is None or not getattr(stub, "STUBBED", None):
        return
    names = sorted(set(stub.STUBBED))
    terminalreporter.write_sep(
        "-", f"hypothesis stubbed: {len(names)} property test(s) skipped, "
             f"NOT run — install hypothesis for the real engine")
