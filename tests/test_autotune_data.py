"""TPU kernel autotune (§2.2), data pipeline, and simulator invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # property tests need the dev extra
    from hypothesis_stub import given, settings, st

from repro.configs import base as cb
from repro.core.autotune import analytic_cost, autotune_arch, matmul_sites, train_cost_model
from repro.data.lm import make_batch
from repro.kernels.matmul.ops import VARIANTS
from repro.primitives.conv import REGISTRY
from repro.profiler.simulators import PLATFORMS, dlt_time, primitive_time


def test_analytic_cost_sane():
    # bigger problems cost more; aligned tiles beat tiny tiles
    assert analytic_cost(4096, 4096, 4096, 128, 128, 128) > \
           analytic_cost(1024, 1024, 1024, 128, 128, 128)
    assert analytic_cost(4096, 4096, 4096, 128, 128, 128) < \
           analytic_cost(4096, 4096, 4096, 32, 32, 32) if (32, 32, 32) else True


def test_matmul_sites_every_arch():
    for arch in cb.ASSIGNED_ARCHS:
        sites = matmul_sites(cb.get(arch))
        assert sites, arch
        for (name, m, k, n) in sites:
            assert m > 0 and k > 0 and n > 0, (arch, name)


def test_autotune_never_worse_than_default():
    model = train_cost_model(max_iters=800)
    for arch in ("chatglm3_6b", "mixtral_8x7b", "mamba2_2_7b"):
        res = autotune_arch(cb.get(arch), model)
        assert res.predicted_s <= res.default_s * 1.01, arch
        assert res.predicted_s >= res.oracle_s * 0.999, arch


def test_data_pipeline_deterministic_and_shard_stable():
    cfg = cb.get("chatglm3_6b").reduced()
    a = make_batch(cfg, 4, 16, index=7, seed=3, host=0)
    b = make_batch(cfg, 4, 16, index=7, seed=3, host=0)
    c = make_batch(cfg, 4, 16, index=7, seed=3, host=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])      # restartable
    assert not np.array_equal(a["tokens"], c["tokens"])          # host-sharded
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(a["tokens"][:, 1:]),
                                  np.asarray(a["labels"][:, :-1]))


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 512), c=st.integers(1, 512), im=st.integers(7, 128),
       s=st.sampled_from([1, 2, 4]), f=st.sampled_from([1, 3, 5, 7]))
def test_simulator_invariants(k, c, im, s, f):
    """times positive where applicable; NaN exactly where inapplicable;
    deterministic (same key -> same noise)."""
    if f > im:
        return
    plat = PLATFORMS["intel"]
    for name in ("im2col-copy-ab-ki", "winograd-2x2-3x3", "conv-1x1-gemm-ab-ki",
                 "kn2row", "mec-col"):
        p = REGISTRY[name]
        t1 = primitive_time(plat, p, k, c, im, s, f)
        t2 = primitive_time(plat, p, k, c, im, s, f)
        if p.applicable(k, c, im, s, f):
            assert t1 > 0 and t1 == t2
        else:
            assert np.isnan(t1)


def test_simulator_platform_ordering():
    """Same primitive/config must be slower on the weaker platforms."""
    p = REGISTRY["im2col-copy-ab-ki"]
    cfgs = [(64, 64, 28, 1, 3), (256, 128, 14, 1, 3)]
    for cfg in cfgs:
        ti = primitive_time(PLATFORMS["intel"], p, *cfg, noisy=False)
        ta = primitive_time(PLATFORMS["amd"], p, *cfg, noisy=False)
        tr = primitive_time(PLATFORMS["arm"], p, *cfg, noisy=False)
        assert ti < ta < tr, cfg


def test_dlt_identity_free_and_symmetric_scale():
    plat = PLATFORMS["intel"]
    assert dlt_time(plat, "chw", "chw", 64, 56) == 0.0
    small = dlt_time(plat, "chw", "hwc", 16, 14, noisy=False)
    big = dlt_time(plat, "chw", "hwc", 256, 56, noisy=False)
    assert big > small
