"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.models import transformer as T


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.arange(B * S).reshape(B, S) % cfg.vocab,
         "labels": (jnp.arange(B * S).reshape(B, S) + 1) % cfg.vocab}
    if cfg.prefix_tokens:
        b["prefix_embeds"] = jnp.full((B, 8, cfg.d_model), 0.01, jnp.float32)
    if cfg.kind == "encdec":
        b["enc_embeds"] = jnp.full((B, 16, cfg.d_model), 0.01, jnp.float32)
    return b


@pytest.mark.parametrize("arch", cb.ASSIGNED_ARCHS)
def test_arch_smoke_train_step(arch):
    """One forward/train step on CPU: output shapes + no NaNs (assignment
    brief: reduced same-family config)."""
    cfg = cb.get(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch), has_aux=True))(params)
    assert np.isfinite(float(loss)), arch
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0, arch


@pytest.mark.parametrize("arch", cb.ASSIGNED_ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = cb.get(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B = 2
    cache = T.init_cache(cfg, B, 64, enc_len=16, dtype=jnp.float32)
    logits, cache2 = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))(
        params, cache, jnp.full((B, 1), 3, jnp.int32), jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", cb.ASSIGNED_ARCHS)
def test_arch_prefill_matches_forward(arch):
    """prefill's last-position logits == forward + unembed on the same
    tokens (the cache-producing path computes the same function)."""
    cfg = cb.get(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg)
    logits, cache = jax.jit(lambda p: T.prefill(
        p, cfg, b["tokens"], prefix_embeds=b.get("prefix_embeds"),
        enc_embeds=b.get("enc_embeds")))(params)
    h, _ = jax.jit(lambda p: T.forward(
        p, cfg, b["tokens"], prefix_embeds=b.get("prefix_embeds"),
        enc_embeds=b.get("enc_embeds")))(params)
    from repro.models import components as C
    from repro.models.transformer import _norm
    hN = _norm(cfg, params["final_norm"], h[:, -1:])
    emb = params["embed"] if cfg.tie_embeddings else {"emb": params["lm_head"]["w"].T}
    want = C.unembed(emb, hN)[:, 0].astype(jnp.float32)
    if cfg.final_softcap:
        want = cfg.final_softcap * jnp.tanh(want / cfg.final_softcap)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["chatglm3_6b", "gemma2_27b", "mixtral_8x7b",
                                  "minicpm3_4b", "mamba2_2_7b", "zamba2_2_7b"])
def test_decode_consistency_with_forward(arch):
    """Teacher-forced decode: prefill tokens[:4], then step tokens[4..7];
    final-step logits must match a full forward over tokens[:8]."""
    cfg = cb.get(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 8
    tokens = (jnp.arange(B * S).reshape(B, S) * 7 + 3) % cfg.vocab

    full_logits, _ = jax.jit(lambda p: T.prefill(p, cfg, tokens))(params)

    _, cache = jax.jit(lambda p: T.prefill(p, cfg, tokens[:, :4]))(params)
    # pad KV caches from prefill length 4 to S so decode can append
    def grow(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "ckv", "kr"):
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, S - a.shape[2])
            return jnp.pad(a, pad)
        return a
    cache = jax.tree_util.tree_map_with_path(grow, cache)

    step = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
    for i in range(4, S):
        logits, cache = step(params, cache, tokens[:, i:i + 1],
                             jnp.asarray(i, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=3e-3, atol=3e-3)


def test_param_counts_sane():
    for arch, lo, hi in [("llama3_405b", 380e9, 430e9),
                         ("mixtral_8x7b", 42e9, 50e9),
                         ("mamba2_2_7b", 2.2e9, 3.2e9),
                         ("gemma2_27b", 24e9, 30e9)]:
        n = cb.get(arch).n_params()
        assert lo < n < hi, (arch, n)
    a = cb.get("qwen3_moe_30b_a3b")
    assert 27e9 < a.n_params() < 34e9
    assert 2.5e9 < a.n_active_params() < 4.5e9
