"""Tile-config registry columns, the autotuned Pallas platform, and
predicted-cost cross-backend dispatch (DESIGN.md §9)."""
import numpy as np
import pytest

from repro.core.autotune import (PALLAS_CONV_BASES, PallasTileProvider,
                                 conv_tile_time_batch, pallas_columns,
                                 pallas_dlt_time_batch)
from repro.core.perfmodel import fit_perf_model
from repro.models import cnn_zoo
from repro.primitives.conv import (REGISTRY, compile_traits, is_runnable,
                                   resolve, split_tile, tile_columns)
from repro.service import (ArtifactStore, OptimisedNetwork, OptimisedServer,
                           PallasPlatform, get_platform, optimise)
from repro.service.artifacts import digest


# ---------------------------------------------------------------------------
# Tile-config registry columns
# ---------------------------------------------------------------------------

def test_tile_column_name_scheme():
    cols = tile_columns(["winograd-2x2-3x3"], ["mm-128x128x128", "mm-256x256x256"])
    assert cols == ["winograd-2x2-3x3@mm-128x128x128",
                    "winograd-2x2-3x3@mm-256x256x256"]
    assert split_tile(cols[0]) == ("winograd-2x2-3x3", "mm-128x128x128")
    assert split_tile("kn2row") == ("kn2row", None)
    assert resolve(cols[0]) is REGISTRY["winograd-2x2-3x3"]
    assert is_runnable(cols[0])                      # runs the base impl
    assert is_runnable("kn2row")
    assert not is_runnable("nonexistent@mm-128x128x128")


def test_compile_traits_over_tile_columns():
    base = "im2col-copy-ab-ki"
    names = (base, f"{base}@mm-128x128x128", f"{base}@mm-512x256x256")
    tr = compile_traits(names)
    # layouts/family/applicability are tile-invariant: inherited from base
    assert tr.fam[0] == tr.fam[1] == tr.fam[2]
    assert tr.in_layout[0] == tr.in_layout[1] == tr.in_layout[2]
    assert tr.out_layout[0] == tr.out_layout[1] == tr.out_layout[2]
    # but every tile column gets its own deterministic noise key
    assert len({int(k) for k in tr.key}) == 3
    # and the plain-name key is unchanged vs a plain-only compile (the
    # registry-wide trait cache predates tile columns)
    tr0 = compile_traits((base,))
    assert int(tr0.key[0]) == int(tr.key[0])


def test_pallas_profile_deterministic_and_tile_sensitive():
    cols = pallas_columns()
    assert len(cols) == len(PALLAS_CONV_BASES) * 8
    cfgs = np.array([[64, 32, 28, 1, 3], [256, 128, 14, 1, 1],
                     [512, 256, 7, 2, 3]], np.int64)
    a = conv_tile_time_batch(cfgs, cols)
    b = conv_tile_time_batch(cfgs, cols)
    np.testing.assert_array_equal(a, b)              # deterministic noise
    assert a.shape == (3, len(cols))
    # NaN follows base applicability: conv-1x1 is inapplicable at f=3
    j1 = cols.index("conv-1x1-gemm-ab-ki@mm-128x128x128")
    assert np.isnan(a[0, j1]) and np.isfinite(a[1, j1])
    # the tile config must MATTER: within one base, different tiles differ
    im2 = [j for j, c in enumerate(cols)
           if split_tile(c)[0] == "im2col-copy-ab-ki"]
    assert len({float(v) for v in a[0, im2]}) > 1
    d = pallas_dlt_time_batch(np.array([[32, 28], [256, 7]], np.int64))
    assert d.shape == (2, 6) and np.isfinite(d).all() and (d > 0).all()


# ---------------------------------------------------------------------------
# subset_columns over backend (tile) columns
# ---------------------------------------------------------------------------

def _lin_model(columns, seed=0):
    rng = np.random.default_rng(seed)
    f = np.exp(rng.uniform(0, 3, (60, 5)))
    t = np.exp(np.log(f) @ rng.uniform(0.5, 2.0, (5, len(columns)))) * 1e-6
    return fit_perf_model("lin", f[:40], t[:40], f[40:], t[40:],
                          columns=columns)


def test_subset_columns_base_of_expands_tiles():
    m = _lin_model(["a", "b", "c"])
    want = ["a@t1", "a@t2", "c@t1", "b"]
    sub = m.subset_columns(want, base_of=lambda c: c.split("@")[0])
    assert list(sub.columns) == want
    x = np.exp(np.random.default_rng(1).uniform(0, 3, (7, 5)))
    full, tiled = m.predict(x), sub.predict(x)
    # every tile head starts as its base primitive's head
    np.testing.assert_allclose(tiled[:, 0], full[:, 0])   # a@t1 == a
    np.testing.assert_allclose(tiled[:, 1], full[:, 0])   # a@t2 == a
    np.testing.assert_allclose(tiled[:, 2], full[:, 2])   # c@t1 == c
    np.testing.assert_allclose(tiled[:, 3], full[:, 1])   # b == b
    with pytest.raises(Exception):
        m.subset_columns(["zz@t1"], base_of=lambda c: c.split("@")[0])
    with pytest.raises(Exception):
        m.subset_columns(["a@t1"])                   # no base_of: unknown


def test_pallas_platform_transfer_and_optimise(tmp_path):
    tpu = PallasPlatform(max_triplets=5)
    assert len(tpu.columns) == 40
    assert tpu.base_column("winograd-2x2-3x3@mm-128x128x128") == "winograd-2x2-3x3"
    base = get_platform("intel", max_triplets=5).pretrain(max_iters=150,
                                                          patience=40)
    models = tpu.calibrate(base, budget=0.05, max_iters=100)
    assert list(models.prim.columns) == tpu.columns
    opt = optimise("edge_cnn", tpu, models=models, executable=True)
    # the PBQP picked tile columns, and they lower/execute via their base
    chosen = [v for v in opt.assignment.values() if "@" in v]
    assert chosen, "no tile column selected"
    from repro.primitives.executor import execute
    rep = execute(opt.spec, opt.assignment)
    assert rep.outputs is not None


def test_pallas_provider_matches_profile():
    tpu = PallasPlatform(max_triplets=5)
    prov = tpu.cost_provider()
    assert isinstance(prov, PallasTileProvider)
    cfgs = np.array([[64, 32, 28, 1, 3], [128, 64, 14, 1, 5]], np.int64)
    np.testing.assert_array_equal(tpu.profile(cfgs),
                                  prov.primitive_cost_matrix(cfgs))


# ---------------------------------------------------------------------------
# Per-backend artifact keys
# ---------------------------------------------------------------------------

def test_backend_in_artifact_address():
    p1 = PallasPlatform(max_triplets=5, name="tpu")
    p2 = PallasPlatform(max_triplets=5, name="tpu-b")
    f1 = p1._model_fields("prim", "nn2")
    f2 = p2._model_fields("prim", "nn2")
    assert f1["backend"] == "tpu" and f2["backend"] == "tpu-b"
    assert f1["columns"] == f2["columns"]
    assert digest(f1) != digest(f2)
    # even if the platform fingerprint and dataset were ever to coincide,
    # the backend name alone must keep the addresses apart
    forced = {**f2, "platform": f1["platform"], "dataset": f1["dataset"]}
    assert digest(f1) != digest(forced)
    assert digest(f1) == digest({**forced, "backend": "tpu"})


def test_per_backend_warm_start_roundtrip(tmp_path):
    store = ArtifactStore(str(tmp_path))
    base = get_platform("intel", max_triplets=5).pretrain(max_iters=150,
                                                          patience=40)
    kw = dict(base=base, budget=0.05, executable=True, max_iters=100,
              store=store)
    cold = {b: optimise("edge_cnn", get_platform(b, max_triplets=5), **kw)
            for b in ("arm", "tpu")}
    assert not cold["arm"].warm and not cold["tpu"].warm
    warm = {b: optimise("edge_cnn", get_platform(b, max_triplets=5), **kw)
            for b in ("arm", "tpu")}
    x = np.array([[64, 32, 28, 1, 3]], np.float64)
    for b in ("arm", "tpu"):
        # byte-identical warm start: same model content, same assignment
        assert warm[b].warm_models and warm[b].warm_selection
        assert warm[b].models.prim.fingerprint() == cold[b].models.prim.fingerprint()
        np.testing.assert_array_equal(warm[b].models.prim.predict(x),
                                      cold[b].models.prim.predict(x))
        assert warm[b].assignment == cold[b].assignment
    # and the two backends never shared an artifact: their model columns
    # (hence selections) are backend-specific
    assert set(cold["arm"].assignment.values()) != set(cold["tpu"].assignment.values())


# ---------------------------------------------------------------------------
# Cross-backend router
# ---------------------------------------------------------------------------

def _routed(spec, fast_s, slow_s, **server_kw):
    """A server with two backends of one logical net whose predicted
    per-image costs are ``fast_s``/``slow_s``. Nothing is executed — the
    router unit tests inspect queue placement only."""
    srv = OptimisedServer(**server_kw)
    for name, cost in (("fast", fast_s), ("slow", slow_s)):
        opt = OptimisedNetwork.from_assignment(spec, {}, net=spec.name,
                                               predicted_cost_s=cost)
        srv.register(opt, backend=name, max_inflight=1)
    return srv


def test_router_picks_predicted_cheapest_and_flips():
    spec = cnn_zoo.get("edge_cnn")
    n0 = spec.nodes[0]
    x = np.zeros((n0.c, n0.im, n0.im), np.float32)

    srv = _routed(spec, 1e-6, 1e-3)
    t = srv.submit(spec.name, x)
    assert t.net == f"{spec.name}#fast"
    s = srv.stats(spec.name)
    assert s["backends"]["fast"]["queued"] == 1
    assert s["backends"]["slow"]["queued"] == 0

    # predicted costs flip => the routing decision flips
    srv2 = _routed(spec, 1e-3, 1e-6)
    t2 = srv2.submit(spec.name, x)
    assert t2.net == f"{spec.name}#slow"


def test_router_spills_on_backpressure_and_fallback_on_unregister():
    spec = cnn_zoo.get("edge_cnn")
    n0 = spec.nodes[0]
    x = np.zeros((n0.c, n0.im, n0.im), np.float32)

    srv = OptimisedServer(queue_depth=1)
    for name, cost in (("fast", 1e-6), ("slow", 1e-3)):
        opt = OptimisedNetwork.from_assignment(spec, {}, net=spec.name,
                                               predicted_cost_s=cost)
        srv.register(opt, backend=name, max_inflight=1, queue_depth=1)
    t1 = srv.submit(spec.name, x)
    t2 = srv.submit(spec.name, x)        # fast is full: spill to slow
    assert t1.net.endswith("#fast") and t2.net.endswith("#slow")
    t3 = srv.submit(spec.name, x)        # both full: backpressure
    assert t3.rejected

    # unregistering a backend rejects its queued work and routing falls
    # back cleanly to the remaining backend
    assert srv.unregister_backend(spec.name, "fast")
    assert t1.done and t1.rejected
    assert srv.backends(spec.name) == ["slow"]
    assert not srv.unregister_backend(spec.name, "fast")
    srv2_t = srv.submit(spec.name, x)    # slow still full from t2
    assert srv2_t.rejected
    # unknown net still raises
    with pytest.raises(KeyError):
        srv.submit("no_such_net", x)


def test_routed_serving_end_to_end_and_stats(tmp_path):
    base = get_platform("intel", max_triplets=5).pretrain(max_iters=150,
                                                          patience=40)
    kw = dict(base=base, budget=0.05, executable=True, max_iters=100)
    opt_arm = optimise("edge_cnn", get_platform("arm", max_triplets=5), **kw)
    opt_tpu = optimise("edge_cnn", get_platform("tpu", max_triplets=5), **kw)
    srv = OptimisedServer(latency_budget_ms=50.0)
    srv.register(opt_arm, backend="arm", max_inflight=1)
    srv.register(opt_tpu, backend="tpu", max_inflight=1)
    n0 = opt_arm.spec.nodes[0]
    xs = np.random.default_rng(0).standard_normal(
        (8, n0.c, n0.im, n0.im)).astype(np.float32)
    out = srv.serve("edge_cnn", xs)
    assert len(out) == 8 and all(o is not None for o in out)
    s = srv.stats("edge_cnn")
    assert s["images"] == 8
    assert set(s["backends"]) == {"arm", "tpu"}
    per_backend = [b["dispatches"] for b in s["backends"].values()]
    assert sum(per_backend) == s["dispatches"] >= 1
    for b in s["backends"].values():
        assert "queue_wait_p50_ms" in b and "queue_wait_p99_ms" in b
    srv.stop()
