"""Pallas kernels vs ref.py oracles — shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.im2col_gemm.im2col_gemm import conv_im2col, conv_im2col_batch
from repro.kernels.im2col_gemm.ref import conv_ref
from repro.kernels.matmul.matmul import matmul, matmul_batch
from repro.kernels.matmul.ops import VARIANTS as MM_VARIANTS
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.winograd.ops import winograd_conv_batch_op, winograd_conv_op
from repro.kernels.winograd.ref import conv3x3_ref, point_gemm_ref
from repro.kernels.winograd.winograd import (winograd_point_gemm,
                                             winograd_point_gemm_batch)

_TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
        jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,blocks", [
    ((256, 256, 256), (128, 128, 128)),
    ((300, 200, 150), (128, 128, 128)),     # non-divisible edges
    ((64, 64, 64), (128, 128, 128)),        # blocks larger than array
    ((100, 77, 33), (32, 32, 32)),
])
def test_matmul_kernel(shape, blocks, dtype, rng):
    m, k, n = shape
    bm, bk, bn = blocks
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    y = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = matmul(x, y, bm=bm, bk=bk, bn=bn, interpret=True)
    ref = matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_TOL[dtype])


@pytest.mark.parametrize("variant", sorted(MM_VARIANTS))
def test_matmul_all_variants(variant, rng):
    from repro.kernels.matmul.ops import matmul_op
    x = jnp.asarray(rng.standard_normal((160, 96)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((96, 200)), jnp.float32)
    got = matmul_op(x, y, variant=variant, interpret=True)
    np.testing.assert_allclose(got, matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("cfg", [(4, 256, 64, 128, 128), (2, 256, 32, 64, 64),
                                 (3, 512, 64, 128, 256)])
def test_flash_attention_kernel(cfg, causal, rng):
    bh, s, d, bq, bkv = cfg
    q, k, v = (jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
               for _ in range(3))
    got = flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_flash_attention_gqa_wrapper(rng):
    B, S, Hq, Hkv, d = 2, 256, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, Hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, d)), jnp.float32)
    got = flash_attention_op(q, k, v, interpret=True)
    kr = jnp.repeat(k, Hq // Hkv, 2)
    vr = jnp.repeat(v, Hq // Hkv, 2)
    ref = attention_ref(q.transpose(0, 2, 1, 3).reshape(B * Hq, S, d),
                        kr.transpose(0, 2, 1, 3).reshape(B * Hq, S, d),
                        vr.transpose(0, 2, 1, 3).reshape(B * Hq, S, d))
    ref = ref.reshape(B, Hq, S, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", [(8, 16, 16, 3, 1), (4, 19, 8, 3, 2),
                                 (3, 14, 32, 5, 1), (8, 9, 8, 1, 1),
                                 (5, 12, 20, 3, 1)])
def test_im2col_gemm_kernel(cfg, rng):
    C, H, K, f, s = cfg
    x = jnp.asarray(rng.standard_normal((C, H, H)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, C, f, f)), jnp.float32)
    got = conv_im2col(x, w, s, bk=16, interpret=True)
    np.testing.assert_allclose(got, conv_ref(x, w, s), rtol=1e-4, atol=2e-4)


def test_winograd_point_gemm(rng):
    u = jnp.asarray(rng.standard_normal((16, 60, 48)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((16, 48, 75)), jnp.float32)
    got = winograd_point_gemm(u, v, bk=32, bt=32, bc=32, interpret=True)
    np.testing.assert_allclose(got, point_gemm_ref(u, v), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", [(4, 16, 8), (3, 15, 16), (6, 21, 10)])
def test_winograd_full_conv(cfg, rng):
    C, H, K = cfg
    x = jnp.asarray(rng.standard_normal((C, H, H)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, C, 3, 3)), jnp.float32)
    got = winograd_conv_op(x, w, interpret=True)
    np.testing.assert_allclose(got, conv3x3_ref(x, w), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Batch-grid variants (explicit batch dimension in the kernel grid)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,blocks", [
    ((2, 100, 77, 53), (32, 32, 32)),
    ((3, 64, 64, 64), (128, 128, 128)),     # blocks larger than array
    ((1, 130, 70, 140), (64, 64, 64)),      # non-divisible edges
])
def test_matmul_batch_kernel(shape, blocks, rng):
    B, m, k, n = shape
    bm, bk, bn = blocks
    x = jnp.asarray(rng.standard_normal((B, m, k)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((B, k, n)), jnp.float32)
    got = matmul_batch(x, y, bm=bm, bk=bk, bn=bn, interpret=True)
    ref = jnp.stack([matmul_ref(x[b], y[b]) for b in range(B)])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", [(2, 4, 16, 8, 3, 1), (3, 4, 19, 8, 3, 2),
                                 (2, 3, 14, 32, 5, 1), (2, 8, 9, 8, 1, 1)])
def test_im2col_gemm_batch_kernel(cfg, rng):
    N, C, H, K, f, s = cfg
    x = jnp.asarray(rng.standard_normal((N, C, H, H)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, C, f, f)), jnp.float32)
    got = conv_im2col_batch(x, w, s, bk=16, interpret=True)
    ref = jnp.stack([conv_ref(x[b], w, s) for b in range(N)])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=2e-4)


def test_winograd_point_gemm_batch(rng):
    u = jnp.asarray(rng.standard_normal((16, 60, 48)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 16, 48, 75)), jnp.float32)
    got = winograd_point_gemm_batch(u, v, bk=32, bt=32, bc=32, interpret=True)
    ref = jnp.stack([point_gemm_ref(u, v[b]) for b in range(2)])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_winograd_full_conv_batch(rng):
    x = jnp.asarray(rng.standard_normal((2, 4, 16, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 4, 3, 3)), jnp.float32)
    got = winograd_conv_batch_op(x, w, interpret=True)
    ref = jnp.stack([conv3x3_ref(x[b], w) for b in range(2)])
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)
