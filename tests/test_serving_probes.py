"""Probe dispatches (DESIGN.md §14.4): rate-limited single-layer
measurements piggybacked on clean dispatches. Unit coverage for the three
contracts the fleet soak cannot isolate:

  * rate limiting under load — at most one probe per ``1/probe_rate``
    seconds per state, round-robin over the attribution profile;
  * isolation — probes never enter the drift buffer, the served-latency
    wait samples, or the bucket-scale head;
  * attribution — probe measurements surface in the served sample as their
    own single-column rows at the probed (config, column), in the model's
    prediction scale.

All timing is an injected fake clock (test_serving.py idiom)."""
import numpy as np
import pytest

from repro.models import cnn_zoo
from repro.service import OptimisedServer, layer_profile, optimise
from repro.service.platforms import SimulatedPlatform
from repro.service.serving.server import ProbeUnsupported


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@pytest.fixture(scope="module")
def optimised_net():
    platform = SimulatedPlatform("arm", max_triplets=16)
    return optimise("edge_cnn", platform, executable=True, max_iters=250)


def _requests(spec, n, seed=0):
    n0 = spec.nodes[0]
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n0.c, n0.im, n0.im)).astype(np.float32)


class _ProbingServer(OptimisedServer):
    """Real plan execution paced on the fake clock; probes measure exactly
    ``probe_factor`` × the model's prediction for the probed target."""

    def __init__(self, fake_clock, base_cost_s, probe_factor=4.0, **kw):
        super().__init__(clock=fake_clock, **kw)
        self._fake = fake_clock
        self._base_cost_s = base_cost_s
        self._probe_factor = probe_factor
        self.probe_calls = []

    def _run_plan(self, opt, xs, weights):
        out = super()._run_plan(opt, xs, weights)
        self._fake.advance(self._base_cost_s * xs.shape[0])
        return out

    def _run_probe(self, opt, config, column):
        layers = self._drift.layer_profile(opt.net)
        key = tuple(float(v) for v in np.asarray(config).reshape(-1))
        for f, c, p in zip(layers.feats, layers.columns, layers.predicted):
            if tuple(float(v) for v in f) == key and c == column:
                self.probe_calls.append((key, column))
                return self._probe_factor * float(p)
        raise AssertionError(f"probe target {(key, column)} not in profile")


def _mk(optimised_net, clock, **kw):
    server = _ProbingServer(clock, optimised_net.predicted_cost_s,
                            max_batch=4, latency_budget_ms=1e9,
                            drift_threshold=50.0, drift_calib_obs=1, **kw)
    server.register(optimised_net)
    return server


def test_probe_rate_limit_and_round_robin(optimised_net):
    clock = FakeClock()
    server = _mk(optimised_net, clock, probe_rate=1.0)
    net, spec = optimised_net.net, optimised_net.spec
    xs = _requests(spec, 4)
    try:
        server.serve(net, xs)                   # bucket-4 compile: no probe
        assert server.stats(net)["probes"] == 0
        # a burst of clean dispatches: exactly ONE probe, interval unelapsed
        for _ in range(8):
            server.serve(net, xs)
        assert server.stats(net)["probes"] == 1
        clock.advance(1.0)
        server.serve(net, xs)
        assert server.stats(net)["probes"] == 2
        assert server.stats(net)["probe_failures"] == 0
        # round-robin over the attribution profile, in order
        prof = layer_profile(optimised_net)
        want = [(tuple(float(v) for v in prof.feats[i]), prof.columns[i])
                for i in (0, 1)]
        assert server.probe_calls == want
    finally:
        server.stop()


def test_probes_excluded_from_buffer_waits_and_bucket_head(optimised_net):
    clock = FakeClock()
    server = _mk(optimised_net, clock, probe_rate=1e9)   # probe every batch
    net, spec = optimised_net.net, optimised_net.spec
    xs = _requests(spec, 4)
    try:
        rounds = 6
        for _ in range(rounds):
            server.serve(net, xs)
        s = server.stats(net)
        assert s["probes"] == rounds - 1        # every clean dispatch probed
        # the drift buffer holds only plan dispatches, never probes
        assert s["observed_dispatches"] == rounds - 1
        # ticketless probes leave no queueing-wait samples behind
        with server._cond:
            waits = len(server._drift._stats[net].waits)
        assert waits == rounds
        # only the served bucket can appear in the scale head
        scales = s["bucket_scales"]
        assert scales is None or set(scales) <= {4}
        # probes ride the served sample as single-column rows at the probed
        # (config, column), scaled by the measured observed/predicted ratio
        ds = server.served_sample(net)
        assert ds is not None
        assert ds.served_info["probes"] == s["probes"]
        prof = layer_profile(optimised_net)
        probed = {k for k, _ in server.probe_calls}
        n_bucket_rows = ds.n - len(probed)
        for key, col in set(server.probe_calls):
            rows = [i for i in range(n_bucket_rows, ds.n)
                    if tuple(float(v) for v in ds.feats[i]) == key
                    and np.isfinite(ds.times[i, ds.columns.index(col)])]
            assert len(rows) == 1
            i = rows[0]
            j = ds.columns.index(col)
            pred = next(float(p) for f, c, p in
                        zip(prof.feats, prof.columns, prof.predicted)
                        if tuple(float(v) for v in f) == key and c == col)
            assert ds.times[i, j] == pytest.approx(4.0 * pred, rel=1e-6)
            # single finite entry per probe row
            assert np.isfinite(ds.times[i]).sum() == 1
    finally:
        server.stop()


def test_probe_failure_counts_and_ledger(optimised_net):
    clock = FakeClock()
    server = _mk(optimised_net, clock, probe_rate=1e9)
    server._run_probe = lambda opt, cfg, col: (_ for _ in ()).throw(
        RuntimeError("probe rig broke"))
    net, spec = optimised_net.net, optimised_net.spec
    xs = _requests(spec, 4)
    try:
        for _ in range(3):
            server.serve(net, xs)
        s = server.stats(net)
        assert s["probes"] == 0 and s["probe_failures"] == 2
        assert server._drift.failure_ledger(net)[0]["probe"] == 2
        # failed probes contribute nothing to the served sample
        ds = server.served_sample(net)
        assert ds is not None and ds.served_info.get("probes", 0) == 0
    finally:
        server.stop()


def test_unsupported_probe_is_skip_not_failure(optimised_net):
    clock = FakeClock()
    server = _mk(optimised_net, clock, probe_rate=1e9)
    server._run_probe = lambda opt, cfg, col: (_ for _ in ()).throw(
        ProbeUnsupported(col))
    net, spec = optimised_net.net, optimised_net.spec
    try:
        for _ in range(3):
            server.serve(net, _requests(spec, 4))
        s = server.stats(net)
        assert s["probes"] == 0 and s["probe_failures"] == 0
        assert "probe" not in server._drift.failure_ledger(net).get(0, {})
    finally:
        server.stop()


def test_probe_rate_validation_and_default_off(optimised_net):
    with pytest.raises(ValueError):
        OptimisedServer(probe_rate=-1.0)
    clock = FakeClock()
    server = _mk(optimised_net, clock)                  # default: disabled
    net, spec = optimised_net.net, optimised_net.spec
    try:
        for _ in range(4):
            server.serve(net, _requests(spec, 4))
        assert server.stats(net)["probes"] == 0
        assert server.probe_calls == []
    finally:
        server.stop()


def test_observations_to_dataset_probe_rows_pure():
    """Pure dataset-layer contract: probe triples become their own rows,
    sorted by (config, column), finite only at the probed column."""
    from repro.profiler.dataset import observations_to_dataset
    feats = np.array([[16, 3, 32, 1, 3]], np.float64)
    probes = [(np.array([32, 16, 30, 1, 3], np.float64), "kn2row", 2e-3),
              (np.array([16, 3, 32, 1, 3], np.float64), "mec-col", 1e-3)]
    ds = observations_to_dataset(
        feats, ("kn2row",), [(1, np.array([1e-3]))],
        columns=["kn2row", "mec-col"], platform="arm", probes=probes)
    assert ds.n == 3                    # 1 bucket row + 2 probe rows
    assert ds.served_info["probes"] == 2
    # probe rows sorted by (config, column): [16,...] before [32,...]
    np.testing.assert_array_equal(ds.feats[1], [16, 3, 32, 1, 3])
    np.testing.assert_array_equal(ds.feats[2], [32, 16, 30, 1, 3])
    j_mec, j_kn = ds.columns.index("mec-col"), ds.columns.index("kn2row")
    assert ds.times[1, j_mec] == pytest.approx(1e-3)
    assert ds.times[2, j_kn] == pytest.approx(2e-3)
    assert np.isfinite(ds.times[1:]).sum() == 2
    with pytest.raises(ValueError):
        observations_to_dataset(
            feats, ("kn2row",), [(1, np.array([1e-3]))], columns=["kn2row"],
            platform="arm",
            probes=[(np.array([1, 1, 1, 1, 1], np.float64), "nope", 1e-3)])
