"""Service layer: platform abstraction, artifact store, end-to-end transfer
loop at tiny scale, and the serving front end (DESIGN.md §7)."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.selection import (SimulatedProvider, build_pbqp, network_cost,
                                  select)
from repro.models import cnn_zoo
from repro.service import (ArtifactStore, OptimisedNetwork, OptimisedServer,
                           get_platform, optimise)
from repro.service.platforms import HostPlatform, SimulatedPlatform


# ---------------------------------------------------------------------------
# Platform abstraction
# ---------------------------------------------------------------------------

def test_get_platform_dispatch():
    assert isinstance(get_platform("intel"), SimulatedPlatform)
    assert isinstance(get_platform("host"), HostPlatform)
    p = get_platform("arm", max_triplets=5)
    assert get_platform(p) is p
    with pytest.raises(KeyError):
        get_platform("riscv")
    with pytest.raises(TypeError):
        get_platform(p, max_triplets=3)


def test_simulated_platform_profile_matches_provider():
    plat = get_platform("amd", max_triplets=5)
    prov = plat.cost_provider()
    cfgs = np.array([[16, 8, 14, 1, 3], [64, 32, 7, 2, 5]])
    np.testing.assert_array_equal(plat.profile(cfgs),
                                  prov.primitive_cost_matrix(cfgs))
    pairs = np.array([[16, 14], [64, 7]])
    np.testing.assert_array_equal(plat.profile_dlt(pairs),
                                  prov.dlt_cost_matrix(pairs))


def test_platform_datasets_cached_and_fingerprinted():
    plat = get_platform("intel", max_triplets=5)
    ds1 = plat.primitive_dataset()
    assert plat.primitive_dataset() is ds1            # per-instance cache
    # deterministic simulator noise => identical fingerprint across instances
    ds2 = get_platform("intel", max_triplets=5).primitive_dataset()
    assert ds1.fingerprint() == ds2.fingerprint()
    assert ds1.fingerprint() != plat.dlt_dataset().fingerprint()
    assert get_platform("arm", max_triplets=5).primitive_dataset().fingerprint() \
        != ds1.fingerprint()


# ---------------------------------------------------------------------------
# Artifact store
# ---------------------------------------------------------------------------

def _tiny_model(seed=0):
    from repro.core.perfmodel import fit_perf_model
    rng = np.random.default_rng(seed)
    f = np.exp(rng.uniform(0, 3, (60, 5)))
    t = np.exp(np.log(f) @ rng.uniform(0.5, 2.0, (5, 3))) * 1e-6
    return fit_perf_model("lin", f[:40], t[:40], f[40:], t[40:])


def test_artifact_store_model_roundtrip_and_miss(tmp_path):
    store = ArtifactStore(str(tmp_path))
    m = _tiny_model()
    fields = {"platform": "test", "columns": ["a", "b", "c"],
              "dataset": "d0", "model_kind": "lin"}
    assert store.get_model(fields) is None
    store.put_model(fields, m)
    m2 = store.get_model(fields)
    assert m2 is not None and m2.fingerprint() == m.fingerprint()
    # different key fields => different address => miss
    assert store.get_model({**fields, "dataset": "d1"}) is None


def test_artifact_store_get_or_train_warm_flag(tmp_path):
    store = ArtifactStore(str(tmp_path))
    fields = {"k": 1}
    calls = []

    def train():
        calls.append(1)
        return _tiny_model()

    m1, warm1 = store.get_or_train(fields, train)
    m2, warm2 = store.get_or_train(fields, train)
    assert (warm1, warm2) == (False, True)
    assert len(calls) == 1
    assert m1.fingerprint() == m2.fingerprint()


def _payload_path(entry_dir):
    """The payload file the entry's manifest names (stage.<token>.<name>)."""
    with open(os.path.join(entry_dir, "manifest.json")) as f:
        return os.path.join(entry_dir, json.load(f)["payload"])


def test_artifact_store_rejects_corrupt_payload(tmp_path):
    store = ArtifactStore(str(tmp_path))
    fields = {"k": "corrupt"}
    path = store.put_model(fields, _tiny_model())
    with open(_payload_path(path), "r+b") as f:
        f.write(b"garbage")                     # checksum now mismatches
    assert store.get_model(fields) is None      # invisible, not an exception


def test_artifact_store_sweep_keeps_newest_k(tmp_path):
    store = ArtifactStore(str(tmp_path))
    for i in range(5):
        store.put_json("selections", {"k": i}, {"i": i})
    assert len(store.entries("selections")) == 5
    assert store.sweep(2, category="selections") == 3
    kept = {e["fields"]["k"] for e in store.entries("selections")}
    assert kept == {3, 4}        # newest two by manifest creation time


def test_artifact_store_sweep_collects_truncated_and_partial(tmp_path):
    """sweep() with no retention is a pure GC pass: corrupt entries (payload
    truncated after the manifest was written) and dead tmp dirs are
    collected; valid artifacts are untouched (DESIGN.md §11)."""
    import time as _time
    store = ArtifactStore(str(tmp_path))
    store.put_json("selections", {"k": "good"}, {"v": 1})
    bad = store.put_json("selections", {"k": "bad"}, {"v": 2})
    with open(_payload_path(bad), "w") as f:
        f.write('{"v":')                        # truncated payload
    partial = os.path.join(str(tmp_path), "selections", "no-manifest")
    os.makedirs(partial)                        # writer died before manifest
    stale_tmp = os.path.join(str(tmp_path), "selections", "tmp.dead.1")
    os.makedirs(stale_tmp)
    old = _time.time() - 7200
    os.utime(stale_tmp, (old, old))             # crashed writer, hours ago
    assert store.get_json("selections", {"k": "bad"}) is None   # invisible
    assert store.sweep() == 2                   # truncated + manifest-less
    assert not os.path.exists(bad) and not os.path.exists(partial)
    assert not os.path.exists(stale_tmp)        # stale tmp reaped too
    assert store.get_json("selections", {"k": "good"}) == {"v": 1}
    assert len(store.entries("selections")) == 1


def test_artifact_store_opportunistic_gc_bounds_growth(tmp_path):
    """keep= makes every put GC its category — drift-loop recalibration
    generations cannot grow the store without bound."""
    store = ArtifactStore(str(tmp_path), keep=3)
    for i in range(10):
        store.put_json("selections", {"gen": i}, {"gen": i})
        store.put_model({"gen": i}, _tiny_model(seed=i))
    assert len(store.entries("selections")) == 3
    assert len(store.entries("models")) == 3
    # the newest generation always survives its own put
    assert store.get_json("selections", {"gen": 9}) == {"gen": 9}
    with pytest.raises(ValueError):
        ArtifactStore(str(tmp_path), keep=0)


def test_artifact_store_dataset_roundtrip(tmp_path):
    from repro.profiler.dataset import PerfDataset
    store = ArtifactStore(str(tmp_path))
    ds = PerfDataset(np.arange(10.0).reshape(5, 2),
                     np.arange(15.0).reshape(5, 3) * 1e-6,
                     ["a", "b", "c"], ["x", "y"], "testplat")
    fields = {"artifact": "perf_dataset", "pool": [[1, 2]], "repeats": 3}
    assert store.get_dataset(fields) is None
    store.put_dataset(fields, ds)
    back = store.get_dataset(fields)
    assert back is not None and back.fingerprint() == ds.fingerprint()
    assert back.columns == ds.columns and back.platform == ds.platform


def test_artifact_store_json_and_entries(tmp_path):
    store = ArtifactStore(str(tmp_path))
    obj = {"assignment": {"0": "winograd-2-3"}, "cost": 1e-3}
    store.put_json("selections", {"net": "x"}, obj)
    assert store.get_json("selections", {"net": "x"}) == obj
    assert store.get_json("selections", {"net": "y"}) is None
    store.put_model({"m": 1}, _tiny_model())
    cats = {e["category"] for e in store.entries()}
    assert cats == {"models", "selections"}


# ---------------------------------------------------------------------------
# End-to-end transfer loop (tiny scale) — the paper's deployment story
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def transfer_setup(tmp_path_factory):
    """Pretrain on intel once, calibrate onto arm with a 1% sample."""
    store = ArtifactStore(str(tmp_path_factory.mktemp("artifacts")))
    intel = get_platform("intel", max_triplets=40)
    base = intel.pretrain("nn2", store=store, max_iters=800)
    arm = get_platform("arm", max_triplets=40)
    opt = optimise("alexnet", arm, store=store, base=base, mode="factor")
    return store, intel, arm, base, opt


def test_transfer_selection_quality_within_bound(transfer_setup):
    _, _, arm, base, opt = transfer_setup
    assert base.prim.kind == "nn2"
    assert opt.models.prim.kind == "factor-nn2"
    truth = SimulatedProvider("arm")
    g = build_pbqp(opt.spec, truth)
    c_opt = select(opt.spec, truth).solver_cost
    c_model = network_cost(opt.spec, opt.assignment, graph=g)
    # 1%-sample factor calibration lands within 1.25x of selecting from
    # ground-truth costs (observed ~1.00-1.08 across seeds; the paper's
    # full-scale result is <= 1.1% — this is the tiny-scale analogue)
    assert c_model / c_opt < 1.25


def test_transfer_warm_start_byte_identical(transfer_setup):
    store, intel, arm, base, opt = transfer_setup
    base2 = intel.pretrain("nn2", store=store, max_iters=800)
    opt2 = optimise("alexnet", arm, store=store, base=base2, mode="factor")
    assert base2.warm and opt2.warm_models and opt2.warm_selection
    assert opt2.assignment == opt.assignment
    for a, b in ((base.prim, base2.prim), (opt.models.prim, opt2.models.prim),
                 (opt.models.dlt, opt2.models.dlt)):
        s1, s2 = a.to_state(), b.to_state()
        assert s1["header"] == s2["header"]
        for name in s1["arrays"]:
            assert s1["arrays"][name].tobytes() == s2["arrays"][name].tobytes()


def test_calibrate_modes(transfer_setup):
    _, _, arm, base, _ = transfer_setup
    fc = arm.calibrate(base, 0.01, mode="factor")
    ft = arm.calibrate(base, 0.01, mode="finetune", max_iters=50)
    sc = arm.calibrate(base, 0.01, mode="scratch", max_iters=50)
    assert fc.prim.kind == "factor-nn2"
    assert ft.prim.kind == "nn2" and sc.prim.kind == "nn2"
    _, _, te = arm.primitive_dataset().split()
    # any calibration must beat applying the intel model unchanged
    direct = base.prim.mdrae(te.feats, te.times)
    assert fc.prim.mdrae(te.feats, te.times) < direct
    with pytest.raises(ValueError):
        arm.calibrate(base, 0.01, mode="telepathy")


def test_calibrate_wide_base_onto_narrow_platform(transfer_setup):
    """Transferring the 49-column simulator model onto a platform that
    profiles fewer primitives (the host CLI path) slices the base's output
    head instead of mispairing columns positionally."""
    from repro.primitives.conv import RUNNABLE
    from repro.profiler.dataset import PerfDataset

    _, _, _, base, _ = transfer_setup
    narrow_cols = list(RUNNABLE)[:6]

    class Narrow(SimulatedPlatform):
        def primitive_dataset(self):
            ds = super().primitive_dataset()
            idx = [ds.columns.index(c) for c in narrow_cols]
            return PerfDataset(ds.feats, ds.times[:, idx], narrow_cols,
                               ds.feature_names, ds.platform)

    plat = Narrow("arm", max_triplets=10)
    fc = plat.calibrate(base, 0.05, mode="factor")
    assert list(fc.prim.columns) == narrow_cols
    cfgs = np.array([[16, 8, 14, 1, 3]], float)
    assert fc.prim.predict(cfgs).shape == (1, 6)
    ft = plat.calibrate(base, 0.3, mode="finetune", max_iters=30)
    assert ft.prim.n_outputs == 6 and ft.prim.predict(cfgs).shape == (1, 6)


# ---------------------------------------------------------------------------
# Serving front end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_net():
    spec = cnn_zoo.get("edge_cnn")
    from repro.primitives.plan import heuristic_assignment
    asg = heuristic_assignment(spec)
    return OptimisedNetwork.from_assignment(spec, asg,
                                            predicted_cost_s=2e-3)


def _requests(spec, n, seed=0):
    n0 = spec.nodes[0]
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n0.c, n0.im, n0.im)).astype(np.float32)


def test_server_results_match_direct_plan(served_net):
    import jax.numpy as jnp
    from repro.primitives.executor import make_weights
    from repro.primitives.plan import compile_plan

    weights = make_weights(served_net.spec)
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9)
    server.register(served_net, weights=weights)
    xs = _requests(served_net.spec, 7)       # 7 requests -> batches 4 + 3
    results = server.serve(served_net.net, xs)
    assert all(r is not None for r in results)

    plan = compile_plan(served_net.spec, served_net.assignment,
                        (7,) + xs.shape[1:])
    want = np.asarray(plan(jnp.asarray(xs), weights)[plan.sinks[-1]])
    got = np.stack(results)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)

    s = server.stats(served_net.net)
    assert s["dispatches"] == 2 and s["images"] == 7
    assert s["padded"] == 1                  # 3-request tail padded to 4


def test_server_batch_cap_follows_latency_budget(served_net):
    # predicted 2 ms/img, 8 ms budget -> cap 4; 100 ms -> capped at max_batch
    server = OptimisedServer(max_batch=16, latency_budget_ms=8.0)
    assert server.register(served_net).batch_cap == 4
    server2 = OptimisedServer(max_batch=16, latency_budget_ms=1000.0)
    assert server2.register(served_net).batch_cap == 16


def test_server_hot_swap(served_net):
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9)
    server.register(served_net)
    out1 = server.serve(served_net.net, _requests(served_net.spec, 2))

    swapped = OptimisedNetwork.from_assignment(
        served_net.spec,
        {i: ("im2col-copy-ab-ki" if hasattr(n, "k") else "chw")
         for i, n in enumerate(served_net.spec.nodes)},
        net=served_net.net, predicted_cost_s=2e-3)
    server.hot_swap(served_net.net, swapped)
    st = server.stats(served_net.net)
    assert st["generation"] == 1
    out2 = server.serve(served_net.net, _requests(served_net.spec, 2))
    assert out1[0].shape == out2[0].shape    # same topology, new primitives

    other = OptimisedNetwork.from_assignment(
        cnn_zoo.get("alexnet"), {}, net=served_net.net)
    with pytest.raises(ValueError):
        server.hot_swap(served_net.net, other)


def test_server_unknown_network():
    server = OptimisedServer()
    with pytest.raises(KeyError):
        server.submit("nope", np.zeros((3, 8, 8), np.float32))


def test_server_rejects_malformed_request_shape(served_net):
    server = OptimisedServer()
    server.register(served_net)
    n0 = served_net.spec.nodes[0]
    with pytest.raises(ValueError):
        server.submit(served_net.net, np.zeros((n0.c, n0.im), np.float32))


def test_server_failed_dispatch_marks_tickets_not_loses_them(served_net):
    """A dispatch that raises must mark its batch's tickets with the error
    and keep serving the rest of the queue."""
    server = OptimisedServer(max_batch=4, latency_budget_ms=1e9)
    server.register(served_net)
    state = server._nets[served_net.net]
    good_weights = state.weights
    state.weights = {}                        # first pump: dispatch raises
    bad = [server.submit(served_net.net, x)
           for x in _requests(served_net.spec, 2)]
    server.pump()
    assert all(t.done and t.error and t.result is None for t in bad)
    state.weights = good_weights              # recovered: serving continues
    ok = server.serve(served_net.net, _requests(served_net.spec, 2))
    assert all(r is not None for r in ok)


def test_one_keying_scheme_pretrain_prim_shares_address(tmp_path):
    """A model trained via the split platform verbs and one trained inside
    ``pretrain`` land at the SAME artifact address (ROADMAP: no benchmark-only
    tag field, one address per logical model)."""
    store = ArtifactStore(str(tmp_path))
    plat = get_platform("arm", max_triplets=5)
    m1, warm1 = plat.pretrain_prim("lin", store=store, max_iters=50)
    d1, warm_d1 = plat.pretrain_dlt("lin", store=store)
    models = plat.pretrain("lin", store=store, max_iters=50)
    assert (warm1, warm_d1, models.warm) == (False, False, True)  # address hits
    assert models.prim.fingerprint() == m1.fingerprint()
    assert models.dlt.fingerprint() == d1.fingerprint()
    assert len(store.entries("models")) == 2           # prim + dlt, nothing else


def test_host_platform_dataset_persistence(tmp_path, monkeypatch):
    """HostPlatform with a store profiles once and warm-starts the dataset
    across instances keyed by (pool, repeats, machine id)."""
    from repro.profiler.dataset import PerfDataset
    from repro.service.platforms import host_machine_id

    calls = []

    def fake_profile(configs, primitives=None, repeats=9):
        calls.append(len(configs))
        feats = np.asarray(configs, np.float64)
        times = np.full((len(configs), len(primitives)), 1e-4)
        return PerfDataset(feats, times, list(primitives),
                           ["k", "c", "im", "s", "f"], "host-cpu")

    import repro.profiler.host as host
    monkeypatch.setattr(host, "profile_primitive_dataset", fake_profile)

    store = ArtifactStore(str(tmp_path))
    pool = [(8, 4, 8, 1, 3), (16, 8, 8, 1, 3)]
    prims = ["im2col-copy-ab-ki", "kn2row"]
    p1 = HostPlatform(configs=pool, primitives=prims, repeats=3, store=store)
    ds1 = p1.primitive_dataset()
    assert calls == [2]
    p2 = HostPlatform(configs=pool, primitives=prims, repeats=3, store=store)
    ds2 = p2.primitive_dataset()
    assert calls == [2]                       # warm: no second measurement
    assert ds2.fingerprint() == ds1.fingerprint()
    # a different pool/repeats/machine is a different address
    p3 = HostPlatform(configs=pool, primitives=prims, repeats=5, store=store)
    p3.primitive_dataset()
    assert calls == [2, 2]
    assert "/" in host_machine_id() and "cpus=" in host_machine_id()


def test_calibrate_from_fresh_sample_and_reoptimise(transfer_setup):
    """The drift loop's path: measure a fresh sample, calibrate onto it,
    reoptimise — without touching the platform's cached profiling pool."""
    from repro.service import reoptimise

    _, _, arm, base, opt = transfer_setup
    sample = arm.measure_sample(12, seed=3)
    assert sample.n == 12 and list(sample.columns) == list(arm.columns)
    cal = arm.calibrate(base, mode="factor", sample=sample)
    assert cal.prim.kind == "factor-nn2"
    # scaled platform => scaled sample => scaled calibrated predictions
    arm2 = SimulatedPlatform("arm", max_triplets=40, time_scale=3.0)
    sample3 = arm2.measure_sample(12, seed=3)
    np.testing.assert_allclose(sample3.times, 3.0 * sample.times)
    cal3 = arm2.calibrate(base, mode="factor", sample=sample3)
    cfgs = np.array([[16, 8, 14, 1, 3]], float)
    # only columns the sample measured get a factor (others keep the base)
    cols = np.isfinite(sample.times).any(axis=0)
    np.testing.assert_allclose(cal3.prim.predict(cfgs)[:, cols],
                               3.0 * cal.prim.predict(cfgs)[:, cols],
                               rtol=1e-6)

    opt2 = reoptimise(opt, sample=sample, mode="factor")
    assert opt2.net == opt.net and opt2.models.prim.kind == "factor-nn2"
    assert opt2.predicted_cost_s > 0
    with pytest.raises(ValueError):
        reoptimise(OptimisedNetwork.from_assignment(opt.spec, opt.assignment))


def test_selection_artifact_keyed_by_spec_topology(tmp_path):
    """Editing a network definition must invalidate its stored selection."""
    from repro.service.pipeline import _spec_fingerprint
    spec = cnn_zoo.get("edge_cnn")
    fp = _spec_fingerprint(spec)
    assert fp == _spec_fingerprint(cnn_zoo.get("edge_cnn"))
    mutated = dataclasses.replace(
        spec, nodes=[dataclasses.replace(spec.nodes[0], k=spec.nodes[0].k * 2)]
        + spec.nodes[1:])
    assert _spec_fingerprint(mutated) != fp
