"""End-to-end primitive selection (paper Fig 2 pipeline)."""
import numpy as np
import pytest

from repro.core import pbqp
from repro.core.selection import (ModelProvider, SimulatedProvider, build_pbqp,
                                  network_cost, select)
from repro.models import cnn_zoo
from repro.primitives.conv import REGISTRY


@pytest.fixture(scope="module")
def provider():
    return SimulatedProvider("intel")


def test_selection_runs_all_paper_networks(provider):
    for net in cnn_zoo.PAPER_SELECTION_NETS:
        spec = cnn_zoo.get(net)
        res = select(spec, provider)
        assert res.optimal, net            # reductions stay exact on these DAGs
        assert np.isfinite(res.solver_cost) and res.solver_cost > 0
        # every conv node got an applicable primitive
        for i, node in enumerate(spec.nodes):
            if hasattr(node, "k"):
                p = REGISTRY[res.assignment[i]]
                assert p.applicable(*node.config), (net, i)


def test_selection_beats_single_family(provider):
    """The PBQP-selected mix must be at least as fast as forcing every layer
    to the best single always-applicable primitive (the paper's motivation)."""
    spec = cnn_zoo.get("alexnet")
    res = select(spec, provider)
    for fixed in ("im2col-copy-ab-ki", "direct-sum2d", "mec-col"):
        assignment = {}
        for i, node in enumerate(spec.nodes):
            assignment[i] = fixed if hasattr(node, "k") else "chw"
        cost_fixed = network_cost(spec, assignment, provider)
        assert res.solver_cost <= cost_fixed + 1e-12


def test_model_provider_selection_near_optimal():
    """A perfect 'model' (the noiseless simulator) must reproduce the
    measured-cost selection exactly; Fig 7's gap comes only from estimation
    error."""
    truth = SimulatedProvider("intel", noisy=True)
    perfect = SimulatedProvider("intel", noisy=False)
    spec = cnn_zoo.get("alexnet")
    sel = select(spec, perfect)
    c_model = network_cost(spec, sel.assignment, truth)
    c_truth = select(spec, truth).solver_cost
    assert c_model <= c_truth * 1.05


def test_build_pbqp_edge_costs_are_dlt_times(provider):
    spec = cnn_zoo.get("alexnet")
    g = build_pbqp(spec, provider)
    # identity layout transitions must cost 0 on some edge pair
    m = g.adj[0][1]
    names = provider.columns
    i = names.index("im2col-copy-ab-ki")     # chw -> chw
    j = names.index("im2col-scan-ab-ki")     # chw in
    assert m[i, j] == 0.0
    k = names.index("im2col-copy-atb-ik")    # hwc out
    assert m[k, j] > 0.0                     # hwc -> chw costs time


def test_network_cost_prebuilt_graph_matches_and_requires_source(provider):
    spec = cnn_zoo.get("alexnet")
    sel = select(spec, provider)
    g = build_pbqp(spec, provider)
    direct = network_cost(spec, sel.assignment, provider)
    assert network_cost(spec, sel.assignment, graph=g) == pytest.approx(direct)
    # a prebuilt graph amortises O(build) across a Fig-7 scoring loop
    for _ in range(3):
        assert network_cost(spec, sel.assignment, graph=g) == pytest.approx(direct)
    with pytest.raises(TypeError):
        network_cost(spec, sel.assignment)


def test_model_provider_column_subset(provider):
    from repro.core.perfmodel import fit_perf_model
    from repro.profiler.dataset import simulate_primitive_dataset, simulate_dlt_dataset
    ds = simulate_primitive_dataset("intel", max_triplets=12)
    dlt = simulate_dlt_dataset("intel")
    m = fit_perf_model("lin", ds.feats, ds.times, ds.feats[:4], ds.times[:4],
                       columns=ds.columns)
    md = fit_perf_model("lin", dlt.feats, dlt.times, dlt.feats[:2], dlt.times[:2],
                        columns=dlt.columns)
    sub = ["im2col-copy-ab-ki", "direct-sum2d", "winograd-2x2-3x3"]
    prov = ModelProvider(m, md, columns=sub)
    assert prov.columns == sub
    cfgs = np.array([[16, 8, 14, 1, 3], [32, 16, 7, 2, 5]], float)
    full = ModelProvider(m, md).primitive_cost_matrix(cfgs)
    part = prov.primitive_cost_matrix(cfgs)
    cols = [list(m.columns).index(c) for c in sub]
    np.testing.assert_allclose(part, full[:, cols])
    with pytest.raises(ValueError):
        ModelProvider(m, md, columns=["no-such-primitive"])
