"""End-to-end primitive selection (paper Fig 2 pipeline)."""
import numpy as np
import pytest

from repro.core import pbqp
from repro.core.selection import (ModelProvider, SimulatedProvider, build_pbqp,
                                  network_cost, select)
from repro.models import cnn_zoo
from repro.primitives.conv import REGISTRY


@pytest.fixture(scope="module")
def provider():
    return SimulatedProvider("intel")


def test_selection_runs_all_paper_networks(provider):
    for net in cnn_zoo.PAPER_SELECTION_NETS:
        spec = cnn_zoo.get(net)
        res = select(spec, provider)
        assert res.optimal, net            # reductions stay exact on these DAGs
        assert np.isfinite(res.solver_cost) and res.solver_cost > 0
        # every conv node got an applicable primitive
        for i, node in enumerate(spec.nodes):
            if hasattr(node, "k"):
                p = REGISTRY[res.assignment[i]]
                assert p.applicable(*node.config), (net, i)


def test_selection_beats_single_family(provider):
    """The PBQP-selected mix must be at least as fast as forcing every layer
    to the best single always-applicable primitive (the paper's motivation)."""
    spec = cnn_zoo.get("alexnet")
    res = select(spec, provider)
    for fixed in ("im2col-copy-ab-ki", "direct-sum2d", "mec-col"):
        assignment = {}
        for i, node in enumerate(spec.nodes):
            assignment[i] = fixed if hasattr(node, "k") else "chw"
        cost_fixed = network_cost(spec, assignment, provider)
        assert res.solver_cost <= cost_fixed + 1e-12


def test_model_provider_selection_near_optimal():
    """A perfect 'model' (the noiseless simulator) must reproduce the
    measured-cost selection exactly; Fig 7's gap comes only from estimation
    error."""
    truth = SimulatedProvider("intel", noisy=True)
    perfect = SimulatedProvider("intel", noisy=False)
    spec = cnn_zoo.get("alexnet")
    sel = select(spec, perfect)
    c_model = network_cost(spec, sel.assignment, truth)
    c_truth = select(spec, truth).solver_cost
    assert c_model <= c_truth * 1.05


def test_build_pbqp_edge_costs_are_dlt_times(provider):
    spec = cnn_zoo.get("alexnet")
    g = build_pbqp(spec, provider)
    # identity layout transitions must cost 0 on some edge pair
    m = g.adj[0][1]
    names = provider.columns
    i = names.index("im2col-copy-ab-ki")     # chw -> chw
    j = names.index("im2col-scan-ab-ki")     # chw in
    assert m[i, j] == 0.0
    k = names.index("im2col-copy-atb-ik")    # hwc out
    assert m[k, j] > 0.0                     # hwc -> chw costs time
