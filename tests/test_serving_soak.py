"""Serving soak: concurrent submitters × three networks × a drifting
platform (DESIGN.md §8). One sustained run asserting the system-level
invariants that unit tests cannot see:

  * zero lost tickets — every accepted submission finishes with a result,
    every overflow submission is a marked rejection, nothing hangs;
  * zero duplicated tickets — served image count equals accepted ticket
    count exactly (a double-dispatched ticket would inflate it);
  * generations are monotonic, and each drift recalibration is a real
    hot-swap (generation == recalibrations) observed by later traffic;
  * the recalibration calibrated from served observations (§8.5), not a
    fresh profiling pass, once the buffer had coverage.

Submitters run closed-loop (submit a burst, wait for it) so the soak
exercises concurrency without saturating the CI host — an open-loop flood
would bury the drift signal under multi-second queueing contention.
"""
import threading
import time

import numpy as np
import pytest

from repro.service import (OptimisedNetwork, OptimisedServer,
                           make_recalibrator, optimise)
from repro.service.platforms import SimulatedPlatform


class _DriftingServer(OptimisedServer):
    """Emulates the serving machine slowing down by the network platform's
    ``time_scale`` (sleep proportional to the excess), so observed per-image
    latency rises unambiguously above any contention noise."""

    def _run_plan(self, opt, xs, weights):
        out = super()._run_plan(opt, xs, weights)
        scale = getattr(opt.platform, "time_scale", 1.0) or 1.0
        if scale != 1.0:
            time.sleep(0.03 * xs.shape[0] * (scale - 1.0))
        return out


@pytest.fixture(scope="module")
def soak_setup():
    platform = SimulatedPlatform("arm", max_triplets=16)
    opt = optimise("edge_cnn", platform, executable=True, max_iters=250)
    from repro.primitives.plan import heuristic_assignment
    spec = opt.spec
    variants = [OptimisedNetwork.from_assignment(
        spec, heuristic_assignment(spec), net=f"edge_cnn@{tag}",
        predicted_cost_s=opt.predicted_cost_s) for tag in ("b", "c")]
    return platform, opt, variants


def test_soak_no_lost_tickets_monotonic_generations(soak_setup):
    platform, opt, variants = soak_setup
    platform.time_scale = 1.0          # module fixture: ensure clean start
    platform.invalidate_datasets()
    from repro.primitives.executor import make_weights
    weights = make_weights(opt.spec)

    server = _DriftingServer(
        max_batch=4, latency_budget_ms=1e9, workers=3, max_wait_ms=2.0,
        queue_depth=10_000, drift_threshold=1.5, drift_alpha=0.5,
        drift_calib_obs=2,
        recalibrate=make_recalibrator(sample_n=12, mode="factor"))
    server.register(opt, weights=weights)
    for v in variants:
        server.register(v, weights=weights)
    nets = [opt.net] + [v.net for v in variants]

    n0 = opt.spec.nodes[0]
    rng = np.random.default_rng(7)
    images = [rng.standard_normal((n0.c, n0.im, n0.im)).astype(np.float32)
              for _ in range(8)]       # shared read-only request pool

    stop = threading.Event()
    tickets = {net: [] for net in nets}
    t_lock = threading.Lock()

    def submitter(net, seed):
        """Closed loop: submit a burst of 4, wait for it, repeat."""
        local = []
        r = np.random.default_rng(seed)
        while not stop.is_set() and len(local) < 3000:
            burst = [server.submit(net, images[r.integers(len(images))])
                     for _ in range(4)]
            local.extend(burst)
            for t in burst:
                t.wait(30.0)
        with t_lock:
            tickets[net].extend(local)

    generations = []

    def sampler():
        while not stop.is_set():
            generations.append(server.stats(opt.net)["generation"])
            time.sleep(0.003)

    threads = [threading.Thread(target=submitter, args=(net, 10 + i))
               for i, net in enumerate(nets)]
    threads.append(threading.Thread(target=sampler))
    for th in threads:
        th.start()

    try:
        # healthy phase: run until the drift reference AND the observation
        # buffer are established (clean, post-compile dispatches) — a fixed
        # sleep races bucket compilation on a loaded CI host
        deadline = time.time() + 60.0
        while (server.stats(opt.net)["observed_dispatches"] < 6
               and time.time() < deadline):
            time.sleep(0.05)
        assert server.stats(opt.net)["observed_dispatches"] >= 6, \
            "healthy phase never produced clean observations"
        platform.time_scale = 4.0      # the machine gets 4x slower
        platform.invalidate_datasets()
        deadline = time.time() + 60.0
        while (server.stats(opt.net)["recalibrations"] == 0
               and time.time() < deadline):
            time.sleep(0.05)
    finally:
        stop.set()
        for th in threads:
            th.join(60.0)
        server.stop(timeout=60.0)      # drains every queued ticket
        platform.time_scale = 1.0
        platform.invalidate_datasets()

    # -- zero lost tickets: everything is finished, nothing hangs ----------
    all_tickets = [t for net in nets for t in tickets[net]]
    assert all_tickets, "soak submitted nothing"
    assert all(t.wait(30.0) for t in all_tickets)
    accepted = [t for t in all_tickets if not t.rejected]
    rejected = [t for t in all_tickets if t.rejected]
    assert all(t.done and t.error is None and t.result is not None
               for t in accepted)
    assert all(t.done and t.result is None for t in rejected)

    # -- zero duplicated tickets: served images == accepted submissions ----
    stats = {net: server.stats(net) for net in nets}
    assert sum(s["images"] for s in stats.values()) == len(accepted)
    assert sum(s["rejected"] for s in stats.values()) == len(rejected)

    # -- drift was detected and every recalibration was a real hot-swap ----
    # (≥ 1: post-swap timing noise on a contended CI host may legitimately
    # open a second excursion during the shutdown drain)
    st = stats[opt.net]
    assert st["recalibrations"] >= 1, f"no recalibration: {st}"
    assert st["generation"] == st["recalibrations"]
    assert st["last_recal_error"] is None
    for v in variants:                 # undrifted nets untouched
        assert stats[v.net]["recalibrations"] == 0
        assert stats[v.net]["generation"] == 0

    # -- §8.5: the recalibration sample came (mostly) from served traffic --
    assert st["recal_sample"] is not None
    assert st["recal_sample"]["served_fraction"] >= 0.5

    # -- generations monotonic, and the swap is visible to later traffic ---
    assert generations == sorted(generations)
    out = server.serve(opt.net, [images[0], images[1]])
    assert all(r is not None for r in out)
    assert server.stats(opt.net)["generation"] >= st["generation"]
