"""Compiled whole-graph plan vs the interpreted executor (DESIGN.md §6):
DAG equivalence on chain/concat/add topologies (incl. the centre-crop
branch-mismatch case), batch semantics, DLT fusion, and cache bounds."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import cnn_zoo
from repro.models.cnn_zoo import CNNSpec, JoinNode
from repro.primitives import executor, layouts as L
from repro.primitives.conv import REGISTRY, RUNNABLE, batch_impl, run_primitive
from repro.primitives.executor import clear_jit_cache, execute, make_weights
from repro.primitives.plan import (clear_plan_cache, compile_plan,
                                   fused_dlt_count, heuristic_assignment as
                                   heuristic, lower)


def zoo_prefix(net: str) -> CNNSpec:
    """Truncate a zoo spec just past its first join node — a real zoo
    topology at testable cost (builder specs are topo-ordered by index)."""
    spec = cnn_zoo.get(net)
    stop = next(i for i, n in enumerate(spec.nodes) if isinstance(n, JoinNode))
    keep = stop + 1
    edges = [(u, v) for (u, v) in spec.edges if u < keep and v < keep]
    return CNNSpec(f"{net}[:{keep}]", spec.nodes[:keep], edges)


def _assert_all_nodes_close(spec, asg, weights, x=None, rtol=2e-3, atol=2e-3):
    ri = execute(spec, asg, weights, x=x, compiled=False)
    rc = execute(spec, asg, weights, x=x)
    assert set(rc.outputs) == set(ri.outputs)
    for i in ri.outputs:
        np.testing.assert_allclose(np.asarray(rc.outputs[i]),
                                   np.asarray(ri.outputs[i]),
                                   rtol=rtol, atol=atol, err_msg=f"node {i}")


def test_plan_matches_interpreted_chain(rng):
    """alexnet (zoo chain) under a mixed assignment, reduced input size."""
    spec = cnn_zoo.get("alexnet")
    asg = {0: "im2col-copy-ab-ki", 1: "mec-col", 2: "winograd-2x2-3x3",
           3: "kn2row", 4: "direct-sum2d"}
    w = make_weights(spec)
    x = jnp.asarray(rng.standard_normal((3, 64, 64)), jnp.float32) * 0.1
    _assert_all_nodes_close(spec, asg, w, x=x)


def test_plan_matches_interpreted_concat_crop(rng):
    """squeezenet (zoo concat DAG): 1x1/3x3 fire branches shrink by
    different amounts, exercising the centre-crop path at every join."""
    spec = cnn_zoo.get("squeezenet")
    asg = heuristic(spec)
    w = make_weights(spec)
    x = jnp.asarray(rng.standard_normal((3, 96, 96)), jnp.float32) * 0.1
    _assert_all_nodes_close(spec, asg, w, x=x)


def test_plan_matches_interpreted_add(rng):
    """resnet18 prefix (zoo residual-add incl. downsample shortcut)."""
    spec = zoo_prefix("resnet18")
    assert any(isinstance(n, JoinNode) and n.kind == "add" for n in spec.nodes)
    asg = heuristic(spec)
    w = make_weights(spec)
    x = jnp.asarray(rng.standard_normal((3, 48, 48)), jnp.float32) * 0.1
    _assert_all_nodes_close(spec, asg, w, x=x)


def test_plan_matches_interpreted_mixed_layouts(rng):
    """edge_cnn with hwc-output primitives forcing non-identity fused DLTs
    on concat, add, and conv edges."""
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic(spec)
    # hwc producers into chw joins and chw consumers
    asg[2] = "conv-1x1-gemm-atb-ik"       # exp1: hwc out
    asg[3] = "im2col-copy-atb-ik"         # exp3: hwc out
    asg[5] = "im2row-copy-ab-ik"          # hwc in, hwc out
    asg[4] = "hwc"                        # concat join in hwc
    w = make_weights(spec)
    steps, _ = lower(spec, asg)
    eliminated, inlined = fused_dlt_count(steps)
    assert inlined > 0                    # the fusion path is actually hit
    _assert_all_nodes_close(spec, asg, w)


def test_plan_random_input_matches_interpreted():
    """No explicit x: both paths must draw identical source inputs."""
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic(spec)
    w = make_weights(spec)
    _assert_all_nodes_close(spec, asg, w, x=None)


def test_plan_batch_consistency(rng):
    """A batch-n dispatch equals n stacked single-image dispatches."""
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic(spec)
    w = make_weights(spec)
    plan = compile_plan(spec, asg, outputs="sinks")
    sink = plan.sinks[-1]
    xb = jnp.asarray(rng.standard_normal((3, 3, 32, 32)), jnp.float32)
    ob = plan(xb, w)[sink]
    assert ob.shape[0] == 3
    for b in range(3):
        o1 = plan(xb[b:b + 1], w)[sink]
        np.testing.assert_allclose(np.asarray(ob[b]), np.asarray(o1[0]),
                                   rtol=2e-3, atol=2e-3)


def test_plan_cache_reuse_and_keying():
    clear_plan_cache()
    spec = cnn_zoo.get("edge_cnn")
    asg = heuristic(spec)
    p1 = compile_plan(spec, asg, (4, 3, 32, 32))
    p2 = compile_plan(spec, asg, (4, 3, 32, 32))
    p3 = compile_plan(spec, asg, (8, 3, 32, 32))
    assert p1 is p2                       # cache hit on identical key
    assert p1 is not p3                   # batch shape participates in key
    clear_plan_cache()
    assert compile_plan(spec, asg, (4, 3, 32, 32)) is not p1


def test_plan_rejects_simulated_only():
    spec = cnn_zoo.get("alexnet")
    asg = heuristic(spec)
    asg[2] = "im2col-copy-atb-ki"         # impl=None registry entry
    with pytest.raises(ValueError, match="simulated-only"):
        compile_plan(spec, asg)


def test_batched_impls_match_stacked_singles(rng):
    """Every runnable impl is rank-polymorphic: batch call == stacked
    single-image calls (the plan compiler's batched entry point)."""
    cases = [(4, 3, 12, 1, 3), (5, 2, 9, 1, 1), (6, 4, 11, 2, 3),
             (3, 2, 13, 1, 5)]
    for name in RUNNABLE:
        p = REGISTRY[name]
        fn = batch_impl(p)
        for (k, c, im, s, f) in cases:
            if not p.applicable(k, c, im, s, f):
                continue
            xb = jnp.asarray(rng.standard_normal((2, c, im, im)), jnp.float32)
            w = jnp.asarray(rng.standard_normal((k, c, f, f)), jnp.float32)
            got = L.to_chw(fn(L.from_chw(xb, p.in_layout), w, s), p.out_layout)
            ref = jnp.stack([run_primitive(name, xb[b], w, s) for b in range(2)])
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"{name} {(k, c, im, s, f)}")
            break                          # one applicable case per primitive


def test_batched_layout_transforms(rng):
    x = jnp.asarray(rng.standard_normal((2, 3, 5, 5)), jnp.float32)
    for src in L.LAYOUTS:
        for dst in L.LAYOUTS:
            xb = L.from_chw(x, src)
            yb = L.transform(xb, src, dst)
            per_img = jnp.stack([L.transform(xb[i], src, dst) for i in range(2)])
            np.testing.assert_allclose(yb, per_img)
            np.testing.assert_allclose(L.to_chw(yb, dst), x)
    # permutation algebra used by DLT fusion
    for a in L.LAYOUTS:
        for b in L.LAYOUTS:
            for c in L.LAYOUTS:
                composed = L.compose(L.perm(a, b), L.perm(b, c))
                assert composed == L.perm(a, c)
    assert L.is_identity(L.perm("hcw", "hcw"))


def test_jit_cache_lru_cap():
    clear_jit_cache()
    for i in range(executor._JIT_CACHE_CAP + 40):
        executor._cached(("fake", i), lambda: (lambda: None))
    assert len(executor._JIT_CACHE) == executor._JIT_CACHE_CAP
    # oldest entries evicted, newest retained
    assert ("fake", 0) not in executor._JIT_CACHE
    assert ("fake", executor._JIT_CACHE_CAP + 39) in executor._JIT_CACHE
    # a re-touched entry survives the next evictions
    executor._cached(("fake", 50), lambda: (lambda: None))
    executor._cached(("fake2", 0), lambda: (lambda: None))
    assert ("fake", 50) in executor._JIT_CACHE
    clear_jit_cache()
    assert len(executor._JIT_CACHE) == 0
