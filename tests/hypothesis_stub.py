"""Fallback for environments without the ``hypothesis`` dev extra.

Lets test modules keep their deterministic tests runnable while property
tests (@given) collect as skipped instead of breaking the whole module at
import time. Usage:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from hypothesis_stub import given, settings, st
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategies:
    """Stand-in so module-level strategy expressions evaluate to inert None."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _Strategies()
