"""Fallback for environments without the ``hypothesis`` dev extra.

Lets test modules keep their deterministic tests runnable while property
tests (@given) collect as skipped instead of breaking the whole module at
import time. Usage:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from hypothesis_stub import given, settings, st

A stubbed skip is NOT a pass: every ``@given`` test routed through this
module is counted and surfaced by ``conftest.pytest_terminal_summary`` as
its own summary line, so a local green run visibly reports how much
property coverage it did not exercise. CI installs the real engine
(requirements-dev.txt) and never imports this module.
"""
import pytest

# test functions stubbed into skips this run — read by conftest.py for the
# terminal summary line
STUBBED = []

_MARK = pytest.mark.skip(
    reason="hypothesis not installed — property test stubbed, not run")


def given(*_args, **_kwargs):
    def deco(fn):
        STUBBED.append(getattr(fn, "__name__", str(fn)))
        return _MARK(fn)
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategies:
    """Stand-in so module-level strategy expressions evaluate to inert None."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _Strategies()
