"""PBQP solver: property tests against the brute-force oracle."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # property tests need the dev extra
    from hypothesis_stub import given, settings, st

from repro.core.pbqp import PBQPGraph, brute_force, evaluate, solve


def _random_graph(rng, n, max_choices=4, p_inf=0.3, extra_edges=None):
    g = PBQPGraph()
    sizes = rng.integers(2, max_choices + 1, size=n)
    for i in range(n):
        c = rng.uniform(0, 10, sizes[i])
        if rng.random() < p_inf:
            c[rng.integers(0, sizes[i])] = np.inf
        if not np.isfinite(c).any():
            c[0] = 1.0
        g.add_node(i, c)
    for i in range(n - 1):
        g.add_edge(i, i + 1, rng.uniform(0, 5, (sizes[i], sizes[i + 1])))
    extra = rng.integers(0, n) if extra_edges is None else extra_edges
    for _ in range(extra):
        u, v = rng.integers(0, n, 2)
        if u != v:
            g.add_edge(u, v, rng.uniform(0, 5, (sizes[u], sizes[v])))
    return g


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 6))
def test_matches_brute_force(seed, n):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, n)
    sol = solve(g)
    ref = brute_force(g)
    if sol.optimal:
        assert np.isclose(sol.cost, ref.cost), (sol.cost, ref.cost)
    else:  # heuristic RN used: never better than optimal
        assert sol.cost >= ref.cost - 1e-9
    assert np.isclose(evaluate(g, sol.assignment), sol.cost)


def test_chain_is_exact_and_fast():
    rng = np.random.default_rng(0)
    g = PBQPGraph()
    for i in range(200):
        g.add_node(i, rng.uniform(0, 10, 5))
    for i in range(199):
        g.add_edge(i, i + 1, rng.uniform(0, 5, (5, 5)))
    sol = solve(g)
    assert sol.optimal


def test_diamond_reduces_exactly():
    """Split/join (inception-style) graphs reduce via RII + parallel-edge
    merge — no heuristic."""
    rng = np.random.default_rng(1)
    g = PBQPGraph()
    for i in range(4):
        g.add_node(i, rng.uniform(0, 10, 3))
    g.add_edge(0, 1, rng.uniform(0, 5, (3, 3)))
    g.add_edge(0, 2, rng.uniform(0, 5, (3, 3)))
    g.add_edge(1, 3, rng.uniform(0, 5, (3, 3)))
    g.add_edge(2, 3, rng.uniform(0, 5, (3, 3)))
    sol = solve(g)
    ref = brute_force(g)
    assert sol.optimal and np.isclose(sol.cost, ref.cost)


def test_inapplicable_choice_never_selected():
    g = PBQPGraph()
    g.add_node("a", np.array([np.inf, 5.0]))
    g.add_node("b", np.array([1.0, np.inf, 2.0]))
    g.add_edge("a", "b", np.ones((2, 3)))
    sol = solve(g)
    assert sol.assignment["a"] == 1
    assert sol.assignment["b"] != 1
    assert np.isfinite(sol.cost)


def test_all_inf_node_rejected():
    g = PBQPGraph()
    with pytest.raises(ValueError):
        g.add_node("x", np.array([np.inf, np.inf]))
