"""Crash-consistency property tests for ArtifactStore over its pluggable
backends (DESIGN.md §14.1–§14.2).

Backend-parametrised (local directory AND simulated object store): injected
fault schedules kill a writer between the staged upload and the manifest
commit, tear a payload write in half, and re-publish after an ambiguous
ack — asserting the §14.2 invariants:

* a reader NEVER observes a partial entry: every get returns a complete
  committed value or None/the previous value — never bytes mid-write;
* an interrupted overwrite never destroys the existing entry;
* ``sweep()`` collects every orphan (staged uploads no manifest names,
  corrupt entries) and nothing live.

The deterministic schedules run everywhere; the @given tests drive random
fault interleavings through the same invariants when the real hypothesis
engine is installed (CI). Locally-stubbed runs report the skip count in
the pytest summary (conftest.pytest_terminal_summary).
"""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_stub import given, settings, st

from repro.service import (ArtifactStore, BackendError, LocalDirBackend,
                           ObjectStoreBackend, ScriptedFaults)
from repro.service.store_backends import StoreBackend


class FaultyBackend(StoreBackend):
    """Fault-hook wrapper making ANY backend crash-testable — the object
    store has native hooks, the local directory gets them here, and both
    run the identical suite."""

    def __init__(self, inner, faults=None):
        self.inner = inner
        self.faults = faults

    def _act(self, op, key):
        action = self.faults(op, key) if self.faults else None
        if action == "raise":
            raise BackendError(f"injected: {op} {key}")
        return action

    def put(self, key, data):
        action = self._act("put", key)
        if action == "torn":
            self.inner.put(key, bytes(data)[:max(1, len(data) // 2)])
            raise BackendError(f"injected: torn put {key}")
        self.inner.put(key, data)
        if action == "raise_after":
            raise BackendError(f"injected: late ack {key}")

    def get(self, key):
        if self._act("get", key) == "lost":
            return None
        return self.inner.get(key)

    def get_stream(self, key, chunk_size=1 << 20):
        if self._act("get", key) == "lost":
            return None
        return self.inner.get_stream(key, chunk_size)

    def list(self, prefix=""):
        self._act("list", prefix)
        return self.inner.list(prefix)

    def delete(self, key):
        self._act("delete", key)
        return self.inner.delete(key)

    def delete_prefix(self, prefix):
        return self.inner.delete_prefix(prefix)

    def mtime(self, key):
        return self.inner.mtime(key)

    def local_path(self, key):
        return self.inner.local_path(key)


def _make_inner(kind, tmp_path):
    if kind == "local":
        return LocalDirBackend(str(tmp_path / "store"))
    return ObjectStoreBackend()


@pytest.fixture(params=["local", "object"])
def backend_kind(request):
    return request.param


def _store(kind, tmp_path, faults=None):
    wrapped = FaultyBackend(_make_inner(kind, tmp_path), faults)
    return ArtifactStore(backend=wrapped), wrapped


def _entry_keys(backend, category="selections"):
    # drop the local backend's empty-directory pseudo-keys: only real
    # objects count as store contents
    return [k for k in backend.inner.list(f"{category}/")
            if not k.endswith("/")]


# ---------------------------------------------------------------------------
# Backend basics
# ---------------------------------------------------------------------------

def test_backend_roundtrip_list_stream_delete(backend_kind, tmp_path):
    b = _make_inner(backend_kind, tmp_path)
    assert b.get("a/b") is None and b.get_stream("a/b") is None
    b.put("a/b", b"xy" * 600)
    b.put("a/c", b"z")
    assert b.get("a/b") == b"xy" * 600
    assert b"".join(b.get_stream("a/b", chunk_size=7)) == b"xy" * 600
    assert b.list("a/") == ["a/b", "a/c"]
    assert b.mtime("a/b") is not None
    assert b.delete("a/b") and not b.delete("a/b")
    assert b.list("a/") == ["a/c"]
    assert b.delete_prefix("a/") == 1
    assert b.list() == []


def test_object_backend_share_and_native_faults():
    """Host views share one bucket; a view's fault schedule is its own."""
    a = ObjectStoreBackend()
    b = a.share(faults=ScriptedFaults([("get", "lost")]))
    a.put("k", b"v")
    assert b.get("k") is None          # this view's injected loss...
    assert b.get("k") == b"v"          # ...fires exactly once
    assert a.get("k") == b"v"          # the sibling view never saw it
    with pytest.raises(BackendError):
        ObjectStoreBackend(faults=ScriptedFaults([("put", "raise")])).put(
            "x", b"1")


# ---------------------------------------------------------------------------
# Crash schedules: staged-upload-then-manifest-commit invariants
# ---------------------------------------------------------------------------

def test_crash_between_stage_and_commit_is_invisible(backend_kind, tmp_path):
    faults = ScriptedFaults([(("put", "manifest.json"), "raise")])
    store, backend = _store(backend_kind, tmp_path, faults)
    with pytest.raises(OSError):
        store.put_json("selections", {"k": 1}, {"v": 1})
    # the staged payload landed, the commit did not: nothing is readable
    assert store.get_json("selections", {"k": 1}) is None
    assert store.entries("selections") == []
    staged = _entry_keys(backend)
    assert staged and all("stage." in k for k in staged)
    # sweep collects the orphan (grace disabled so age is irrelevant)
    store.sweep(grace_s=-1.0)
    assert _entry_keys(backend) == []


def test_torn_payload_write_is_invisible_and_swept(backend_kind, tmp_path):
    faults = ScriptedFaults([(("put", "stage."), "torn")])
    store, backend = _store(backend_kind, tmp_path, faults)
    with pytest.raises(OSError):
        store.put_json("selections", {"k": "torn"}, {"v": list(range(64))})
    assert store.get_json("selections", {"k": "torn"}) is None
    store.sweep(grace_s=-1.0)
    assert _entry_keys(backend) == []


def test_interrupted_overwrite_keeps_old_entry(backend_kind, tmp_path):
    """A duplicate publish that dies mid-write must not destroy the live
    entry: the old manifest still names the old payload."""
    store, backend = _store(backend_kind, tmp_path)
    fields = {"k": "stable"}
    store.put_json("selections", fields, {"v": "old"})
    for schedule in ([(("put", "stage."), "torn")],
                     [(("put", "stage."), "raise")],
                     [(("put", "manifest.json"), "raise")]):
        backend.faults = ScriptedFaults(schedule)
        with pytest.raises(OSError):
            store.put_json("selections", fields, {"v": "new"})
        backend.faults = None
        assert store.get_json("selections", fields) == {"v": "old"}
    # GC reaps every failed attempt's leftovers; the entry survives
    assert store.sweep(grace_s=-1.0) == 0
    assert store.get_json("selections", fields) == {"v": "old"}
    rest = _entry_keys(backend)
    assert len(rest) == 2              # manifest + its one live payload
    # and a clean retry finally lands the new value
    store.put_json("selections", fields, {"v": "new"})
    assert store.get_json("selections", fields) == {"v": "new"}


def test_duplicate_publish_after_ambiguous_ack(backend_kind, tmp_path):
    """An ack lost after the commit landed (raise_after) forces a retry of
    an already-complete publish; the retry is idempotent and readers see a
    complete value throughout."""
    faults = ScriptedFaults([(("put", "manifest.json"), "raise_after")])
    store, backend = _store(backend_kind, tmp_path, faults)
    fields = {"k": "dup"}
    with pytest.raises(OSError):
        store.put_json("selections", fields, {"v": 7})
    # the commit actually landed — the entry is already complete
    assert store.get_json("selections", fields) == {"v": 7}
    store.put_json("selections", fields, {"v": 7})          # blind retry
    assert store.get_json("selections", fields) == {"v": 7}
    assert len(store.entries("selections")) == 1
    store.sweep(grace_s=-1.0)
    assert store.get_json("selections", fields) == {"v": 7}
    assert len(_entry_keys(backend)) == 2


def test_corrupt_payload_parametrised_sweep(backend_kind, tmp_path):
    """The PR-3 truncated-artifact test, generalised over backends: corrupt
    the committed payload bytes through the backend — the entry turns
    invisible and sweep() counts exactly it."""
    store, backend = _store(backend_kind, tmp_path)
    store.put_json("selections", {"k": "good"}, {"v": 1})
    store.put_json("selections", {"k": "bad"}, {"v": 2})
    from repro.service.artifacts import digest
    key = digest({"k": "bad"})
    man = json.loads(backend.get(f"selections/{key}/manifest.json").decode())
    backend.put(f"selections/{key}/{man['payload']}", b'{"v":')
    assert store.get_json("selections", {"k": "bad"}) is None
    assert store.get_json("selections", {"k": "good"}) == {"v": 1}
    assert store.sweep() == 1
    assert store.get_json("selections", {"k": "good"}) == {"v": 1}
    assert len(store.entries("selections")) == 1
    assert backend.get(f"selections/{key}/manifest.json") is None


def test_get_or_train_survives_backend_outage(backend_kind, tmp_path):
    """The caching-failures-cost-the-cache contract extends to backends: a
    store whose backend raises on every op never loses a trained model."""
    def down(op, key):
        return "raise"
    store, _ = _store(backend_kind, tmp_path, down)
    calls = []

    def train():
        calls.append(1)
        return _tiny_model()

    m1, warm1 = store.get_or_train({"k": 1}, train)
    m2, warm2 = store.get_or_train({"k": 1}, train)
    assert (warm1, warm2) == (False, False) and len(calls) == 2
    assert m1 is not None and m2 is not None


def test_dataset_roundtrip_through_object_store(tmp_path):
    """npz payloads spool through the streaming read on a pathless backend."""
    from repro.profiler.dataset import PerfDataset
    store = ArtifactStore(backend=ObjectStoreBackend())
    ds = PerfDataset(np.arange(10.0).reshape(5, 2),
                     np.arange(15.0).reshape(5, 3) * 1e-6,
                     ["a", "b", "c"], ["x", "y"], "arm")
    store.put_dataset({"d": 1}, ds)
    back = store.get_dataset({"d": 1})
    assert back is not None and back.fingerprint() == ds.fingerprint()


def test_retention_sweep_on_object_store():
    store = ArtifactStore(backend=ObjectStoreBackend(), keep=2)
    for i in range(6):
        store.put_json("selections", {"i": i}, {"i": i})
    kept = {e["fields"]["i"] for e in store.entries("selections")}
    assert kept == {4, 5}


def _tiny_model(seed=0):
    from repro.core.perfmodel import fit_perf_model
    rng = np.random.default_rng(seed)
    f = np.exp(rng.uniform(0, 3, (60, 5)))
    t = np.exp(np.log(f) @ rng.uniform(0.5, 2.0, (5, 3))) * 1e-6
    return fit_perf_model("lin", f[:40], t[:40], f[40:], t[40:])


# ---------------------------------------------------------------------------
# Hypothesis-driven interleavings (real engine in CI; stubbed skips report
# their count in the local pytest summary)
# ---------------------------------------------------------------------------

_ACTIONS = ["ok", "stage_fail", "stage_torn", "manifest_fail", "late_ack",
            "sweep"]


def _schedule_for(action):
    return {
        "ok": [],
        "stage_fail": [(("put", "stage."), "raise")],
        "stage_torn": [(("put", "stage."), "torn")],
        "manifest_fail": [(("put", "manifest.json"), "raise")],
        "late_ack": [(("put", "manifest.json"), "raise_after")],
    }[action]


def _drive(kind, tmp_path, script):
    """Run a publish/sweep script under its fault schedule, asserting after
    EVERY step that each address reads as a complete committed value or
    None — never a partial, never an exception — and at the end that sweep
    leaves exactly the live entries' keys."""
    store, backend = _store(kind, tmp_path)
    committed = {}                     # addr -> set of acceptable values
    for step, (addr, action) in enumerate(script):
        fields = {"addr": addr}
        if action == "sweep":
            store.sweep(grace_s=-1.0)
        else:
            backend.faults = ScriptedFaults(_schedule_for(action))
            value = {"addr": addr, "step": step}
            try:
                store.put_json("selections", fields, value)
                committed[addr] = {json.dumps(value, sort_keys=True)}
            except OSError:
                # late_ack means the commit may have landed despite the error
                if action == "late_ack":
                    committed[addr] = {json.dumps(value, sort_keys=True)}
            backend.faults = None
        for a in {a for a, _ in script}:
            got = store.get_json("selections", {"addr": a})
            if a in committed:
                assert got is not None, f"committed {a} unreadable"
                assert json.dumps(got, sort_keys=True) in committed[a], \
                    f"partial/alien value at {a}: {got}"
            else:
                assert got is None, f"uncommitted {a} readable: {got}"
    store.sweep(grace_s=-1.0)
    for a, vals in committed.items():
        got = store.get_json("selections", {"addr": a})
        assert got is not None and json.dumps(got, sort_keys=True) in vals
    keys = _entry_keys(backend)
    assert len(keys) == 2 * len(committed)   # manifest + one payload each
    assert all(("manifest.json" in k) or ("stage." in k) for k in keys)


@given(script=st.lists(st.tuples(st.sampled_from(["p", "q", "r"]),
                                 st.sampled_from(_ACTIONS)),
                       min_size=1, max_size=12))
@settings(max_examples=60, deadline=None)
def test_random_fault_interleavings_local(tmp_path_factory, script):
    _drive("local", tmp_path_factory.mktemp("fuzz"), script)


@given(script=st.lists(st.tuples(st.sampled_from(["p", "q", "r"]),
                                 st.sampled_from(_ACTIONS)),
                       min_size=1, max_size=12))
@settings(max_examples=60, deadline=None)
def test_random_fault_interleavings_object(tmp_path_factory, script):
    _drive("object", tmp_path_factory.mktemp("fuzz"), script)
