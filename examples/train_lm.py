"""Distributed LM training driver over the assigned architectures — the
training-substrate demo: any --arch from the pool, synthetic data pipeline,
AdamW/Adafactor, checkpoint/resume, loss curve.

Run:  PYTHONPATH=src python examples/train_lm.py --arch mixtral_8x7b --steps 50
(reduced config by default; --full uses the real config — sized for the
production mesh, not this CPU).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs import base as cb
from repro.data.lm import synthetic_batches
from repro.launch import steps as ST
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3_6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_example")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = cb.get(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params~{cfg.n_params()/1e6:.1f}M")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_name, opt = ST.optimizer_for(cfg)
    opt_state = opt.init(params)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    start, restored = mgr.restore_latest(jax.eval_shape(lambda: (params, opt_state)))
    if start is not None:
        params, opt_state = restored
        print(f"resumed from step {start}")
    start = start or 0

    step_fn = jax.jit(ST.make_train_step(cfg, opt))
    t0 = time.time()
    for step, batch in enumerate(synthetic_batches(
            cfg, args.batch, args.seq, seed=start), start=start + 1):
        if step > args.steps:
            break
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == start + 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({(time.time()-t0):.1f}s)")
        if step % 25 == 0:
            mgr.save(step, (params, opt_state))
            print(f"   checkpointed step {step}")
    mgr.save(min(args.steps, step), (params, opt_state))
    print("done.")


if __name__ == "__main__":
    main()
