"""Quickstart: the paper's pipeline end to end through the service layer.

  1. a Platform profiles itself (simulated intel) and trains NN2 performance
     models — one ``pretrain`` call,
  2. ``optimise`` PBQP-selects primitives for AlexNet from *predictions*,
  3. compare against selecting from measured (simulated ground-truth) costs.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.selection import build_pbqp, network_cost, select
from repro.service import get_platform, optimise


def main():
    print("== 1. profiling + training (simulated intel platform) ==")
    intel = get_platform("intel", max_triplets=60)
    ds = intel.primitive_dataset()
    print(f"   {ds.n} layer configs x {len(ds.columns)} primitives")
    models = intel.pretrain("nn2", max_iters=4000,
                            dlt_kind="nn2", dlt_max_iters=2500)
    _, _, te = ds.split()
    _, _, dte = intel.dlt_dataset().split()
    print(f"   primitive MdRAE: {models.prim.mdrae(te.feats, te.times)*100:.1f}%  "
          f"DLT MdRAE: {models.dlt.mdrae(dte.feats, dte.times)*100:.1f}%  "
          f"({models.seconds:.1f}s)")

    print("== 2. primitive selection from PREDICTED costs ==")
    t0 = time.perf_counter()
    opt = optimise("alexnet", intel, models=models)
    print(f"   selection took {(time.perf_counter()-t0)*1e3:.0f} ms "
          f"(optimal solve: {opt.selection.optimal})")
    for i, layer in enumerate(opt.spec.nodes):
        print(f"   {layer.name:18s} k={layer.k:4d} c={layer.c:4d} im={layer.im:3d} "
              f"-> {opt.assignment[i]}")

    print("== 3. quality vs selecting from measured costs ==")
    truth = intel.cost_provider()
    g_truth = build_pbqp(opt.spec, truth)
    c_model = network_cost(opt.spec, opt.assignment, graph=g_truth)
    c_truth = select(opt.spec, truth).solver_cost
    print(f"   measured-optimal: {c_truth*1e3:.3f} ms | model-selected: "
          f"{c_model*1e3:.3f} ms | increase {100*(c_model/c_truth-1):.2f}% "
          f"(paper: <= 1.1%)")


if __name__ == "__main__":
    main()
