"""Quickstart: the paper's pipeline end to end in one script.

  1. build a profiled dataset (platform simulator),
  2. train the NN2 performance model (+ a DLT model),
  3. PBQP-select primitives for AlexNet from *predictions*,
  4. compare against selecting from measured costs.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.perfmodel import fit_perf_model
from repro.core.selection import ModelProvider, SimulatedProvider, network_cost, select
from repro.models import cnn_zoo
from repro.profiler.dataset import simulate_dlt_dataset, simulate_primitive_dataset


def main():
    print("== 1. profiling (simulated intel platform) ==")
    ds = simulate_primitive_dataset("intel", max_triplets=60)
    dlt = simulate_dlt_dataset("intel")
    print(f"   {ds.n} layer configs x {len(ds.columns)} primitives")

    print("== 2. training NN2 performance models ==")
    tr, va, te = ds.split()
    m = fit_perf_model("nn2", tr.feats, tr.times, va.feats, va.times,
                       columns=ds.columns, max_iters=4000)
    dtr, dva, dte = dlt.split()
    md = fit_perf_model("nn2", dtr.feats, dtr.times, dva.feats, dva.times,
                        columns=dlt.columns, max_iters=2500)
    print(f"   primitive MdRAE: {m.mdrae(te.feats, te.times)*100:.1f}%  "
          f"DLT MdRAE: {md.mdrae(dte.feats, dte.times)*100:.1f}%")

    print("== 3. primitive selection from PREDICTED costs ==")
    spec = cnn_zoo.get("alexnet")
    model = ModelProvider(m, md)
    t0 = time.perf_counter()
    sel = select(spec, model)
    print(f"   selection took {(time.perf_counter()-t0)*1e3:.0f} ms "
          f"(optimal solve: {sel.optimal})")
    for i, layer in enumerate(spec.nodes):
        print(f"   {layer.name:18s} k={layer.k:4d} c={layer.c:4d} im={layer.im:3d} "
              f"-> {sel.assignment[i]}")

    print("== 4. quality vs selecting from measured costs ==")
    truth = SimulatedProvider("intel")
    c_model = network_cost(spec, sel.assignment, truth)
    c_truth = select(spec, truth).solver_cost
    print(f"   measured-optimal: {c_truth*1e3:.3f} ms | model-selected: "
          f"{c_model*1e3:.3f} ms | increase {100*(c_model/c_truth-1):.2f}% "
          f"(paper: <= 1.1%)")


if __name__ == "__main__":
    main()
