"""Transfer learning across platforms (paper §4.4/§5.3): pre-train on intel,
port to arm with 1% of the data — direct / factor-corrected / fine-tuned.

Run:  PYTHONPATH=src python examples/transfer_learning.py
"""
from repro.core.perfmodel import factor_correct, fit_perf_model
from repro.profiler.dataset import simulate_primitive_dataset


def main():
    print("== pre-training on intel ==")
    ds_i = simulate_primitive_dataset("intel", max_triplets=60)
    tr, va, te = ds_i.split()
    intel = fit_perf_model("nn2", tr.feats, tr.times, va.feats, va.times,
                           columns=ds_i.columns, max_iters=4000)
    print(f"   intel test MdRAE: {intel.mdrae(te.feats, te.times)*100:.1f}%")

    print("== porting to arm ==")
    ds_a = simulate_primitive_dataset("arm", max_triplets=60)
    tra, vaa, tea = ds_a.split()
    direct = intel.mdrae(tea.feats, tea.times)
    print(f"   intel model applied directly:   MdRAE {direct*100:.0f}%")

    onepct = tra.subsample(0.01)
    fc = factor_correct(intel, onepct.feats, onepct.times)
    print(f"   + per-primitive factor (1% data): MdRAE "
          f"{fc.mdrae(tea.feats, tea.times)*100:.1f}%")

    ft = fit_perf_model("nn2", onepct.feats, onepct.times, vaa.feats, vaa.times,
                        columns=ds_a.columns, base=intel, max_iters=2000)
    print(f"   + fine-tuning      (1% data): MdRAE "
          f"{ft.mdrae(tea.feats, tea.times)*100:.1f}%")

    scratch = fit_perf_model("nn2", onepct.feats, onepct.times, vaa.feats,
                             vaa.times, columns=ds_a.columns, max_iters=2000)
    print(f"   from scratch       (1% data): MdRAE "
          f"{scratch.mdrae(tea.feats, tea.times)*100:.1f}%")

    native = fit_perf_model("nn2", tra.feats, tra.times, vaa.feats, vaa.times,
                            columns=ds_a.columns, max_iters=4000)
    print(f"   native (all data):            MdRAE "
          f"{native.mdrae(tea.feats, tea.times)*100:.1f}%")


if __name__ == "__main__":
    main()
