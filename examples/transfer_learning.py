"""Transfer learning across platforms (paper §4.4/§5.3) through the service
layer: pre-train on intel, port to arm with 1% of the data — direct /
factor-corrected / fine-tuned — and persist every trained model in the
artifact store, so a second invocation warm-starts in milliseconds instead
of retraining (the paper's "porting costs seconds" claim, operational).

Run:  PYTHONPATH=src python examples/transfer_learning.py
      (run it twice to see the warm-start)
"""
import os

from repro.service import ArtifactStore, get_platform


def main():
    store = ArtifactStore(os.environ.get("REPRO_ARTIFACTS", "artifacts"))

    print("== pre-training on intel ==")
    intel = get_platform("intel", max_triplets=60)
    base = intel.pretrain("nn2", store=store, max_iters=4000)
    _, _, te = intel.primitive_dataset().split()
    print(f"   intel test MdRAE: {base.prim.mdrae(te.feats, te.times)*100:.1f}% "
          f"({'warm' if base.warm else 'cold'}, {base.seconds:.2f}s)")

    print("== porting to arm ==")
    arm = get_platform("arm", max_triplets=60)
    _, _, tea = arm.primitive_dataset().split()
    direct = base.prim.mdrae(tea.feats, tea.times)
    print(f"   intel model applied directly:   MdRAE {direct*100:.0f}%")

    fc = arm.calibrate(base, 0.01, mode="factor", store=store)
    print(f"   + per-primitive factor (1% data): MdRAE "
          f"{fc.prim.mdrae(tea.feats, tea.times)*100:.1f}% "
          f"({'warm' if fc.warm else 'cold'}, {fc.seconds:.2f}s)")

    ft = arm.calibrate(base, 0.01, mode="finetune", store=store, max_iters=2000)
    print(f"   + fine-tuning      (1% data): MdRAE "
          f"{ft.prim.mdrae(tea.feats, tea.times)*100:.1f}% "
          f"({'warm' if ft.warm else 'cold'}, {ft.seconds:.2f}s)")

    scratch = arm.calibrate(base, 0.01, mode="scratch", store=store,
                            max_iters=2000)
    print(f"   from scratch       (1% data): MdRAE "
          f"{scratch.prim.mdrae(tea.feats, tea.times)*100:.1f}% "
          f"({'warm' if scratch.warm else 'cold'}, {scratch.seconds:.2f}s)")

    native = arm.pretrain("nn2", store=store, max_iters=4000)
    print(f"   native (all data):            MdRAE "
          f"{native.prim.mdrae(tea.feats, tea.times)*100:.1f}% "
          f"({'warm' if native.warm else 'cold'}, {native.seconds:.2f}s)")

    warm = all(m.warm for m in (base, fc, ft, scratch, native))
    print("== artifact store ==")
    print(f"   {len(store.entries('models'))} models under {store.root!r}; "
          f"this run was {'WARM (no training)' if warm else 'COLD (trained + stored)'}")


if __name__ == "__main__":
    main()
