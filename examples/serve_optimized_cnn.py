"""End-to-end serving driver (the paper's deployment story): take a CNN,
optimise it by primitive selection ON THIS MACHINE (real profiling of the
JAX primitives), then serve batched inference requests with the optimised
implementation and report throughput against a fixed-primitive baseline.

Run:  PYTHONPATH=src python examples/serve_optimized_cnn.py [--requests 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import fit_perf_model
from repro.core.selection import ModelProvider, select
from repro.models.cnn_zoo import CNNSpec, ConvLayer
from repro.primitives.executor import execute, make_weights
from repro.profiler import host


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    args = ap.parse_args()

    spec = CNNSpec("edge-cnn", [
        ConvLayer("c1", 16, 3, 32, 1, 3), ConvLayer("c2", 32, 16, 30, 1, 3),
        ConvLayer("c3", 32, 32, 28, 2, 3), ConvLayer("c4", 64, 32, 13, 1, 1),
        ConvLayer("c5", 64, 64, 13, 1, 3),
    ], [(0, 1), (1, 2), (2, 3), (3, 4)])

    prims = ["im2col-copy-ab-ki", "im2col-scan-ab-ki", "kn2row", "mec-col",
             "winograd-2x2-3x3", "conv-1x1-gemm-ab-ki", "direct-sum2d"]
    print("== profiling primitives on this CPU (the stage the perf model replaces) ==")
    t0 = time.perf_counter()
    pool = sorted({l.config for l in spec.conv_layers} |
                  {(32, 16, 28, 1, 3), (64, 32, 14, 1, 3), (16, 8, 30, 1, 3)})
    ds = host.profile_primitive_dataset(pool, primitives=prims, repeats=5)
    dlt = host.profile_dlt_dataset([(16, 30), (32, 28), (32, 13), (64, 13)], repeats=5)
    print(f"   profiled {ds.n} configs in {time.perf_counter()-t0:.1f}s")

    m = fit_perf_model("nn2", ds.feats, ds.times, ds.feats[:2], ds.times[:2],
                       columns=ds.columns, max_iters=1200, patience=120)
    md = fit_perf_model("lin", dlt.feats, dlt.times, dlt.feats[:1], dlt.times[:1],
                        columns=dlt.columns)
    sel = select(spec, ModelProvider(m, md))
    print("   assignment:", [sel.assignment[i] for i in range(len(spec.conv_layers))])

    weights = make_weights(spec)
    baseline = {i: "direct-sum2d" for i in range(len(spec.conv_layers))}
    rng = np.random.default_rng(0)

    def serve(assignment, tag):
        # warm up (jit compile per layer), then serve the request batch
        execute(spec, assignment, weights)
        t0 = time.perf_counter()
        for _ in range(args.requests):
            x = jnp.asarray(rng.standard_normal((3, 32, 32)), jnp.float32)
            rep = execute(spec, assignment, weights, x=x)
            jax.block_until_ready(rep.outputs[len(spec.nodes) - 1])
        dt = time.perf_counter() - t0
        print(f"   {tag:10s}: {args.requests/dt:7.1f} req/s "
              f"({dt/args.requests*1e3:.2f} ms/req)")
        return dt

    print(f"== serving {args.requests} requests ==")
    t_base = serve(baseline, "baseline")
    t_opt = serve(sel.assignment, "optimised")
    print(f"   speedup: {t_base/t_opt:.2f}x")


if __name__ == "__main__":
    main()
