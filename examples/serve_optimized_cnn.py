"""End-to-end serving driver (the paper's deployment story): take a CNN,
optimise it by primitive selection ON THIS MACHINE (real profiling of the
JAX primitives), then serve batched inference requests through the compiled
whole-graph plan (repro.primitives.plan) and report throughput against a
fixed-primitive baseline.

Batching knob: ``--batch N`` sets the request batch size — the compiled plan
is one jitted function over a leading batch axis, so larger batches amortise
dispatch and let XLA fuse across images; ``--sweep`` prints an images/s curve
over batch sizes 1/4/16 to show throughput scaling with batch size.

Run:  PYTHONPATH=src python examples/serve_optimized_cnn.py [--requests 32]
      [--batch 8] [--sweep]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import fit_perf_model
from repro.core.selection import ModelProvider, select
from repro.models import cnn_zoo
from repro.models.cnn_zoo import ConvLayer
from repro.primitives.executor import make_weights
from repro.primitives.plan import compile_plan
from repro.profiler import host


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16,
                    help="number of request batches per measurement")
    ap.add_argument("--batch", type=int, default=8,
                    help="images per request batch (the batching knob)")
    ap.add_argument("--sweep", action="store_true",
                    help="also sweep batch sizes 1/4/16 on the optimised net")
    args = ap.parse_args()

    spec = cnn_zoo.get("edge_cnn")
    convs = [(i, n) for i, n in enumerate(spec.nodes) if isinstance(n, ConvLayer)]

    prims = ["im2col-copy-ab-ki", "im2col-scan-ab-ki", "kn2row", "mec-col",
             "winograd-2x2-3x3", "conv-1x1-gemm-ab-ki", "direct-sum2d"]
    print("== profiling primitives on this CPU (the stage the perf model replaces) ==")
    t0 = time.perf_counter()
    pool = sorted({n.config for _, n in convs} |
                  {(32, 16, 28, 1, 3), (64, 32, 14, 1, 3), (16, 8, 30, 1, 3)})
    ds = host.profile_primitive_dataset(pool, primitives=prims, repeats=5)
    dlt = host.profile_dlt_dataset([(16, 30), (32, 28), (32, 26), (64, 13)], repeats=5)
    print(f"   profiled {ds.n} configs in {time.perf_counter()-t0:.1f}s")

    m = fit_perf_model("nn2", ds.feats, ds.times, ds.feats[:2], ds.times[:2],
                       columns=ds.columns, max_iters=1200, patience=120)
    md = fit_perf_model("lin", dlt.feats, dlt.times, dlt.feats[:1], dlt.times[:1],
                        columns=dlt.columns)
    sel = select(spec, ModelProvider(m, md))
    print("   assignment:", [sel.assignment[i] for i, _ in convs])

    weights = make_weights(spec)
    baseline = {i: ("conv-1x1-gemm-ab-ki" if n.f == 1 else "direct-sum2d")
                for i, n in convs}
    baseline.update({i: "chw" for i, n in enumerate(spec.nodes)
                     if not isinstance(n, ConvLayer)})
    rng = np.random.default_rng(0)
    c, im = spec.nodes[0].c, spec.nodes[0].im

    def serve(assignment, tag, batch):
        # compile the whole-graph batched plan (cached by batch shape), warm
        # it once, then serve the request stream one dispatch per batch
        plan = compile_plan(spec, assignment, (batch, c, im, im))
        sink = plan.sinks[-1]
        x = jnp.asarray(rng.standard_normal((batch, c, im, im)), jnp.float32)
        jax.block_until_ready(plan(x, weights)[sink])
        t0 = time.perf_counter()
        for _ in range(args.requests):
            x = jnp.asarray(rng.standard_normal((batch, c, im, im)), jnp.float32)
            jax.block_until_ready(plan(x, weights)[sink])
        dt = time.perf_counter() - t0
        imgs = args.requests * batch
        print(f"   {tag:10s}: batch {batch:3d} | {imgs/dt:8.1f} img/s "
              f"({dt/args.requests*1e3:.2f} ms/request)")
        return dt

    print(f"== serving {args.requests} request batches of {args.batch} ==")
    t_base = serve(baseline, "baseline", args.batch)
    t_opt = serve(sel.assignment, "optimised", args.batch)
    print(f"   speedup: {t_base/t_opt:.2f}x")

    if args.sweep:
        print("== throughput vs batch size (optimised assignment) ==")
        for b in (1, 4, 16):
            serve(sel.assignment, f"batch={b}", b)


if __name__ == "__main__":
    main()
