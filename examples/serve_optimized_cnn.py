"""End-to-end serving driver (the paper's deployment story), through the
service layer: a HostPlatform profiles the JAX primitives ON THIS MACHINE,
``optimise`` trains a model and PBQP-selects an executable assignment, and
an ``OptimisedServer`` serves batched requests through the compiled
whole-graph plan — reported against a fixed-primitive baseline.

Batching knob: ``--batch N`` sets the request batch size (the server batches
up to its perf-model-predicted cap; the compiled plan is one jitted function
over a leading batch axis); ``--sweep`` prints an images/s curve over batch
sizes 1/4/16. ``--workers N`` serves baseline and optimised nets through ONE
concurrent server (N worker threads, ``--max-wait-ms`` batch windows)
instead of sequential per-net measurements — the DESIGN.md §8 serving core.

Run:  PYTHONPATH=src python examples/serve_optimized_cnn.py [--requests 32]
      [--batch 8] [--sweep] [--workers 2] [--max-wait-ms 5]
"""
import argparse
import time

import numpy as np

from repro.models.cnn_zoo import ConvLayer
from repro.primitives.executor import make_weights
from repro.service import HostPlatform, OptimisedServer, OptimisedNetwork, optimise


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16,
                    help="number of request batches per measurement")
    ap.add_argument("--batch", type=int, default=8,
                    help="images per request batch (the batching knob)")
    ap.add_argument("--sweep", action="store_true",
                    help="also sweep batch sizes 1/4/16 on the optimised net")
    ap.add_argument("--workers", type=int, default=0,
                    help="serve both nets concurrently through this many "
                         "worker threads (0 = sequential pump mode)")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="batch window cap when --workers > 0: max time a "
                         "lone request waits for batch peers")
    ap.add_argument("--latency-budget-ms", type=float, default=float("inf"),
                    help="per-request latency budget for the concurrent "
                         "serving section: sets the perf-model batch cap and "
                         "caps each batch window at budget minus predicted "
                         "execution (deadline-aware batching, DESIGN.md "
                         "§8.5); inf = batch-size cap only")
    ap.add_argument("--backends", default=None, metavar="P1,P2,...",
                    help="also demo predicted-cost cross-backend routing "
                         "(DESIGN.md §9): optimise edge_cnn for each listed "
                         "platform (e.g. 'arm,tpu') and serve one request "
                         "stream routed to the predicted-cheapest backend")
    args = ap.parse_args()

    prims = ["im2col-copy-ab-ki", "im2col-scan-ab-ki", "kn2row", "mec-col",
             "winograd-2x2-3x3", "conv-1x1-gemm-ab-ki", "direct-sum2d"]
    print("== profiling primitives on this CPU (the stage the perf model replaces) ==")
    t0 = time.perf_counter()
    import repro.models.cnn_zoo as cnn_zoo
    spec = cnn_zoo.get("edge_cnn")
    convs = [(i, n) for i, n in enumerate(spec.nodes) if isinstance(n, ConvLayer)]
    pool = sorted({n.config for _, n in convs} |
                  {(32, 16, 28, 1, 3), (64, 32, 14, 1, 3), (16, 8, 30, 1, 3)})
    platform = HostPlatform(configs=pool,
                            dlt_pairs=[(16, 30), (32, 28), (32, 26), (64, 13)],
                            primitives=prims, repeats=5)
    opt = optimise(spec, platform, executable=True, max_iters=1200)
    print(f"   profiled {platform.primitive_dataset().n} configs and "
          f"optimised in {time.perf_counter()-t0:.1f}s")
    print("   assignment:", [opt.assignment[i] for i, _ in convs])

    weights = make_weights(spec)
    baseline_asg = {i: ("conv-1x1-gemm-ab-ki" if n.f == 1 else "direct-sum2d")
                    for i, n in convs}
    baseline_asg.update({i: "chw" for i, n in enumerate(spec.nodes)
                         if not isinstance(n, ConvLayer)})
    baseline = OptimisedNetwork.from_assignment(
        spec, baseline_asg, net="edge_cnn_baseline", platform=platform,
        models=opt.models, columns=opt.columns)

    rng = np.random.default_rng(0)
    c, im = spec.nodes[0].c, spec.nodes[0].im

    def serve(registered: OptimisedNetwork, tag, batch):
        # one server per measurement: register, warm the plan once, then
        # serve the request stream batch-by-batch through the queue
        server = OptimisedServer(max_batch=batch,
                                 latency_budget_ms=float("inf"))
        server.register(registered, weights=weights)
        warm = rng.standard_normal((batch, c, im, im)).astype(np.float32)
        server.serve(registered.net, warm)
        t0 = time.perf_counter()
        for _ in range(args.requests):
            xs = rng.standard_normal((batch, c, im, im)).astype(np.float32)
            server.serve(registered.net, xs)
        dt = time.perf_counter() - t0
        imgs = args.requests * batch
        print(f"   {tag:10s}: batch {batch:3d} | {imgs/dt:8.1f} img/s "
              f"({dt/args.requests*1e3:.2f} ms/request)")
        return dt

    print(f"== serving {args.requests} request batches of {args.batch} ==")
    t_base = serve(baseline, "baseline", args.batch)
    t_opt = serve(opt, "optimised", args.batch)
    print(f"   speedup: {t_base/t_opt:.2f}x")

    if args.workers:
        print(f"== concurrent serving core: both nets, {args.workers} "
              f"workers, {args.max_wait_ms:.0f} ms batch window ==")
        server = OptimisedServer(max_batch=args.batch,
                                 latency_budget_ms=args.latency_budget_ms,
                                 workers=args.workers,
                                 max_wait_ms=args.max_wait_ms,
                                 queue_depth=2 * args.requests * args.batch)
        server.register(opt, weights=weights)
        server.register(baseline, weights=weights)
        s0 = server.stats(opt.net)
        print(f"   batch cap {s0['batch_cap']}, effective window "
              f"{s0['effective_wait_ms']:.2f} ms "
              f"(cap {args.max_wait_ms:.1f} ms, budget "
              f"{args.latency_budget_ms:.0f} ms)")
        for net in (opt.net, baseline.net):     # warm the plan cache
            server.serve(net, rng.standard_normal(
                (args.batch, c, im, im)).astype(np.float32))
        tickets = []
        t0 = time.perf_counter()
        for _ in range(args.requests):
            for net in (opt.net, baseline.net):
                xs = rng.standard_normal(
                    (args.batch, c, im, im)).astype(np.float32)
                tickets += [server.submit(net, x) for x in xs]
        for t in tickets:
            t.wait(120.0)
        dt = time.perf_counter() - t0
        served = sum(1 for t in tickets if t.done and t.error is None)
        dropped = len(tickets) - served
        for net in (opt.net, baseline.net):
            s = server.stats(net)
            print(f"   {net:20s}: queue p50/p99 "
                  f"{s['queue_wait_p50_ms']:6.2f}/{s['queue_wait_p99_ms']:6.2f} ms "
                  f"({s['dispatches']} dispatches, {s['padded']} padded, "
                  f"{s['rejected']} rejected)")
            if s["failed_dispatches"] or s["fallback_images"]:
                # failures absorbed by the DESIGN.md §11 fault-tolerance layer
                print(f"   {'':20s}  {s['failed_dispatches']} dispatches "
                      f"failed ({s['retries']} retried), "
                      f"{s['fallback_images']} images served degraded, "
                      f"ledger {s['failures']}")
        print(f"   both nets: {served/dt:8.1f} img/s overlapped "
              f"({dropped} failed/rejected) "
              f"vs {2*args.requests*args.batch/(t_base+t_opt):8.1f} sequential")
        server.stop()

    if args.backends:
        specs = [s.strip() for s in args.backends.split(",") if s.strip()]
        print(f"== cross-backend routing: {', '.join(specs)} ==")
        from repro.service import get_platform
        base = get_platform("intel", max_triplets=8).pretrain(max_iters=400)
        server = OptimisedServer(max_batch=args.batch,
                                 latency_budget_ms=float("inf"),
                                 workers=max(args.workers, 2),
                                 max_wait_ms=args.max_wait_ms,
                                 queue_depth=2 * args.requests * args.batch)
        for name in specs:
            o = optimise(spec, get_platform(name, max_triplets=8), base=base,
                         budget=0.05, executable=True, max_iters=400)
            server.register(o, backend=name, weights=weights, max_inflight=1)
        warm = rng.standard_normal((args.batch, c, im, im)).astype(np.float32)
        server.serve(spec.name, warm)
        t0 = time.perf_counter()
        for _ in range(args.requests):
            xs = rng.standard_normal((args.batch, c, im, im)).astype(np.float32)
            server.serve(spec.name, xs)
        dt = time.perf_counter() - t0
        s = server.stats(spec.name)
        print(f"   routed: {args.requests*args.batch/dt:8.1f} img/s "
              f"across {len(specs)} backends")
        for b, bs in s["backends"].items():
            print(f"   backend {b:6s}: {bs['dispatches']} dispatches, "
                  f"{bs['images']} images, queue p50/p99 "
                  f"{bs['queue_wait_p50_ms']:.2f}/"
                  f"{bs['queue_wait_p99_ms']:.2f} ms")
        server.stop()

    if args.sweep:
        print("== throughput vs batch size (optimised assignment) ==")
        for b in (1, 4, 16):
            serve(opt, f"batch={b}", b)


if __name__ == "__main__":
    main()
