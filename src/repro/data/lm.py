"""Synthetic LM data pipeline.

Deterministic, stateless-shardable: batch ``i`` on host ``h`` is a pure
function of ``(seed, i, h)``, so a restarted (or re-scaled) job regenerates
exactly the stream it needs — the elasticity contract from DESIGN.md §5.
Sequences are Zipf-distributed token n-gram chains so the loss actually
decreases (unlike uniform noise) while requiring no external corpus.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def make_batch(cfg: ArchConfig, batch: int, seq: int, index: int,
               seed: int = 0, host: int = 0) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, index, host]))
    V = cfg.vocab
    # Markov-ish stream: next token = (a * prev + b) % V with noise, giving
    # learnable structure.
    a = 31 if V > 31 else 3
    x = np.zeros((batch, seq + 1), np.int64)
    x[:, 0] = rng.integers(0, V, batch)
    noise = rng.random((batch, seq)) < 0.15
    jumps = rng.integers(0, V, (batch, seq))
    for t in range(seq):
        nxt = (a * x[:, t] + 7) % V
        x[:, t + 1] = np.where(noise[:, t], jumps[:, t], nxt)
    out: Dict[str, jnp.ndarray] = {
        "tokens": jnp.asarray(x[:, :-1], jnp.int32),
        "labels": jnp.asarray(x[:, 1:], jnp.int32),
    }
    if cfg.prefix_tokens:
        out["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((batch, min(cfg.prefix_tokens, 8), cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.kind == "encdec":
        out["enc_embeds"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)) * 0.02, jnp.float32)
    return out


def synthetic_batches(cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
                      host: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    i = 0
    while True:
        yield make_batch(cfg, batch, seq, i, seed=seed, host=host)
        i += 1
