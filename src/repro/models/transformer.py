"""Model assembly for all assigned architecture families.

One parameterised stack covers: dense GQA decoders (llama3, chatglm3,
gemma2 incl. local/global alternation + softcaps, internvl2 with a stubbed
vision prefix), MLA (minicpm3), MoE (mixtral, qwen3-moe), pure SSM (mamba2),
hybrid SSM + shared attention (zamba2) and encoder-decoder (whisper).

Layers are scanned (``jax.lax.scan`` over stacked parameters) so compiled
HLO size is O(1) in depth — at 126 layers x 512 devices this is what keeps
dry-run compiles tractable — with ``jax.checkpoint`` rematerialisation for
training. The same block functions serve training (full sequence) and
decode (single token + cache): caches thread through the layer scan as
per-layer xs/ys.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import components as C
from repro.models import moe as M
from repro.models import ssm as S

Params = Dict[str, Any]
_BIG_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ActShard:
    """How to pin activation shardings inside the jitted step. Without
    explicit constraints GSPMD propagates the FSDP *parameter* shardings
    (which place the 'data' axis on feature dims) into the activations and
    silently drops batch parallelism — observed as global-batch-sized
    attention buffers per device (EXPERIMENTS.md §Perf). ``dp`` = batch
    axes; ``seq`` = sequence-parallel residual stream (Megatron SP): the
    sequence dim of h is sharded over the TP axis between blocks."""
    dp: Tuple[str, ...] = ("data",)
    tp: str = "model"
    seq: bool = False
    tp_size: int = 0          # size of the tp axis (0 = unknown)


def _cst(h: jnp.ndarray, a: Optional["ActShard"]) -> jnp.ndarray:
    """Constrain a (B, S, D) activation (or (B, 1, D) decode activation)."""
    if a is None:
        return h
    from jax.sharding import PartitionSpec as P
    seq_ax = a.tp if (a.seq and h.shape[1] > 1) else None
    return jax.lax.with_sharding_constraint(h, P(a.dp, seq_ax, None))


# ---------------------------------------------------------------------------
# Norm dispatch
# ---------------------------------------------------------------------------

def _norm_init(cfg: ArchConfig, d: int) -> Params:
    if cfg.norm == "layernorm":
        return C.layernorm_init(d)
    return C.rmsnorm_init(d)


def _norm(cfg: ArchConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return C.layernorm(p, x, cfg.norm_eps)
    return C.rmsnorm(p, x, cfg.norm_eps, plus_one=(cfg.norm == "rmsnorm1p"))


# ---------------------------------------------------------------------------
# Per-layer parameter initialisers
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: ArchConfig) -> Params:
    if cfg.attn_kind == "mla":
        return C.mla_init(key, cfg.d_model, cfg.n_heads, cfg.mla, cfg.param_dtype)
    return C.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                      cfg.param_dtype, qkv_bias=cfg.qkv_bias)


def _dense_block_init(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {
        "ln_attn": _norm_init(cfg, cfg.d_model),
        "attn": _attn_init(k1, cfg),
        "ln_mlp": _norm_init(cfg, cfg.d_model),
    }
    if cfg.moe is not None:
        p["moe"] = M.moe_init(k2, cfg.d_model, cfg.moe, cfg.param_dtype)
    else:
        p["mlp"] = C.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype)
    if cfg.post_norms:
        p["ln_attn_post"] = _norm_init(cfg, cfg.d_model)
        p["ln_mlp_post"] = _norm_init(cfg, cfg.d_model)
    return p


def _ssm_block_init(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"ln": _norm_init(cfg, cfg.d_model),
                 "ssm": S.ssm_init(k1, cfg.d_model, cfg.ssm, cfg.param_dtype)}
    return p


def _enc_block_init(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": _norm_init(cfg, cfg.d_model),
        "attn": C.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.param_dtype),
        "ln_mlp": _norm_init(cfg, cfg.d_model),
        "mlp": C.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype, gated=False),
    }


def _dec_block_init(key, cfg: ArchConfig) -> Params:
    """Decoder block for enc-dec: self-attn + cross-attn + mlp."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln_self": _norm_init(cfg, cfg.d_model),
        "self_attn": C.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.param_dtype),
        "ln_cross": _norm_init(cfg, cfg.d_model),
        "cross_attn": C.gqa_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.param_dtype),
        "ln_mlp": _norm_init(cfg, cfg.d_model),
        "mlp": C.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.param_dtype, gated=False),
    }


def _stack(init_fn, key, n: int) -> Params:
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    params: Params = {"embed": C.embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
                      "final_norm": _norm_init(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = C.dense_init(ks[5], cfg.d_model, cfg.vocab, cfg.param_dtype)
    if cfg.pos == "learned":
        params["pos_emb"] = {"emb": (jax.random.normal(ks[6], (cfg.max_position, cfg.d_model),
                                                       jnp.float32) * 0.02).astype(cfg.param_dtype)}
    if cfg.kind == "encdec":
        params["enc_layers"] = _stack(lambda k: _enc_block_init(k, cfg), ks[1], cfg.n_enc_layers)
        params["enc_final_norm"] = _norm_init(cfg, cfg.d_model)
        params["layers"] = _stack(lambda k: _dec_block_init(k, cfg), ks[2], cfg.n_layers)
    elif cfg.hybrid_attn_every:
        per = cfg.hybrid_attn_every
        groups = cfg.n_layers // per
        params["layers"] = jax.vmap(lambda k: _stack(lambda kk: _ssm_block_init(kk, cfg), k, per)
                                    )(jax.random.split(ks[1], groups))
        params["shared"] = _dense_block_init(ks[2], cfg)
    elif cfg.ssm is not None:
        params["layers"] = _stack(lambda k: _ssm_block_init(k, cfg), ks[1], cfg.n_layers)
    else:
        params["layers"] = _stack(lambda k: _dense_block_init(k, cfg), ks[1], cfg.n_layers)
    return params


# ---------------------------------------------------------------------------
# Block apply (training / prefill path)
# ---------------------------------------------------------------------------

def _attn_apply(cfg: ArchConfig, p: Params, h: jnp.ndarray,
                positions: jnp.ndarray, window, causal: bool = True,
                kv_block: int = 1024) -> jnp.ndarray:
    B, Sq, D = h.shape
    if cfg.attn_kind == "mla":
        q, ckv, kr = C.mla_project(p, h, cfg.n_heads, cfg.mla, positions, cfg.rope_theta)
        return C.mla_attend(p, q, ckv, kr, positions, positions, cfg.n_heads,
                            cfg.mla, causal=causal, kv_block=kv_block)
    rot = int(cfg.hd * cfg.rope_fraction) if cfg.rope_theta > 0 else None
    q, k, v = C.gqa_project(p, h, cfg.n_heads, cfg.n_kv_heads, cfg.hd, positions,
                            cfg.rope_theta, rot)
    out = C.attention(q, k, v, positions, positions, causal=causal, window=window,
                      softcap=cfg.attn_softcap, kv_block=kv_block)
    return C.dense(p["wo"], out.reshape(B, Sq, cfg.n_heads * cfg.hd))


def _dense_block(cfg: ArchConfig, p: Params, h: jnp.ndarray, positions: jnp.ndarray,
                 window, causal: bool = True,
                 aspec: Optional[ActShard] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    a = _attn_apply(cfg, p["attn"], _norm(cfg, p["ln_attn"], h), positions, window, causal)
    if cfg.post_norms:
        a = _norm(cfg, p["ln_attn_post"], a)
    h = h + a
    x = _norm(cfg, p["ln_mlp"], h)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        m, aux = M.moe_apply(p["moe"], x, cfg.moe, aspec=aspec)
    else:
        m = C.mlp(p["mlp"], x, cfg.act)
    if cfg.post_norms:
        m = _norm(cfg, p["ln_mlp_post"], m)
    return h + m, aux


def _ssm_block_apply(cfg: ArchConfig, p: Params, h: jnp.ndarray) -> jnp.ndarray:
    return h + S.ssm_block(p["ssm"], _norm(cfg, p["ln"], h), cfg.ssm, cfg.d_model)


def _layer_window(cfg: ArchConfig, layer_flag: Optional[jnp.ndarray]):
    """Resolve the attention window for a layer. ``layer_flag`` (is_global)
    is a traced per-layer scalar under the layer scan."""
    if cfg.layer_pattern == "alt_local_global":
        return jnp.where(layer_flag, _BIG_WINDOW, cfg.window).astype(jnp.int32)
    return cfg.window


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            prefix_embeds: Optional[jnp.ndarray] = None,
            enc_embeds: Optional[jnp.ndarray] = None,
            aspec: Optional[ActShard] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (final hidden (B, S, D), aux loss)."""
    h = C.embed(params["embed"], tokens)
    if cfg.norm == "rmsnorm1p":         # gemma scales embeddings
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, Sq, D = h.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)
    if cfg.pos == "learned":
        h = h + params["pos_emb"]["emb"][:Sq][None]
    h = _cst(h, aspec)

    if cfg.kind == "encdec":
        enc = _encode(params, cfg, enc_embeds, aspec)
        h = _decode_stack(params, cfg, h, positions, enc, aspec)
        return _norm(cfg, params["final_norm"], h), jnp.zeros((), jnp.float32)

    if cfg.hybrid_attn_every:
        h = _hybrid_stack(params, cfg, h, positions, aspec)
        return _norm(cfg, params["final_norm"], h), jnp.zeros((), jnp.float32)

    if cfg.ssm is not None:
        def body(carry, p):
            return _cst(_ssm_block_apply(cfg, p, carry), aspec), None
        body = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(body, h, params["layers"])
        return _norm(cfg, params["final_norm"], h), jnp.zeros((), jnp.float32)

    flags = None
    if cfg.layer_pattern == "alt_local_global":
        flags = (jnp.arange(cfg.n_layers) % 2 == 1)

    def body(carry, xs):
        h, aux = carry
        p, flag = xs
        w = _layer_window(cfg, flag)
        h, a = _dense_block(cfg, p, h, positions, w, aspec=aspec)
        return (_cst(h, aspec), aux + a), None

    body = jax.checkpoint(body) if cfg.remat else body
    xs = (params["layers"], flags if flags is not None else jnp.zeros(cfg.n_layers, bool))
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), xs)
    return _norm(cfg, params["final_norm"], h), aux


def _encode(params: Params, cfg: ArchConfig, enc_embeds: jnp.ndarray,
            aspec: Optional[ActShard] = None) -> jnp.ndarray:
    B, Se, D = enc_embeds.shape
    h = _cst(enc_embeds.astype(cfg.param_dtype), aspec)
    if cfg.pos == "learned":
        h = h + params["pos_emb"]["emb"][:Se][None]
    positions = jnp.arange(Se, dtype=jnp.int32)

    def body(carry, p):
        a = _attn_apply(cfg, p["attn"], _norm(cfg, p["ln_attn"], carry), positions,
                        None, causal=False)
        carry = carry + a
        m = C.mlp(p["mlp"], _norm(cfg, p["ln_mlp"], carry), cfg.act)
        return _cst(carry + m, aspec), None

    body = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return _norm(cfg, params["enc_final_norm"], h)


def _decode_stack(params: Params, cfg: ArchConfig, h: jnp.ndarray,
                  positions: jnp.ndarray, enc: jnp.ndarray,
                  aspec: Optional[ActShard] = None) -> jnp.ndarray:
    B, Se, D = enc.shape
    enc_pos = jnp.arange(Se, dtype=jnp.int32)

    def body(carry, p):
        a = _attn_apply(cfg, p["self_attn"], _norm(cfg, p["ln_self"], carry),
                        positions, None, causal=True)
        carry = carry + a
        x = _norm(cfg, p["ln_cross"], carry)
        q, k, v = C.gqa_project(p["cross_attn"], x, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, positions, 0.0)
        _, ke, ve = C.gqa_project(p["cross_attn"], enc, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.hd, enc_pos, 0.0)
        o = C.attention(q, ke, ve, positions, enc_pos, causal=False)
        carry = carry + C.dense(p["cross_attn"]["wo"], o.reshape(B, -1, cfg.n_heads * cfg.hd))
        m = C.mlp(p["mlp"], _norm(cfg, p["ln_mlp"], carry), cfg.act)
        return _cst(carry + m, aspec), None

    body = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body, h, params["layers"])
    return h


def _hybrid_stack(params: Params, cfg: ArchConfig, h: jnp.ndarray,
                  positions: jnp.ndarray,
                  aspec: Optional[ActShard] = None) -> jnp.ndarray:
    """zamba2: groups of SSM blocks, shared attention block between groups."""
    shared = params["shared"]

    def group_body(carry, group_params):
        def inner(c, p):
            return _cst(_ssm_block_apply(cfg, p, c), aspec), None
        c, _ = jax.lax.scan(inner, carry, group_params)
        c, _ = _dense_block(cfg, shared, c, positions, cfg.window, aspec=aspec)
        return _cst(c, aspec), None

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    h, _ = jax.lax.scan(body, h, params["layers"])
    return h


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            aspec: Optional[ActShard] = None,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens (B, S_text), labels (B, S_text) and optionally
    prefix_embeds / enc_embeds / label_mask."""
    h, aux = forward(params, cfg, batch["tokens"],
                     prefix_embeds=batch.get("prefix_embeds"),
                     enc_embeds=batch.get("enc_embeds"), aspec=aspec)
    if batch.get("prefix_embeds") is not None:
        h = h[:, batch["prefix_embeds"].shape[1]:]
    emb = params["embed"] if cfg.tie_embeddings else {"emb": params["lm_head"]["w"].T}
    ce = C.chunked_ce_loss(emb, h, batch["labels"], cfg.loss_chunks,
                           softcap=cfg.final_softcap, label_mask=batch.get("label_mask"))
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill: forward pass that also emits the serving cache
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            prefix_embeds: Optional[jnp.ndarray] = None,
            enc_embeds: Optional[jnp.ndarray] = None,
            aspec: Optional[ActShard] = None,
            ) -> Tuple[jnp.ndarray, Params]:
    """Run the full-context forward pass and collect the decode cache.
    Returns (last-position logits (B, vocab), cache). Cache sequence length
    equals the input length; the serving layer copies it into (or ring-slices
    it for windowed archs) the decode buffers."""
    h = C.embed(params["embed"], tokens)
    if cfg.norm == "rmsnorm1p":
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, Sq, D = h.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)
    if cfg.pos == "learned":
        h = h + params["pos_emb"]["emb"][:Sq][None]
    h = _cst(h, aspec)

    if cfg.kind == "encdec":
        enc = _encode(params, cfg, enc_embeds, aspec)
        Se = enc.shape[1]
        enc_pos = jnp.arange(Se, dtype=jnp.int32)

        def body(carry, p):
            hh = carry
            x_self = _norm(cfg, p["ln_self"], hh)
            q, ks, vs = C.gqa_project(p["self_attn"], x_self, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.hd, positions, cfg.rope_theta)
            o_self = C.attention(q, ks, vs, positions, positions, causal=True)
            hh = hh + C.dense(p["self_attn"]["wo"],
                              o_self.reshape(B, Sq, cfg.n_heads * cfg.hd))
            x = _norm(cfg, p["ln_cross"], hh)
            q, _, _ = C.gqa_project(p["cross_attn"], x, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd, positions, 0.0)
            _, ke, ve = C.gqa_project(p["cross_attn"], enc, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.hd, enc_pos, 0.0)
            o = C.attention(q, ke, ve, positions, enc_pos, causal=False)
            hh = hh + C.dense(p["cross_attn"]["wo"], o.reshape(B, -1, cfg.n_heads * cfg.hd))
            hh = hh + C.mlp(p["mlp"], _norm(cfg, p["ln_mlp"], hh), cfg.act)
            return _cst(hh, aspec), (ks, vs, ke, ve)

        h, (k, v, ck, cv) = jax.lax.scan(body, h, params["layers"])
        cache = {"k": k, "v": v, "ck": ck, "cv": cv}

    elif cfg.hybrid_attn_every:
        shared = params["shared"]

        def group_body(carry, gp):
            def inner(c, p):
                y, st, cs = S.ssm_block(p["ssm"], _norm(cfg, p["ln"], c), cfg.ssm,
                                        cfg.d_model, return_state=True)
                return c + y, (st, cs)
            c, (st, cs) = jax.lax.scan(inner, carry, gp)
            x = _norm(cfg, shared["ln_attn"], c)
            q, ks, vs = C.gqa_project(shared["attn"], x, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.hd, positions, cfg.rope_theta)
            a = C.attention(q, ks, vs, positions, positions, causal=True,
                            window=cfg.window, softcap=cfg.attn_softcap)
            c = c + C.dense(shared["attn"]["wo"], a.reshape(B, Sq, cfg.n_heads * cfg.hd))
            c = c + C.mlp(shared["mlp"], _norm(cfg, shared["ln_mlp"], c), cfg.act)
            return _cst(c, aspec), (st, cs, ks, vs)

        h, (st, cs, k, v) = jax.lax.scan(group_body, h, params["layers"])
        cache = {"ssm": st, "conv": cs, "k": k, "v": v}

    elif cfg.ssm is not None:
        def body(carry, p):
            y, st, cs = S.ssm_block(p["ssm"], _norm(cfg, p["ln"], carry), cfg.ssm,
                                    cfg.d_model, return_state=True)
            return _cst(carry + y, aspec), (st, cs)
        h, (st, cs) = jax.lax.scan(body, h, params["layers"])
        cache = {"ssm": st, "conv": cs}

    elif cfg.attn_kind == "mla":
        def body(carry, p):
            hh = carry
            x = _norm(cfg, p["ln_attn"], hh)
            q, ckv, kr = C.mla_project(p["attn"], x, cfg.n_heads, cfg.mla,
                                       positions, cfg.rope_theta)
            a = C.mla_attend(p["attn"], q, ckv, kr, positions, positions,
                             cfg.n_heads, cfg.mla, causal=True)
            hh = hh + a
            hh = hh + C.mlp(p["mlp"], _norm(cfg, p["ln_mlp"], hh), cfg.act)
            return _cst(hh, aspec), (ckv, kr)
        h, (ckv, kr) = jax.lax.scan(body, h, params["layers"])
        cache = {"ckv": ckv, "kr": kr}

    else:
        flags = ((jnp.arange(cfg.n_layers) % 2 == 1)
                 if cfg.layer_pattern == "alt_local_global"
                 else jnp.zeros(cfg.n_layers, bool))

        def body(carry, xs):
            hh = carry
            p, flag = xs
            w = _layer_window(cfg, flag)
            x = _norm(cfg, p["ln_attn"], hh)
            rot = int(cfg.hd * cfg.rope_fraction) if cfg.rope_theta > 0 else None
            q, ks, vs = C.gqa_project(p["attn"], x, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.hd, positions, cfg.rope_theta, rot)
            o = C.attention(q, ks, vs, positions, positions, causal=True, window=w,
                            softcap=cfg.attn_softcap)
            a = C.dense(p["attn"]["wo"], o.reshape(B, Sq, cfg.n_heads * cfg.hd))
            if cfg.post_norms:
                a = _norm(cfg, p["ln_attn_post"], a)
            hh = hh + a
            x2 = _norm(cfg, p["ln_mlp"], hh)
            if cfg.moe is not None:
                m, _ = M.moe_apply(p["moe"], x2, cfg.moe, aspec=aspec)
            else:
                m = C.mlp(p["mlp"], x2, cfg.act)
            if cfg.post_norms:
                m = _norm(cfg, p["ln_mlp_post"], m)
            return _cst(hh + m, aspec), (ks, vs)

        h, (k, v) = jax.lax.scan(body, h, (params["layers"], flags))
        cache = {"k": k, "v": v}

    h = _norm(cfg, params["final_norm"], h)
    emb = params["embed"] if cfg.tie_embeddings else {"emb": params["lm_head"]["w"].T}
    logits = C.unembed(emb, h[:, -1:]).astype(jnp.float32)[:, 0]
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode: cache init + single-token step
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               enc_len: int = 0, dtype=jnp.bfloat16) -> Params:
    B, S = batch_size, max_len
    if cfg.kind == "encdec":
        return {
            "k": jnp.zeros((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.hd), dtype),
            "ck": jnp.zeros((cfg.n_layers, B, enc_len, cfg.n_kv_heads, cfg.hd), dtype),
            "cv": jnp.zeros((cfg.n_layers, B, enc_len, cfg.n_kv_heads, cfg.hd), dtype),
        }
    if cfg.hybrid_attn_every:
        per = cfg.hybrid_attn_every
        G = cfg.n_layers // per
        ssm = cfg.ssm
        H = ssm.n_heads(cfg.d_model)
        conv_dim = ssm.d_inner(cfg.d_model) + 2 * ssm.n_groups * ssm.d_state
        kv_len = min(S, cfg.window) if cfg.window else S
        return {
            "ssm": jnp.zeros((G, per, B, H, ssm.headdim, ssm.d_state), jnp.float32),
            "conv": jnp.zeros((G, per, B, ssm.d_conv - 1, conv_dim), dtype),
            "k": jnp.zeros((G, B, kv_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((G, B, kv_len, cfg.n_kv_heads, cfg.hd), dtype),
        }
    if cfg.ssm is not None:
        ssm = cfg.ssm
        H = ssm.n_heads(cfg.d_model)
        conv_dim = ssm.d_inner(cfg.d_model) + 2 * ssm.n_groups * ssm.d_state
        return {
            "ssm": jnp.zeros((cfg.n_layers, B, H, ssm.headdim, ssm.d_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, B, ssm.d_conv - 1, conv_dim), dtype),
        }
    if cfg.attn_kind == "mla":
        return {
            "ckv": jnp.zeros((cfg.n_layers, B, S, cfg.mla.kv_lora), dtype),
            "kr": jnp.zeros((cfg.n_layers, B, S, cfg.mla.qk_rope), dtype),
        }
    # All-windowed archs (mixtral) decode from a window-sized ring buffer —
    # this is what makes the 500k-decode cell serveable. Mixed-pattern archs
    # (gemma2) keep the full cache for their global layers.
    if cfg.window is not None and cfg.layer_pattern == "global":
        kv_len = min(S, cfg.window)
    else:
        kv_len = S
    return {
        "k": jnp.zeros((cfg.n_layers, B, kv_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((cfg.n_layers, B, kv_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray,
                aspec: Optional[ActShard] = None,
                ) -> Tuple[jnp.ndarray, Params]:
    """One-token decode. tokens: (B, 1); pos: scalar int32 (current length).
    Returns (logits (B, vocab), updated cache)."""
    B = tokens.shape[0]
    h = C.embed(params["embed"], tokens)
    if cfg.norm == "rmsnorm1p":
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    q_pos = pos[None].astype(jnp.int32)
    if cfg.pos == "learned":
        h = h + params["pos_emb"]["emb"][pos][None, None]
    h = _cst(h, aspec)

    if cfg.kind == "encdec":
        h, cache = _decode_step_encdec(params, cfg, cache, h, q_pos, pos)
    elif cfg.hybrid_attn_every:
        h, cache = _decode_step_hybrid(params, cfg, cache, h, q_pos, pos)
    elif cfg.ssm is not None:
        h, cache = _decode_step_ssm(params, cfg, cache, h)
    else:
        h, cache = _decode_step_dense(params, cfg, cache, h, q_pos, pos, aspec=aspec)

    h = _norm(cfg, params["final_norm"], h)
    emb = params["embed"] if cfg.tie_embeddings else {"emb": params["lm_head"]["w"].T}
    logits = C.unembed(emb, h)[:, 0].astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, cache


def _cached_attn(cfg: ArchConfig, p: Params, h, ck, cv, q_pos, pos, window,
                 kv_block: int = 2048):
    """Project one token, update the per-layer cache, attend over it."""
    B = h.shape[0]
    rot = int(cfg.hd * cfg.rope_fraction) if cfg.rope_theta > 0 else None
    q, k, v = C.gqa_project(p, h, cfg.n_heads, cfg.n_kv_heads, cfg.hd, q_pos,
                            cfg.rope_theta, rot)
    S = ck.shape[1]
    # Ring buffer when the cache is sized to exactly the sliding window
    # (mixtral / zamba2 long-decode); otherwise linear slots.
    ring = cfg.window is not None and S == cfg.window
    slot = (pos % S).astype(jnp.int32) if ring else pos
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
    if ring:
        # absolute position held by each slot; never-written slots get a
        # large sentinel so the causal mask kills them during warm-up.
        wrap = (pos // S) * S
        idx = jnp.arange(S, dtype=jnp.int32)
        k_pos = jnp.where(idx <= (pos % S), wrap + idx, wrap - S + idx)
        k_pos = jnp.where(k_pos < 0, jnp.int32(_BIG_WINDOW), k_pos)
    else:
        k_pos = jnp.arange(S, dtype=jnp.int32)
    out = C.attention(q, ck, cv, q_pos, k_pos, causal=True, window=window,
                      softcap=cfg.attn_softcap, kv_block=kv_block)
    return C.dense(p["wo"], out.reshape(B, 1, cfg.n_heads * cfg.hd)), ck, cv


def _decode_step_dense(params, cfg: ArchConfig, cache, h, q_pos, pos, aspec=None):
    flags = (jnp.arange(cfg.n_layers) % 2 == 1) if cfg.layer_pattern == "alt_local_global" \
        else jnp.zeros(cfg.n_layers, bool)

    if cfg.attn_kind == "mla":
        def body(carry, xs):
            hh = carry
            p, ckv, kr, flag = xs
            x = _norm(cfg, p["ln_attn"], hh)
            q, new_ckv, new_kr = C.mla_project(p["attn"], x, cfg.n_heads, cfg.mla,
                                               q_pos, cfg.rope_theta)
            ckv = jax.lax.dynamic_update_slice(ckv, new_ckv.astype(ckv.dtype), (0, pos, 0))
            kr = jax.lax.dynamic_update_slice(kr, new_kr.astype(kr.dtype), (0, pos, 0))
            S = ckv.shape[1]
            k_pos = jnp.arange(S, dtype=jnp.int32)
            a = C.mla_attend(p["attn"], q, ckv, kr, q_pos, k_pos, cfg.n_heads, cfg.mla,
                             kv_block=2048)
            hh = hh + a
            hh = hh + C.mlp(p["mlp"], _norm(cfg, p["ln_mlp"], hh), cfg.act)
            return hh, (ckv, kr)

        h, (ckv, kr) = jax.lax.scan(body, h, (params["layers"], cache["ckv"], cache["kr"], flags))
        return h, {"ckv": ckv, "kr": kr}

    def body(carry, xs):
        hh = carry
        p, ck, cv, flag = xs
        w = _layer_window(cfg, flag)
        a, ck, cv = _cached_attn(cfg, p["attn"], _norm(cfg, p["ln_attn"], hh),
                                 ck, cv, q_pos, pos, w)
        if cfg.post_norms:
            a = _norm(cfg, p["ln_attn_post"], a)
        hh = hh + a
        x = _norm(cfg, p["ln_mlp"], hh)
        if cfg.moe is not None:
            m, _ = M.moe_apply(p["moe"], x, cfg.moe, aspec=aspec)
        else:
            m = C.mlp(p["mlp"], x, cfg.act)
        if cfg.post_norms:
            m = _norm(cfg, p["ln_mlp_post"], m)
        return hh + m, (ck, cv)

    h, (ck, cv) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"], flags))
    return h, {"k": ck, "v": cv}


def _decode_step_ssm(params, cfg: ArchConfig, cache, h):
    def body(carry, xs):
        hh = carry
        p, st, cs = xs
        y, st, cs = S.ssm_decode_step(p["ssm"], _norm(cfg, p["ln"], hh),
                                      cfg.ssm, cfg.d_model, st, cs)
        return hh + y, (st, cs)

    h, (st, cs) = jax.lax.scan(body, h, (params["layers"], cache["ssm"], cache["conv"]))
    return h, {"ssm": st, "conv": cs}


def _decode_step_hybrid(params, cfg: ArchConfig, cache, h, q_pos, pos):
    shared = params["shared"]

    def group(carry, xs):
        hh = carry
        gp, st, cs, ck, cv = xs

        def inner(c, ys):
            p, s1, c1 = ys
            y, s1, c1 = S.ssm_decode_step(p["ssm"], _norm(cfg, p["ln"], c),
                                          cfg.ssm, cfg.d_model, s1, c1)
            return c + y, (s1, c1)

        hh, (st, cs) = jax.lax.scan(inner, hh, (gp, st, cs))
        a, ck, cv = _cached_attn(cfg, shared["attn"], _norm(cfg, shared["ln_attn"], hh),
                                 ck, cv, q_pos, pos, cfg.window)
        hh = hh + a
        hh = hh + C.mlp(shared["mlp"], _norm(cfg, shared["ln_mlp"], hh), cfg.act)
        return hh, (st, cs, ck, cv)

    h, (st, cs, ck, cv) = jax.lax.scan(
        group, h, (params["layers"], cache["ssm"], cache["conv"], cache["k"], cache["v"]))
    return h, {"ssm": st, "conv": cs, "k": ck, "v": cv}


def _decode_step_encdec(params, cfg: ArchConfig, cache, h, q_pos, pos):
    B = h.shape[0]
    Se = cache["ck"].shape[2]
    enc_pos = jnp.arange(Se, dtype=jnp.int32)

    def body(carry, xs):
        hh = carry
        p, ck, cv, cck, ccv = xs
        a, ck, cv = _cached_attn(cfg, p["self_attn"], _norm(cfg, p["ln_self"], hh),
                                 ck, cv, q_pos, pos, None)
        hh = hh + a
        x = _norm(cfg, p["ln_cross"], hh)
        q, _, _ = C.gqa_project(p["cross_attn"], x, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, q_pos, 0.0)
        o = C.attention(q, cck, ccv, q_pos, enc_pos, causal=False, kv_block=2048)
        hh = hh + C.dense(p["cross_attn"]["wo"], o.reshape(B, 1, cfg.n_heads * cfg.hd))
        hh = hh + C.mlp(p["mlp"], _norm(cfg, p["ln_mlp"], hh), cfg.act)
        return hh, (ck, cv)

    h, (ck, cv) = jax.lax.scan(
        body, h, (params["layers"], cache["k"], cache["v"], cache["ck"], cache["cv"]))
    return h, {"k": ck, "v": cv, "ck": cache["ck"], "cv": cache["cv"]}
