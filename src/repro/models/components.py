"""Transformer building blocks, pure JAX.

Everything is a (params-pytree, apply-fn) pair; no framework. Conventions:
  * activations (B, S, D); weights stored in matmul-ready orientation;
  * attention supports GQA, sliding windows, logit soft-capping and MLA;
  * long sequences use blockwise (online-softmax) attention under
    ``jax.checkpoint`` so neither forward nor backward materialises S x S;
  * every apply-fn is shape-polymorphic over batch/sequence so the same code
    serves train_step (full sequence) and serve_step (single token + cache).

Initialisers take an explicit ``jax.random`` key and a dtype; parameter
pytrees are plain nested dicts so the sharding rules in
``repro.dist.sharding`` can pattern-match on path names.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6,
            plus_one: bool = False) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if plus_one:                       # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (x * scale).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Dense / embeddings
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16, scale: Optional[float] = None) -> Params:
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)}


def dense(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["w"]


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    return {"emb": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["emb"][tokens]


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding: (B, S, D) @ (V, D)^T."""
    return x @ params["emb"].T


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0, rot_dim: Optional[int] = None) -> jnp.ndarray:
    rd = rot_dim if rot_dim is not None else head_dim
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0,
               rot_dim: Optional[int] = None) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (S,) — positions are batch-independent
    (arange for train/prefill, the scalar step for decode), which keeps all
    mask/rotation tensors free of the batch dim. Rotates the first
    ``rot_dim`` dims (partial rotary — chatglm3 rotates half)."""
    hd = x.shape[-1]
    rd = rot_dim if rot_dim is not None else hd
    freqs = rope_freqs(hd, theta, rd)                       # (rd/2,)
    ang = positions[:, None].astype(jnp.float32) * freqs    # (S, rd/2)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    rot = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1) if rd < hd else rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------

def _softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: Optional[int]) -> jnp.ndarray:
    """(Sq, Sk) additive bias: 0 allowed / -inf masked. Positions are 1-D,
    so the bias carries no batch dim (broadcast over batch and heads)."""
    d = q_pos[:, None].astype(jnp.int32) - k_pos[None, :].astype(jnp.int32)
    ok = d >= 0 if causal else jnp.ones(d.shape, bool)
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              q_pos: jnp.ndarray, k_pos: jnp.ndarray, *,
              causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              kv_block: int = 1024) -> jnp.ndarray:
    """GQA attention. q: (B, Sq, Hq, hd); k/v: (B, Sk, Hkv, hd);
    q_pos/k_pos: (Sq,)/(Sk,) absolute positions (1-D: batch-uniform).

    Sharding-aware layout choice (found via dry-run memory analysis — see
    EXPERIMENTS.md §Perf): the grouped (B,S,Hkv,rep,hd) reshape breaks the
    head-dim TP sharding whenever Hkv doesn't divide the model axis, forcing
    a full all-gather of activations. So:
      * train/prefill (Sq large): keep q as (B,S,H,hd) and broadcast K/V to
        full heads per KV block — transient, preserves TP sharding exactly;
      * decode (Sq == 1): grouped einsum without the broadcast — all q-side
        tensors are single-token-sized, so resharding them is free and the
        big cache tensors stay in their native (Hkv) layout.
    Long Sk uses a blockwise online-softmax scan (flash-style)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    vd = v.shape[-1]                      # may differ from hd (MLA)
    rep = Hq // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    Sk = k.shape[1]

    if Sq > 1:
        qf = (q * sc).astype(jnp.float32)

        def blk_attend(kc, vc, pc):
            if rep > 1:
                kc = jnp.repeat(kc, rep, axis=2)
                vc = jnp.repeat(vc, rep, axis=2)
            logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kc,
                                preferred_element_type=jnp.float32)
            logits = _softcap(logits, softcap)
            logits = logits + _mask_bias(q_pos, pc, causal, window)[None, None]
            return logits, vc

        if Sk <= max(kv_block, 2048):
            logits, vc = blk_attend(k, v, k_pos)
            p = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", p, vc.astype(jnp.float32))
            return out.astype(q.dtype)

        nblk = Sk // kv_block
        assert nblk * kv_block == Sk, "Sk must divide kv_block for blockwise path"
        kb = jnp.moveaxis(k.reshape(B, nblk, kv_block, Hkv, hd), 1, 0)
        vb = jnp.moveaxis(v.reshape(B, nblk, kv_block, Hkv, vd), 1, 0)
        pb = k_pos.reshape(nblk, kv_block)

        def body(carry, blk):
            m, l, acc = carry
            kc, vc, pc = blk
            logits, vc = blk_attend(kc, vc, pc)             # (B, H, Sq, kb)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hq, Sq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
        a0 = jnp.zeros((B, Hq, Sq, vd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)      # (B, Sq, H, vd)

    # ---- decode path (Sq == 1): grouped single-shot, no K/V broadcast ----
    # Blockwise online softmax is pointless at Sq=1: logits are only
    # (B, H, 1, Sk) (~100 MB at 32k) and a KV-block scan over the
    # sequence-sharded cache forces per-block resharding collectives (and
    # blew up SPMD compile memory — see EXPERIMENTS.md §Perf). One-shot
    # softmax over the sharded Sk lowers to a clean psum-of-max/sum pattern.
    qf = (q * sc).astype(jnp.float32).reshape(B, Sq, Hkv, rep, hd)
    logits = jnp.einsum("bqgrh,bkgh->bgrqk", qf, k,
                        preferred_element_type=jnp.float32)
    logits = _softcap(logits, softcap)
    logits = logits + _mask_bias(q_pos, k_pos, causal, window)[None, None, None]
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, vd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_init(key, d: int, n_heads: int, n_kv: int, head_dim: int,
             dtype=jnp.bfloat16, qkv_bias: bool = False) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, n_heads * head_dim, dtype),
        "wk": dense_init(k2, d, n_kv * head_dim, dtype),
        "wv": dense_init(k3, d, n_kv * head_dim, dtype),
        "wo": dense_init(k4, n_heads * head_dim, d, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def gqa_project(params: Params, x: jnp.ndarray, n_heads: int, n_kv: int,
                head_dim: int, positions: jnp.ndarray, rope_theta: float,
                rot_dim: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    q = dense(params["wq"], x)
    k = dense(params["wk"], x)
    v = dense(params["wv"], x)
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta, rot_dim)
        k = apply_rope(k, positions, rope_theta, rot_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLADims:
    q_lora: int = 768
    kv_lora: int = 256
    qk_nope: int = 64
    qk_rope: int = 32
    v_head: int = 64


def mla_init(key, d: int, n_heads: int, dims: MLADims, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8)
    qk_head = dims.qk_nope + dims.qk_rope
    return {
        "wdq": dense_init(ks[0], d, dims.q_lora, dtype),
        "q_norm": rmsnorm_init(dims.q_lora),
        "wuq": dense_init(ks[1], dims.q_lora, n_heads * qk_head, dtype),
        "wdkv": dense_init(ks[2], d, dims.kv_lora, dtype),
        "kv_norm": rmsnorm_init(dims.kv_lora),
        "wkr": dense_init(ks[3], d, dims.qk_rope, dtype),
        "wukv": dense_init(ks[4], dims.kv_lora, n_heads * (dims.qk_nope + dims.v_head), dtype),
        "wo": dense_init(ks[5], n_heads * dims.v_head, d, dtype),
    }


def mla_project(params: Params, x: jnp.ndarray, n_heads: int, dims: MLADims,
                positions: jnp.ndarray, rope_theta: float
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q, c_kv, k_rope, positions-ready). The compressed latent
    (c_kv, k_rope) is what decode caches — 288 dims/token vs 2*H*hd."""
    B, S, _ = x.shape
    cq = rmsnorm(params["q_norm"], dense(params["wdq"], x))
    q = dense(params["wuq"], cq).reshape(B, S, n_heads, dims.qk_nope + dims.qk_rope)
    q_nope, q_rope = q[..., :dims.qk_nope], q[..., dims.qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], dense(params["wdkv"], x))   # (B, S, kv_lora)
    k_rope = dense(params["wkr"], x).reshape(B, S, 1, dims.qk_rope)
    k_rope = apply_rope(k_rope, positions, rope_theta)
    return q, c_kv, k_rope[:, :, 0, :]


def mla_attend(params: Params, q: jnp.ndarray, c_kv: jnp.ndarray,
               k_rope: jnp.ndarray, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
               n_heads: int, dims: MLADims, *, causal: bool = True,
               kv_block: int = 1024) -> jnp.ndarray:
    """q: (B,Sq,H,qk); c_kv: (B,Sk,kv_lora); k_rope: (B,Sk,qk_rope).

    Train/prefill: expand the latent to per-head K/V and run full attention.
    Decode (Sq==1): ABSORBED path (DeepSeek-V2 trick) — fold W_uk into the
    query and W_uv into the output so attention runs directly in the
    compressed latent space; the (B,Sk,H,·) expansion never materialises and
    per-token KV reads drop from 2*H*hd to kv_lora + qk_rope floats."""
    B, Sk, _ = c_kv.shape
    Sq = q.shape[1]
    scale = 1.0 / math.sqrt(dims.qk_nope + dims.qk_rope)

    if Sq == 1:
        w = params["wukv"]["w"].reshape(-1, n_heads, dims.qk_nope + dims.v_head)
        w_uk, w_uv = w[..., :dims.qk_nope], w[..., dims.qk_nope:]
        q_nope, q_rope = q[..., :dims.qk_nope], q[..., dims.qk_nope:]
        q_lat = jnp.einsum("bqhn,chn->bqhc", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        logits = (jnp.einsum("bqhc,bkc->bhqk", q_lat, c_kv.astype(jnp.float32))
                  + jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32),
                               k_rope.astype(jnp.float32))) * scale
        logits = logits + _mask_bias(q_pos, k_pos, causal, None)[None, None]
        p = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhqk,bkc->bqhc", p, c_kv.astype(jnp.float32))
        out = jnp.einsum("bqhc,chv->bqhv", o_lat, w_uv.astype(jnp.float32))
        return dense(params["wo"], out.astype(q.dtype).reshape(B, 1, n_heads * dims.v_head))

    kv = dense(params["wukv"], c_kv).reshape(B, Sk, n_heads, dims.qk_nope + dims.v_head)
    k_nope, v = kv[..., :dims.qk_nope], kv[..., dims.qk_nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, Sk, n_heads, dims.qk_rope))], axis=-1)
    out = attention(q, k, v, q_pos, k_pos, causal=causal, scale=scale, kv_block=kv_block)
    return dense(params["wo"], out.reshape(B, Sq, n_heads * dims.v_head))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype=jnp.bfloat16, gated: bool = True) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d, d_ff, dtype),
         "w_down": dense_init(k2, d_ff, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(k3, d, d_ff, dtype)
    return p


def mlp(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    h = dense(params["w_up"], x)
    if "w_gate" in params:
        g = dense(params["w_gate"], x)
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        h = g * h
    else:
        h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    return dense(params["w_down"], h)


# ---------------------------------------------------------------------------
# Cross-entropy (sequence-chunked: never materialises (B, S, V) at once)
# ---------------------------------------------------------------------------

def chunked_ce_loss(emb_params: Params, h: jnp.ndarray, labels: jnp.ndarray,
                    n_chunks: int = 8, softcap: Optional[float] = None,
                    label_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """h: (B, S, D) final hidden; labels: (B, S). Computes mean CE by
    scanning over S/n_chunks slabs — the full (B, S, V) logits tensor never
    exists, which is what keeps the 128k-vocab archs inside HBM."""
    B, S, D = h.shape
    n_chunks = min(n_chunks, S)
    while S % n_chunks:
        n_chunks -= 1
    hs = h.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)
    if label_mask is None:
        ms = jnp.ones_like(ls, jnp.float32)
    else:
        ms = label_mask.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2).astype(jnp.float32)

    @jax.checkpoint
    def body(carry, xs):
        # checkpointed: the (B, S/n, V) logits of each chunk are recomputed
        # in backward instead of stored (8 x 2.1 GB/device at 65k vocab).
        hc, lc, mc = xs
        logits = unembed(emb_params, hc).astype(jnp.float32)
        logits = _softcap(logits, softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mc
        return (carry[0] + jnp.sum(ce), carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
