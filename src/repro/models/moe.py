"""Mixture-of-Experts layer (Mixtral 8x7b top-2, Qwen3-MoE 128x top-8).

Capacity-based top-k routing with scatter dispatch / gather combine:
tokens are routed per sequence-row (so the dispatch is shardable over the
batch/data axis with no global resort), experts run as one batched GEMM
over the expert axis (shardable over the model axis = expert parallelism;
XLA inserts the all-to-all at the dispatch/combine boundaries). Dropped
tokens (capacity overflow) pass through the residual, standard practice.

An auxiliary load-balance loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.components import dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                 # per-expert hidden
    capacity_factor: float = 1.25
    aux_coef: float = 0.01
    dispatch: str = "sort"    # "sort" (optimized) | "scatter" (baseline)


def moe_init(key, d: int, cfg: MoEConfig, dtype=jnp.bfloat16) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(k1, d, cfg.n_experts, jnp.float32),
        "w_gate": (jax.random.normal(k2, (cfg.n_experts, d, cfg.d_ff), jnp.float32) * s).astype(dtype),
        "w_up": (jax.random.normal(k3, (cfg.n_experts, d, cfg.d_ff), jnp.float32) * s).astype(dtype),
        "w_down": (jax.random.normal(k4, (cfg.n_experts, cfg.d_ff, d), jnp.float32)
                   / math.sqrt(cfg.d_ff)).astype(dtype),
    }


def _route(params: Dict, x: jnp.ndarray, cfg: MoEConfig):
    """Shared router: top-k indices/weights + Switch aux loss."""
    E, K = cfg.n_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ params["router"]["w"])          # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                      # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e f_e * p_e  (f_e computed WITHOUT a dense
    # (B,S,K,E) one-hot: scatter-add of ones into (B, E))
    me = jnp.mean(probs, axis=(0, 1))
    B, S, _ = x.shape
    counts = jnp.zeros((B, E), jnp.float32).at[
        jnp.arange(B)[:, None, None], gate_idx].add(1.0)
    fe = jnp.mean(counts, axis=0) / S
    aux = cfg.aux_coef * E * jnp.sum(me * fe)
    return gate_idx, gate_vals, aux


def _experts(params: Dict, buf: jnp.ndarray) -> jnp.ndarray:
    """Batched expert FFN over (B, E, C, D) buffers — the expert dim is the
    EP shard axis; XLA places the all-to-all at the buffer boundaries."""
    h = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    h = jax.nn.silu(h) * u
    return jnp.einsum("becf,efd->becd", h, params["w_down"])


def _buf_cst(buf: jnp.ndarray, cfg: MoEConfig, aspec) -> jnp.ndarray:
    """Pin the (B, E, C, D) expert-buffer sharding: batch over dp, experts
    over the TP axis when they divide it (expert parallelism). Without this
    the partitioner may contract the FSDP-sharded weight dim instead —
    observed as a per-layer all-reduce of a GLOBAL-batch (B, E, C, ff)
    tensor (EXPERIMENTS.md §Perf iteration 2)."""
    if aspec is None:
        return buf
    from jax.sharding import PartitionSpec as P
    ep = aspec.tp_size and cfg.n_experts % aspec.tp_size == 0
    spec = P(aspec.dp, aspec.tp if ep else None, None, None)
    return jax.lax.with_sharding_constraint(buf, spec)


def moe_apply(params: Dict, x: jnp.ndarray, cfg: MoEConfig, aspec=None,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss). Dispatch impl per cfg.dispatch."""
    if cfg.dispatch == "sort":
        return _moe_sort(params, x, cfg, aspec)
    return _moe_scatter(params, x, cfg, aspec)


def _moe_scatter(params: Dict, x: jnp.ndarray, cfg: MoEConfig, aspec=None,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """BASELINE dispatch (recorded in EXPERIMENTS.md §Perf): positions from a
    dense (B, S*K, E) one-hot cumsum — O(S*K*E) memory, the dominant cost at
    E=128 — and a scatter-add of the full (B, S, K, D) token copies."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * S * K / E))
    gate_idx, gate_vals, aux = _route(params, x, cfg)

    one_hot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)           # (B, S, K, E)
    flat_hot = one_hot.reshape(B, S * K, E)
    pos = (jnp.cumsum(flat_hot, axis=1) - flat_hot)                    # (B, S*K, E)
    pos = jnp.sum(pos * flat_hot, axis=-1).reshape(B, S, K)
    keep = (pos < C).astype(x.dtype) * gate_vals.astype(x.dtype)
    pos = jnp.minimum(pos, C - 1).astype(jnp.int32)

    buf = jnp.zeros((B, E, C, D), x.dtype)
    bidx = jnp.arange(B)[:, None, None]
    mask = (keep > 0).astype(x.dtype)[..., None]
    xk = jnp.broadcast_to(x[:, :, None, :], (B, S, K, D)) * mask
    buf = _buf_cst(buf.at[bidx, gate_idx, pos].add(xk), cfg, aspec)

    y = _buf_cst(_experts(params, buf), cfg, aspec)
    out = y[bidx, gate_idx, pos] * keep[..., None]
    return out.sum(2), aux


def _moe_sort(params: Dict, x: jnp.ndarray, cfg: MoEConfig, aspec=None,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based dispatch (optimized; EXPERIMENTS.md §Perf iteration 1):
    expert positions come from an argsort over the (B, S*K) expert ids —
    every routing tensor is O(S*K) ints instead of the O(S*K*E) one-hot —
    and expert buffers are built by GATHER (token-id table per slot) instead
    of a (B,S,K,D)-sized scatter-add."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * S * K / E))
    gate_idx, gate_vals, aux = _route(params, x, cfg)

    flat_e = gate_idx.reshape(B, S * K)                                # (B, N)
    N = S * K
    order = jnp.argsort(flat_e, axis=1, stable=True)                   # (B, N)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # position within each expert's run
    ar = jnp.arange(N, dtype=jnp.int32)[None]
    is_start = jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(is_start, ar, -1), axis=1)
    pos_sorted = ar - run_start                                        # (B, N)
    # token-id (flattened S*K slot) feeding each (e, c) buffer slot;
    # capacity overflow routes to the out-of-bounds slot E*C, which
    # mode="drop" discards (no collision with the last real slot).
    slot = jnp.where(pos_sorted < C, sorted_e * C + pos_sorted, E * C)
    token_sorted = order                                               # token*K + k
    slot_token = jnp.zeros((B, E * C), jnp.int32).at[
        jnp.arange(B)[:, None], slot].set(token_sorted, mode="drop")
    slot_filled = jnp.zeros((B, E * C), bool).at[
        jnp.arange(B)[:, None], slot].set(True, mode="drop")

    # gather dispatch: (B, E, C, D)
    src_tok = slot_token // K                                          # (B, E*C)
    buf = jnp.take_along_axis(x, src_tok[..., None], axis=1)           # (B, E*C, D)
    buf = jnp.where(slot_filled[..., None], buf, 0).reshape(B, E, C, D)
    buf = _buf_cst(buf, cfg, aspec)

    y = _buf_cst(_experts(params, buf), cfg, aspec).reshape(B, E * C, D)

    # combine: each (token, k) reads back its slot
    pos_tok = jnp.zeros((B, N), jnp.int32).at[
        jnp.arange(B)[:, None], order].set(pos_sorted, mode="drop")
    keep = (pos_tok < C).reshape(B, S, K).astype(x.dtype) * gate_vals.astype(x.dtype)
    read_slot = (flat_e * C + jnp.minimum(pos_tok, C - 1))             # (B, N)
    out = jnp.take_along_axis(y, read_slot[..., None], axis=1)         # (B, N, D)
    out = out.reshape(B, S, K, D) * keep[..., None]
    return out.sum(2), aux
