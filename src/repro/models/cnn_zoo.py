"""CNN architecture zoo: conv-layer configurations of the networks the paper
profiles (Table 7 pool) and optimises (§4.3: AlexNet, VGG-11/19, GoogLeNet,
ResNet-18/34).

A network is a DAG over conv layers plus *join* nodes (concat / residual-add).
Join nodes are virtual PBQP nodes with one choice per data layout and zero
node cost; they keep branch/merge degrees small so the PBQP reduction solver
stays exact on inception-style modules (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    k: int      # kernels (output channels)
    c: int      # input channels
    im: int     # input spatial size (square)
    s: int      # stride
    f: int      # kernel size (square)

    @property
    def out_im(self) -> int:
        return (self.im - self.f) // self.s + 1

    @property
    def config(self) -> Tuple[int, int, int, int, int]:
        return (self.k, self.c, self.im, self.s, self.f)


@dataclasses.dataclass(frozen=True)
class JoinNode:
    """Virtual concat/add node; carries the tensor shape it produces."""
    name: str
    kind: str   # "concat" | "add"
    c: int
    im: int


@dataclasses.dataclass(frozen=True)
class EltwiseLayer:
    """Elementwise consumer (bias add / ReLU) — a fusion target for the plan
    compiler's epilogue pass (DESIGN.md §13.2). Like joins, eltwise nodes
    are virtual PBQP nodes with one choice per data layout; ``kind="bias"``
    carries a learned (c,) weight vector."""
    name: str
    kind: str   # "relu" | "bias"
    c: int
    im: int     # spatial size it produces (same as its producer's output)


Node = Union[ConvLayer, JoinNode, EltwiseLayer]


@dataclasses.dataclass
class CNNSpec:
    name: str
    nodes: List[Node]
    edges: List[Tuple[int, int]]          # (producer idx, consumer idx)

    @property
    def conv_layers(self) -> List[ConvLayer]:
        return [n for n in self.nodes if isinstance(n, ConvLayer)]

    def triplets(self) -> List[Tuple[int, int, int]]:
        return sorted({(l.c, l.k, l.im) for l in self.conv_layers})


class _Builder:
    def __init__(self, name: str):
        self.name = name
        self.nodes: List[Node] = []
        self.edges: List[Tuple[int, int]] = []

    def conv(self, k, c, im, s, f, prev: Union[int, None, Sequence[int]] = "last", tag="") -> int:
        idx = len(self.nodes)
        self.nodes.append(ConvLayer(f"{self.name}/{tag or 'conv'}{idx}", k, c, im, s, f))
        self._link(prev, idx)
        return idx

    def eltwise(self, kind, c, im, prev: Union[int, None, Sequence[int]] = "last", tag="") -> int:
        idx = len(self.nodes)
        self.nodes.append(EltwiseLayer(f"{self.name}/{tag or kind}{idx}", kind, c, im))
        self._link(prev, idx)
        return idx

    def join(self, kind, c, im, inputs: Sequence[int], tag="") -> int:
        idx = len(self.nodes)
        self.nodes.append(JoinNode(f"{self.name}/{tag or kind}{idx}", kind, c, im))
        for i in inputs:
            self.edges.append((i, idx))
        return idx

    def _link(self, prev, idx):
        if prev is None:
            return
        if prev == "last":
            if idx > 0:
                self.edges.append((idx - 1, idx))
            return
        if isinstance(prev, int):
            self.edges.append((prev, idx))
        else:
            for p in prev:
                self.edges.append((p, idx))

    def build(self) -> CNNSpec:
        return CNNSpec(self.name, self.nodes, self.edges)


# ---------------------------------------------------------------------------
# Chain families
# ---------------------------------------------------------------------------

def alexnet() -> CNNSpec:
    b = _Builder("alexnet")
    b.conv(64, 3, 224, 4, 11)
    b.conv(192, 64, 27, 1, 5)
    b.conv(384, 192, 13, 1, 3)
    b.conv(256, 384, 13, 1, 3)
    b.conv(256, 256, 13, 1, 3)
    return b.build()


_VGG_PLANS = {
    "vgg11": [(64, 1)], "vgg13": [(64, 2)], "vgg16": [(64, 2)], "vgg19": [(64, 2)],
}


def vgg(depth: int) -> CNNSpec:
    reps = {11: (1, 1, 2, 2, 2), 13: (2, 2, 2, 2, 2),
            16: (2, 2, 3, 3, 3), 19: (2, 2, 4, 4, 4)}[depth]
    chans = (64, 128, 256, 512, 512)
    ims = (224, 112, 56, 28, 14)
    b = _Builder(f"vgg{depth}")
    c_in = 3
    for (k, im, r) in zip(chans, ims, reps):
        for _ in range(r):
            b.conv(k, c_in, im, 1, 3)
            c_in = k
    return b.build()


def mobilenet_pointwise() -> CNNSpec:
    """MobileNet v1's standard convs + pointwise convs (depthwise omitted:
    grouped convs are outside the (k,c,im,s,f) parameterisation)."""
    b = _Builder("mobilenet")
    b.conv(32, 3, 224, 2, 3)
    plan = [(64, 32, 112), (128, 64, 56), (128, 128, 56), (256, 128, 28),
            (256, 256, 28), (512, 256, 14)] + [(512, 512, 14)] * 5 + \
           [(1024, 512, 7), (1024, 1024, 7)]
    for (k, c, im) in plan:
        b.conv(k, c, im, 1, 1)
    return b.build()


def squeezenet() -> CNNSpec:
    b = _Builder("squeezenet")
    prev = b.conv(96, 3, 224, 2, 7)
    fires = [(96, 16, 64, 64, 55), (128, 16, 64, 64, 55), (128, 32, 128, 128, 55),
             (256, 32, 128, 128, 27), (256, 48, 192, 192, 27), (384, 48, 192, 192, 27),
             (384, 64, 256, 256, 27), (512, 64, 256, 256, 13)]
    for (cin, sq, e1, e3, im) in fires:
        s = b.conv(sq, cin, im, 1, 1, prev=prev, tag="squeeze")
        a = b.conv(e1, sq, im, 1, 1, prev=s, tag="exp1")
        c = b.conv(e3, sq, im, 1, 3, prev=s, tag="exp3")
        prev = b.join("concat", e1 + e3, im - 2, [a, c])
    return b.build()


# ---------------------------------------------------------------------------
# ResNets
# ---------------------------------------------------------------------------

def resnet(depth: int) -> CNNSpec:
    blocks = {18: (2, 2, 2, 2), 34: (3, 4, 6, 3), 50: (3, 4, 6, 3)}[depth]
    bottleneck = depth >= 50
    b = _Builder(f"resnet{depth}")
    prev = b.conv(64, 3, 224, 2, 7)
    c_in, im = 64, 56
    widths = (64, 128, 256, 512)
    for stage, (width, nblk) in enumerate(zip(widths, blocks)):
        for blk in range(nblk):
            stride = 2 if (stage > 0 and blk == 0) else 1
            im_in = im * stride
            out_c = width * (4 if bottleneck else 1)
            if bottleneck:
                x1 = b.conv(width, c_in, im_in, 1, 1, prev=prev)
                x2 = b.conv(width, width, im_in, stride, 3, prev=x1)
                x3 = b.conv(out_c, width, im, 1, 1, prev=x2)
                tail = x3
            else:
                x1 = b.conv(width, c_in, im_in, stride, 3, prev=prev)
                x2 = b.conv(width, width, im, 1, 3, prev=x1)
                tail = x2
            if stride != 1 or c_in != out_c:
                sc = b.conv(out_c, c_in, im_in, stride, 1, prev=prev, tag="down")
                prev = b.join("add", out_c, im, [tail, sc])
            else:
                prev = b.join("add", out_c, im, [tail, prev])
            c_in = out_c
        im = im // 2 if stage < 3 else im
    return b.build()


# ---------------------------------------------------------------------------
# GoogLeNet (Inception v1)
# ---------------------------------------------------------------------------

_INCEPTION = [
    # (im, in_c, b1, b2red, b2, b3red, b3, b4)
    ("3a", 28, 192, 64, 96, 128, 16, 32, 32),
    ("3b", 28, 256, 128, 128, 192, 32, 96, 64),
    ("4a", 14, 480, 192, 96, 208, 16, 48, 64),
    ("4b", 14, 512, 160, 112, 224, 24, 64, 64),
    ("4c", 14, 512, 128, 128, 256, 24, 64, 64),
    ("4d", 14, 512, 112, 144, 288, 32, 64, 64),
    ("4e", 14, 528, 256, 160, 320, 32, 128, 128),
    ("5a", 7, 832, 256, 160, 320, 32, 128, 128),
    ("5b", 7, 832, 384, 192, 384, 48, 128, 128),
]


def googlenet() -> CNNSpec:
    b = _Builder("googlenet")
    c1 = b.conv(64, 3, 224, 2, 7)
    c2 = b.conv(64, 64, 56, 1, 1, prev=c1)
    c3 = b.conv(192, 64, 56, 1, 3, prev=c2)
    prev = c3
    for (tag, im, cin, b1, b2r, b2k, b3r, b3k, b4) in _INCEPTION:
        n1 = b.conv(b1, cin, im, 1, 1, prev=prev, tag=f"{tag}.b1")
        n2a = b.conv(b2r, cin, im, 1, 1, prev=prev, tag=f"{tag}.b2r")
        n2 = b.conv(b2k, b2r, im, 1, 3, prev=n2a, tag=f"{tag}.b2")
        n3a = b.conv(b3r, cin, im, 1, 1, prev=prev, tag=f"{tag}.b3r")
        n3 = b.conv(b3k, b3r, im, 1, 5, prev=n3a, tag=f"{tag}.b3")
        n4 = b.conv(b4, cin, im, 1, 1, prev=prev, tag=f"{tag}.b4")
        prev = b.join("concat", b1 + b2k + b3k + b4, im, [n1, n2, n3, n4], tag=f"{tag}.cat")
    return b.build()


# ---------------------------------------------------------------------------
# DenseNet-121 (pool contributor)
# ---------------------------------------------------------------------------

def densenet121() -> CNNSpec:
    b = _Builder("densenet121")
    b.conv(64, 3, 224, 2, 7)
    growth = 32
    c_in = 64
    for im, nlayers in ((56, 6), (28, 12), (14, 24), (7, 16)):
        for i in range(nlayers):
            b.conv(128, c_in + growth * i, im, 1, 1, tag="bottleneck")
            b.conv(growth, 128, im, 1, 3, tag="dense")
        c_in = (c_in + growth * nlayers) // 2
        if im > 7:
            b.conv(c_in, c_in * 2, im, 1, 1, tag="transition")
    return b.build()


def shufflenet_v2() -> CNNSpec:
    """ShuffleNet v2 x1.0 pointwise/3x3 stages (grouped convs folded to
    their (k,c,im) shapes — pool contributor)."""
    b = _Builder("shufflenet_v2")
    b.conv(24, 3, 224, 2, 3)
    for (im, cin, cout, n) in ((28, 24, 116, 4), (14, 116, 232, 8), (7, 232, 464, 4)):
        for i in range(n):
            c = cin if i == 0 else cout
            b.conv(cout // 2, c, im, 1, 1, tag="pw1")
            b.conv(cout // 2, cout // 2, im, 1, 3, tag="dwish")
            b.conv(cout // 2, cout // 2, im, 1, 1, tag="pw2")
    b.conv(1024, 464, 7, 1, 1, tag="head")
    return b.build()


def edge_cnn() -> CNNSpec:
    """Small 32x32 edge-class CNN (the serve example's deployment target):
    two stages of squeeze-style concats and residual adds — every join
    topology, MobileNet-like depth, at a scale where per-layer dispatch
    overhead, not FLOPs, dominates the interpreted executor."""
    b = _Builder("edge_cnn")
    c1 = b.conv(16, 3, 32, 1, 3)
    c2 = b.conv(32, 16, 30, 1, 3, prev=c1)
    a1 = b.conv(16, 32, 28, 1, 1, prev=c2, tag="exp1")
    a3 = b.conv(16, 32, 28, 1, 3, prev=c2, tag="exp3")
    cat = b.join("concat", 32, 26, [a1, a3])
    d1 = b.conv(32, 32, 26, 1, 3, prev=cat)
    d2 = b.conv(32, 32, 24, 1, 3, prev=d1)
    sc = b.conv(32, 32, 26, 1, 1, prev=cat, tag="down")
    add = b.join("add", 32, 22, [d2, sc])
    e1 = b.conv(48, 32, 22, 2, 3, prev=add)
    e2 = b.conv(48, 48, 10, 1, 3, prev=e1)
    f1 = b.conv(64, 48, 8, 1, 1, prev=e2, tag="exp1")
    f3 = b.conv(64, 48, 8, 1, 3, prev=e2, tag="exp3")
    cat2 = b.join("concat", 128, 6, [f1, f3])
    g1 = b.conv(64, 128, 6, 1, 3, prev=cat2)
    sc2 = b.conv(64, 128, 6, 1, 1, prev=cat2, tag="down")
    add2 = b.join("add", 64, 4, [g1, sc2])
    b.conv(96, 64, 4, 1, 3, prev=add2, tag="head")
    return b.build()


def inception_v3_pool() -> CNNSpec:
    """Inception-v3 stem + representative mixed-block convs (pool contributor)."""
    b = _Builder("inception_v3")
    b.conv(32, 3, 299, 2, 3)
    b.conv(32, 32, 149, 1, 3)
    b.conv(64, 32, 147, 1, 3)
    b.conv(80, 64, 73, 1, 1)
    b.conv(192, 80, 73, 1, 3)
    for (im, cin, outs) in ((35, 192, (64, 48, 64, 96)), (35, 256, (64, 48, 64, 96)),
                            (17, 768, (192, 128, 192, 192)), (8, 1280, (320, 384, 448, 192))):
        prev = len(b.nodes) - 1
        tails = []
        for k in outs:
            tails.append(b.conv(k, cin, im, 1, 1, prev=prev))
        f = 5 if im == 35 else 3
        tails.append(b.conv(outs[1], outs[1], im, 1, f, prev=tails[1]))
        b.join("concat", sum(outs) + outs[1], im - (f - 1), tails)
    return b.build()


def resnet_deep_pool(depth: int) -> CNNSpec:
    """ResNet-101/152 bottleneck conv shapes (pool contributors)."""
    blocks = {101: (3, 4, 23, 3), 152: (3, 8, 36, 3)}[depth]
    b = _Builder(f"resnet{depth}")
    b.conv(64, 3, 224, 2, 7)
    c_in, im = 64, 56
    for stage, (width, nblk) in enumerate(zip((64, 128, 256, 512), blocks)):
        for blk in range(min(nblk, 4)):   # shapes repeat; 4 reps cover the triplets
            stride = 2 if (stage > 0 and blk == 0) else 1
            b.conv(width, c_in, im * stride, 1, 1)
            b.conv(width, width, im * stride, stride, 3)
            b.conv(width * 4, width, im, 1, 1)
            c_in = width * 4
        im = im // 2 if stage < 3 else im
    return b.build()


ZOO = {
    "alexnet": alexnet,
    "edge_cnn": edge_cnn,
    "vgg11": lambda: vgg(11),
    "vgg13": lambda: vgg(13),
    "vgg16": lambda: vgg(16),
    "vgg19": lambda: vgg(19),
    "resnet18": lambda: resnet(18),
    "resnet34": lambda: resnet(34),
    "resnet50": lambda: resnet(50),
    "googlenet": googlenet,
    "squeezenet": squeezenet,
    "mobilenet": mobilenet_pointwise,
    "densenet121": densenet121,
    "shufflenet_v2": shufflenet_v2,
    "inception_v3": inception_v3_pool,
    "resnet101": lambda: resnet_deep_pool(101),
    "resnet152": lambda: resnet_deep_pool(152),
}

# the six networks the paper optimises (§4.3)
PAPER_SELECTION_NETS = ("alexnet", "vgg11", "vgg19", "googlenet", "resnet18", "resnet34")

# zoo entries whose DAGs are channel-consistent end to end and can be run by
# the executor (the rest are triplet *pool contributors*: chains of conv
# shapes whose grouped/concat plumbing is folded away, profile-only)
EXECUTABLE_NETS = ("alexnet", "edge_cnn", "vgg11", "vgg13", "vgg16", "vgg19",
                   "resnet18", "resnet34", "resnet50", "googlenet",
                   "squeezenet", "mobilenet")


def get(name: str) -> CNNSpec:
    return ZOO[name]()


def pool_triplets() -> List[Tuple[int, int, int]]:
    """(c, k, im) triplets across the zoo — the paper's Table 7 pool
    ('475 unique triplets' from common architectures)."""
    trip = set()
    for fn in ZOO.values():
        trip.update(fn().triplets())
    return sorted(trip)
