"""Mamba2 SSD (state-space duality) blocks — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: intra-chunk work is
attention-like batched GEMMs (MXU-friendly on TPU — this is the hardware
adaptation of SSD's GPU kernel, see DESIGN.md §2.3), inter-chunk state is a
small recurrence. Decode is the O(1)-per-token state update.

Shapes: d_inner = expand * d_model, nheads = d_inner / headdim.
x/z from in_proj; B, C per group (n_groups=1); dt per head; A scalar per
head (Mamba2's scalar-identity structure); depthwise causal conv on the
(x, B, C) channels.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.components import dense_init, rmsnorm, rmsnorm_init


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    chunk: int = 256
    n_groups: int = 1
    d_conv: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


def ssm_init(key, d_model: int, cfg: SSMConfig, dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 6)
    din = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    conv_dim = din + 2 * cfg.n_groups * cfg.d_state
    d_in_proj = 2 * din + 2 * cfg.n_groups * cfg.d_state + H
    return {
        "in_proj": dense_init(ks[0], d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_dim), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(din),
        "out_proj": dense_init(ks[2], din, d_model, dtype),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(…, L) -> (…, L, L) lower-triangular segment sums (SSD paper)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]                 # sum_{j<i<=k}
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.
    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) negative;
    B, C: (b, s, g, n) with g groups broadcast over h.
    Returns (y: (b, s, h, p), final_state: (b, h, p, n))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    nc = s // chunk
    assert nc * chunk == s, "sequence must be divisible by chunk"

    xs = x.reshape(b, nc, chunk, h, p)
    dts = dt.reshape(b, nc, chunk, h)
    Bs = B.reshape(b, nc, chunk, g, n)
    Cs = C.reshape(b, nc, chunk, g, n)
    dA = dts * A[None, None, None, :]                       # (b, nc, l, h)
    dA = jnp.moveaxis(dA, -1, 2)                            # (b, nc, h, l)
    dA_cum = jnp.cumsum(dA, axis=-1)

    # 1. intra-chunk (diagonal blocks): attention-like batched GEMMs
    Lmat = jnp.exp(_segsum(dA))                             # (b, nc, h, l, l)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(causal, Lmat, 0.0)
    xw = xs * dts[..., None]                                # dt-weighted input
    # scores: C_i . B_j per head-group
    scores = jnp.einsum("bcigs,bcjgs->bcgij", Cs, Bs)       # (b, nc, g, l, l)
    scores = jnp.repeat(scores, rep, axis=2)                # (b, nc, h, l, l)
    y_diag = jnp.einsum("bchij,bchij,bcjhp->bcihp", scores, Lmat.astype(scores.dtype), xw)

    # 2. chunk states
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)       # (b, nc, h, l)
    states = jnp.einsum("bclgs,bchl,bclhp->bchps",
                        Bs, decay_states.astype(Bs.dtype), xw)  # (b, nc, h, p, n)

    # 3. inter-chunk recurrence (small lax.scan over chunks)
    chunk_decay = jnp.exp(dA_cum[..., -1])                  # (b, nc, h)

    def body(carry, inp):
        st, dec = inp                                       # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                   # emit state BEFORE chunk

    s0 = (init_state if init_state is not None
          else jnp.zeros_like(states[:, 0]))
    final, prior = jax.lax.scan(body, s0,
                                (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prior = jnp.moveaxis(prior, 0, 1)                       # (b, nc, h, p, n)

    # 4. state -> output
    out_decay = jnp.exp(dA_cum)                             # (b, nc, h, l)
    y_off = jnp.einsum("bclgs,bchps,bchl->bclhp",
                       Cs, prior.astype(Cs.dtype), out_decay.astype(Cs.dtype))
    y = (y_diag + jnp.repeat(y_off, 1, axis=0)).reshape(b, s, h, p)
    return y, final


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. u: (B, S, C); w: (K, C). Returns (y, new_state)
    where state carries the last K-1 inputs for decode."""
    K = w.shape[0]
    if state is None:
        up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([state, u], axis=1)
    y = sum(up[:, i:i + u.shape[1] + 0, :] * w[i] for i in range(K))
    y = y[:, :u.shape[1], :] if y.shape[1] != u.shape[1] else y
    new_state = up[:, -(K - 1):, :]
    return jax.nn.silu(y + b), new_state


def ssm_block(params: Dict, x: jnp.ndarray, cfg: SSMConfig, d_model: int,
              return_state: bool = False):
    """Full Mamba2 block (train/prefill path). x: (B, S, D) -> (B, S, D).
    With ``return_state`` also returns (ssm_state, conv_state) for serving."""
    B_, S, D = x.shape
    din = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    g, n = cfg.n_groups, cfg.d_state

    zxbcdt = x @ params["in_proj"]["w"]
    z, xbc_raw, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * g * n], axis=-1)
    xbc, conv_state = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs, Bc, Cc = jnp.split(xbc, [din, din + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    xh = xs.reshape(B_, S, H, cfg.headdim)
    Bh = Bc.reshape(B_, S, g, n)
    Ch = Cc.reshape(B_, S, g, n)
    y, final_state = ssd_chunked(xh, dt, A, Bh, Ch, min(cfg.chunk, S))
    y = y + xh * params["D"][None, None, :, None]
    y = y.reshape(B_, S, din)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = (y @ params["out_proj"]["w"]).astype(x.dtype)
    if return_state:
        return out, final_state, xbc_raw[:, -(cfg.d_conv - 1):, :]
    return out


def ssm_decode_step(params: Dict, x: jnp.ndarray, cfg: SSMConfig, d_model: int,
                    ssm_state: jnp.ndarray, conv_state: jnp.ndarray,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (B, 1, D); ssm_state: (B, H, P, N);
    conv_state: (B, d_conv-1, conv_dim). Returns (y, ssm_state, conv_state)."""
    B_, _, D = x.shape
    din = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    g, n = cfg.n_groups, cfg.d_state

    zxbcdt = x @ params["in_proj"]["w"]
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * g * n], axis=-1)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xs, Bc, Cc = jnp.split(xbc, [din, din + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]   # (B, H)
    A = -jnp.exp(params["A_log"])

    xh = xs.reshape(B_, H, cfg.headdim)
    Bh = jnp.repeat(Bc.reshape(B_, g, n), H // g, axis=1)       # (B, H, N)
    Ch = jnp.repeat(Cc.reshape(B_, g, n), H // g, axis=1)
    dA = jnp.exp(dt * A[None, :])                               # (B, H)
    upd = (dt[..., None] * xh)[..., None] * Bh[:, :, None, :]   # (B, H, P, N)
    ssm_state = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state.astype(Ch.dtype), Ch)
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(B_, 1, din)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return (y @ params["out_proj"]["w"]).astype(x.dtype), ssm_state, conv_state
