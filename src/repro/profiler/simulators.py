"""Analytic platform simulators for primitive execution time (DESIGN.md §2.1).

The container has one CPU, so the paper's three profiled machines (Intel
i9-9900K, AMD A10-7850K, ARM Cortex-A73) are replaced by parameterised
analytic timing models with realistic *structure*:

  * compute term: GEMM-shaped work runs at ``peak * eff(M, N, K)`` where the
    efficiency saturates in each dimension (small-dim penalties) and depends
    on SIMD width utilisation (``-vec-N`` variants);
  * memory term: ``bytes / bw(working_set)`` with a cache-hierarchy bandwidth
    staircase (L1/L2/L3/DRAM cliffs at platform-specific sizes);
  * family-specific work models: im2col pays lowering traffic, kn2 computes
    on the full image and pays accumulate traffic, Winograd pays transform
    FLOPs + tile-quantisation waste, MEC keeps a small working set but pays
    partitioned-GEMM overheads, direct has no lowering but poor compute
    efficiency;
  * per-call overhead and deterministic multiplicative lognormal noise
    (σ: intel 2.5%, amd 3%, arm 6% — the paper's observed MdRAE floors).

Crucially, platforms are *correlated but not proportional* in log-time:
cache-cliff positions, SIMD widths and GEMM efficiencies differ, so a model
trained on one platform transfers imperfectly — a constant per-primitive
factor helps (paper's "Factor Intel") but fine-tuning is required to close
the gap. This is the structure the paper's transfer study measures.

Batched estimation (DESIGN.md §2.4): ``primitive_time_batch`` and
``dlt_time_batch`` evaluate the family models for *all* configs × *all*
registry columns in one numpy broadcast pass, with the registry traits
pre-compiled into per-column arrays (``repro.primitives.conv.compile_traits``).
The lognormal noise is a counter-based hash stream (splitmix64 finaliser over
the integer key fields) rather than a per-call sha256, so a whole noise
matrix is one vectorised evaluation; the scalar APIs ``primitive_time`` /
``dlt_time`` delegate to 1×1 batches and are therefore bit-compatible with
the batched path.

Times are in seconds.
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.primitives.conv import (FAMILIES, PRIMITIVE_NAMES, REGISTRY,
                                   T_VARIANTS, Primitive, compile_traits,
                                   name_hash64, out_size)
from repro.primitives import layouts as L


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    clock_ghz: float
    vec_width: int          # fp32 lanes
    fma_ports: int
    gemm_eff: float         # best-case fraction of peak for large GEMM
    l1_kb: float
    l2_kb: float
    l3_kb: float            # 0 => no L3
    bw_l1: float            # GB/s
    bw_l2: float
    bw_l3: float
    bw_dram: float
    overhead_us: float      # per primitive call
    noise_sigma: float
    # efficiency saturation constants (smaller = less small-dim penalty)
    sat_m: float
    sat_n: float
    sat_k: float
    transpose_eff: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def peak_gflops(self) -> float:
        return self.clock_ghz * self.vec_width * self.fma_ports * 2.0


INTEL = Platform(
    name="intel", clock_ghz=5.0, vec_width=8, fma_ports=2, gemm_eff=0.88,
    l1_kb=32, l2_kb=256, l3_kb=16384, bw_l1=400, bw_l2=180, bw_l3=90,
    bw_dram=38, overhead_us=1.5, noise_sigma=0.025,
    sat_m=10, sat_n=28, sat_k=22,
    transpose_eff={"adjacent": 0.62, "full": 0.38})

AMD = Platform(
    name="amd", clock_ghz=3.7, vec_width=8, fma_ports=1, gemm_eff=0.74,
    l1_kb=16, l2_kb=2048, l3_kb=0, bw_l1=220, bw_l2=80, bw_l3=0,
    bw_dram=18, overhead_us=2.8, noise_sigma=0.030,
    sat_m=14, sat_n=40, sat_k=30,
    transpose_eff={"adjacent": 0.5, "full": 0.3})

ARM = Platform(
    name="arm", clock_ghz=2.36, vec_width=4, fma_ports=1, gemm_eff=0.62,
    l1_kb=32, l2_kb=1024, l3_kb=0, bw_l1=90, bw_l2=35, bw_l3=0,
    bw_dram=7.5, overhead_us=6.0, noise_sigma=0.060,
    sat_m=18, sat_n=64, sat_k=44,
    transpose_eff={"adjacent": 0.42, "full": 0.22})

PLATFORMS: Dict[str, Platform] = {"intel": INTEL, "amd": AMD, "arm": ARM}


# ---------------------------------------------------------------------------
# Building blocks (broadcasting — accept scalars or arrays)
# ---------------------------------------------------------------------------

def _bw(plat: Platform, working_set_bytes) -> np.ndarray:
    """Cache staircase, GB/s (smoothed cliffs)."""
    kb = working_set_bytes / 1024.0
    levels = [(plat.l1_kb, plat.bw_l1), (plat.l2_kb, plat.bw_l2)]
    if plat.l3_kb:
        levels.append((plat.l3_kb, plat.bw_l3))
    bw = plat.bw_dram
    for size, level_bw in reversed(levels):
        # logistic blend around each cliff
        frac = 1.0 / (1.0 + np.exp(4.0 * (np.log(kb + 1e-9) - math.log(size))))
        bw = bw + frac * (level_bw - bw)
    return bw


def _gemm_time(plat: Platform, M, N, K, vec, trans_penalty=1.0) -> np.ndarray:
    """Seconds for a (M,K)x(K,N) fp32 GEMM on this platform.

    ``vec`` is a per-column float array of explicit SIMD widths with 0.0
    meaning "unspecified" (no adjustment); ``trans_penalty`` broadcasts the
    same way. Operation order mirrors the original scalar model exactly.
    """
    flops = 2.0 * M * N * K
    eff = (plat.gemm_eff
           * M / (M + plat.sat_m)
           * N / (N + plat.sat_n)
           * K / (K + plat.sat_k))
    # SIMD-width variants: perfect fit gives a bonus, overwide ops are
    # emulated (severe), narrow explicit vec under-uses wide units (mild).
    vec = np.asarray(vec, np.float64)
    safe = np.where(vec == 0.0, 1.0, vec)
    factor = np.where(vec == 0.0, 1.0,
                      np.where(vec > plat.vec_width,
                               0.30 * plat.vec_width / safe,
                               np.where(vec == plat.vec_width, 1.12,
                                        0.72 + 0.28 * vec / plat.vec_width)))
    eff = eff * factor
    eff = eff / trans_penalty
    t_compute = flops / (plat.peak_gflops * 1e9 * np.maximum(eff, 1e-3))
    ws = 4.0 * (M * K + K * N + M * N)
    t_mem = ws / (_bw(plat, ws) * 1e9)
    return np.maximum(t_compute, t_mem)


def _stream_time(plat: Platform, bytes_moved, footprint, eff=1.0) -> np.ndarray:
    return bytes_moved / (_bw(plat, footprint) * 1e9 * eff)


# ---------------------------------------------------------------------------
# Counter-based noise stream (splitmix64 finaliser over integer key fields)
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1
_MASK52 = (1 << 52) - 1
_MIX_A = 0xBF58476D1CE4E5B9
_MIX_B = 0x94D049BB133111EB


def _mix64(x: np.ndarray) -> np.ndarray:
    """Vectorised splitmix64 finaliser on uint64 arrays."""
    x = (x ^ (x >> np.uint64(30))) * np.uint64(_MIX_A)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(_MIX_B)
    return x ^ (x >> np.uint64(31))


def _mix64_int(x: int) -> int:
    x &= _MASK64
    x = ((x ^ (x >> 30)) * _MIX_A) & _MASK64
    x = ((x ^ (x >> 27)) * _MIX_B) & _MASK64
    return x ^ (x >> 31)


@lru_cache(maxsize=64)
def _plat_key(name: str) -> int:
    return name_hash64("plat|" + name)


def _noise_from_hash(plat: Platform, h: np.ndarray) -> np.ndarray:
    u = (h & np.uint64(_MASK52)).astype(np.float64) / float(1 << 52)
    v = ((h >> np.uint64(8)) & np.uint64(_MASK52)).astype(np.float64) / float(1 << 52)
    # Box-Muller
    z = np.sqrt(-2.0 * np.log(np.maximum(u, 1e-12))) * np.cos(2 * np.pi * v)
    return np.exp(plat.noise_sigma * z)


def _noise_matrix(plat: Platform, col_keys: np.ndarray, *fields) -> np.ndarray:
    """(L, P) lognormal noise: one hash stream per (column, field-tuple)."""
    h = _mix64(np.uint64(_plat_key(plat.name)) ^ col_keys.astype(np.uint64)[None, :])
    for f in fields:
        h = _mix64(h ^ np.asarray(f, np.uint64)[:, None])
    return _noise_from_hash(plat, h)


def _noise_scalar(plat: Platform, col_key: int, *fields: int) -> float:
    """Scalar twin of ``_noise_matrix`` (same stream, python-int hashing)."""
    h = _mix64_int(_plat_key(plat.name) ^ col_key)
    for f in fields:
        h = _mix64_int(h ^ int(f))
    u = (h & _MASK52) / float(1 << 52)
    v = ((h >> 8) & _MASK52) / float(1 << 52)
    z = math.sqrt(-2.0 * math.log(max(u, 1e-12))) * math.cos(2 * math.pi * v)
    return math.exp(plat.noise_sigma * z)


_TRANS_PENALTY = {None: 1.0, "atb": 1.06, "abt": 1.06, "atbt": 1.16}

# transpose penalty per T_VARIANTS code, for vectorised lookup
_TRANS_TABLE = np.array([_TRANS_PENALTY[v] for v in T_VARIANTS], np.float64)

_DLT_PAIRS_NI: Tuple[Tuple[str, str], ...] = tuple(
    (s, d) for (s, d) in L.dlt_pairs() if s != d)
_DLT_FULL = np.array([{s, d} == {"chw", "hwc"} for (s, d) in _DLT_PAIRS_NI])
_DLT_KEYS = np.array([name_hash64("dlt|" + L.dlt_name(s, d))
                      for (s, d) in _DLT_PAIRS_NI], np.uint64)


# ---------------------------------------------------------------------------
# Batched per-family time models
# ---------------------------------------------------------------------------

def primitive_time_batch(plat: Platform, configs: np.ndarray,
                         noisy: bool = True,
                         columns: Optional[Sequence[str]] = None) -> np.ndarray:
    """Simulated execution times for every (config, registry column) pair.

    ``configs`` is (L, 5) integer rows (k, c, im, s, f); returns an (L, P)
    float matrix in ``columns`` order (default: the full registry), NaN where
    a primitive is inapplicable. One broadcast pass over the family models —
    no Python loop over layers or primitives.
    """
    cfg = np.asarray(configs)
    if cfg.ndim != 2 or cfg.shape[1] != 5:
        raise ValueError(f"configs must be (L, 5), got {cfg.shape}")
    names = tuple(columns) if columns is not None else tuple(PRIMITIVE_NAMES)
    tr = compile_traits(names)
    cfg = cfg.astype(np.int64)
    ki, ci, imi, si, fi = (cfg[:, j] for j in range(5))
    app = tr.applicable_mask(ki, ci, imi, si, fi)            # (L, P)

    k, c, im, s, f = (a.astype(np.float64)[:, None] for a in (ki, ci, imi, si, fi))
    o_int = ((imi - fi) // si + 1)[:, None]                  # (L, 1) int
    o = o_int.astype(np.float64)
    P = o * o
    in_bytes = 4.0 * c * im * im
    w_bytes = 4.0 * k * c * f * f
    out_bytes = 4.0 * k * P
    base = plat.overhead_us * 1e-6

    out = np.empty((cfg.shape[0], len(names)), np.float64)
    fam = tr.fam
    with np.errstate(all="ignore"):
        cols = np.nonzero(fam == FAMILIES.index("direct"))[0]
        if cols.size:
            # no lowering; poor compute efficiency (no blocking), input
            # re-read f*f times when it does not fit cache.
            flops = 2.0 * k * c * f * f * P
            eff = 0.22 * (plat.vec_width / 8.0) ** 0.25
            t_cmp = flops / (plat.peak_gflops * 1e9 * eff)
            reread = np.where(in_bytes > plat.l2_kb * 1024, f * f, 1.0)
            t_mem = _stream_time(plat, in_bytes * reread + w_bytes + out_bytes,
                                 in_bytes)
            out[:, cols] = base + np.maximum(t_cmp, t_mem)

        cols = np.nonzero(fam == FAMILIES.index("im2"))[0]
        if cols.size:
            vec = tr.vec[cols]
            trans = _TRANS_TABLE[tr.t_idx[cols]]
            lower_bytes = 4.0 * c * f * f * P
            # copy materialises the patch matrix (write+read), scan gathers
            # with poorer locality but half the traffic.
            t_scan = _stream_time(plat, lower_bytes, in_bytes, eff=0.45)
            t_copy = _stream_time(plat, 2.0 * lower_bytes, lower_bytes, eff=0.85)
            t_lower = np.where(tr.scan[cols][None, :], t_scan, t_copy)
            t_g = _gemm_time(plat, k, P, c * f * f, vec, trans)
            # ki (chw) output from pixel-major GEMM pays a strided-write factor
            eff_out = np.where(tr.order_ki[cols], 0.8, 1.0)[None, :]
            t_out = _stream_time(plat, out_bytes, out_bytes, eff=eff_out)
            out[:, cols] = base + t_lower + t_g + t_out

        cols = np.nonzero(fam == FAMILIES.index("kn2"))[0]
        if cols.size:
            vec = tr.vec[cols]
            trans = _TRANS_TABLE[tr.t_idx[cols]]
            # f*f GEMMs over the FULL image + shifted accumulation traffic.
            t_g = f * f * _gemm_time(plat, k, im * im, c, vec, trans)
            acc_bytes = 4.0 * k * P * f * f * 2.0
            t_acc = _stream_time(plat, acc_bytes, 4.0 * k * im * im, eff=0.7)
            # "-as" variants: single fused reduction
            t_acc = t_acc * np.where(tr.variant_as[cols], 0.8, 1.0)[None, :]
            out[:, cols] = base + t_g + t_acc

        cols = np.nonzero((fam == FAMILIES.index("wino3"))
                          | (fam == FAMILIES.index("wino5")))[0]
        if cols.size:
            vec = tr.vec[cols]
            m = tr.tile_m[cols][None, :]                     # (1, W) int
            r = fi[:, None]                                  # (L, 1) int
            n = m + r - 1                                    # (L, W) int
            oned = tr.oned[cols][None, :]
            # 1-D: rows x row-tiles; 2-D: tile quantisation waste
            tiles1 = o_int * (-(-o_int // m))
            th = -(-o_int // m)
            tiles2 = th * th
            tiles = np.where(oned, tiles1, tiles2)
            tr_flops = np.where(
                oned,
                2.0 * (c + k) * tiles1 * n * n + 2.0 * k * tiles1 * m * n,
                (2.0 * c * tiles2 * 2 * n * n * n        # input transform
                 + 2.0 * k * c * 2 * n * n * r           # kernel transform
                 + 2.0 * k * tiles2 * 2 * n * n * m))    # output transform
            gemms1 = r * n                                # r kernel-rows x n points
            t_g = np.where(
                oned,
                gemms1 * _gemm_time(plat, k, tiles1 / np.maximum(1, n), c, vec),
                n * n * _gemm_time(plat, k, tiles2, c, vec))
            t_tr = tr_flops / (plat.peak_gflops * 1e9 * 0.35)
            t_mem = _stream_time(plat, in_bytes + out_bytes + 4.0 * c * tiles * n * n,
                                 4.0 * c * tiles * n * n, eff=0.8)
            out[:, cols] = base + t_g + t_tr + t_mem

        cols = np.nonzero(fam == FAMILIES.index("c1x1"))[0]
        if cols.size:
            vec = tr.vec[cols]
            trans = _TRANS_TABLE[tr.t_idx[cols]]
            t_g = _gemm_time(plat, k, P, c, vec, trans)
            strided = np.where(s == 1.0, 1.0, 0.6)
            t_mem = _stream_time(plat, in_bytes / (s * s) + out_bytes, in_bytes,
                                 eff=strided)
            out[:, cols] = base + t_g + t_mem

        cols = np.nonzero(fam == FAMILIES.index("mec"))[0]
        if cols.size:
            vec = tr.vec[cols]
            # partial lowering: ow strips of (h x f) columns; f partitioned
            # GEMMs, each seeing a smaller K (worse efficiency) and a small
            # per-partition call overhead — MEC trades time for memory.
            lower_bytes = 4.0 * c * im * f * o
            t_lower = _stream_time(plat, 2.0 * lower_bytes, lower_bytes, eff=0.8)
            t_g = f * _gemm_time(plat, k, P, c * f, vec)
            t_part = f * plat.overhead_us * 0.3e-6
            out[:, cols] = base + t_lower + t_g + t_part

        if noisy:
            out = out * _noise_matrix(plat, tr.key, ki, ci, imi, si, fi)
    out[~app] = np.nan
    return out


def dlt_time_batch(plat: Platform, pairs: np.ndarray,
                   noisy: bool = True) -> np.ndarray:
    """Simulated DLT times for every ((c, im) pair, non-identity layout pair).

    ``pairs`` is (M, 2) integer rows (c, im); returns (M, 6) in
    ``layouts.dlt_pairs()`` order with identity pairs excluded.
    """
    pr = np.asarray(pairs)
    if pr.ndim != 2 or pr.shape[1] != 2:
        raise ValueError(f"pairs must be (M, 2), got {pr.shape}")
    pr = pr.astype(np.int64)
    ci, imi = pr[:, 0], pr[:, 1]
    c, im = (a.astype(np.float64)[:, None] for a in (ci, imi))
    bytes_moved = 2.0 * 4.0 * c * im * im
    # chw<->hwc moves the innermost axis (worst); others swap adjacent axes.
    eff = np.where(_DLT_FULL, plat.transpose_eff["full"],
                   plat.transpose_eff["adjacent"])[None, :]
    tm = plat.overhead_us * 0.5e-6 + _stream_time(plat, bytes_moved,
                                                  bytes_moved / 2, eff=eff)
    if noisy:
        tm = tm * _noise_matrix(plat, _DLT_KEYS, ci, imi)
    return tm


# ---------------------------------------------------------------------------
# Scalar API (delegates to 1×1 batches — bit-compatible with the batch path)
# ---------------------------------------------------------------------------

def primitive_time(plat: Platform, prim: Primitive,
                   k: int, c: int, im: int, s: int, f: int,
                   noisy: bool = True) -> float:
    """Simulated execution time (seconds) of ``prim`` on layer (k,c,im,s,f).
    Returns NaN if the primitive is inapplicable."""
    if prim.name not in REGISTRY:
        # ad-hoc Primitive instances can't go through the compiled-trait
        # batch path; fall back to the per-call reference model
        return _primitive_time_scalar(plat, prim, k, c, im, s, f, noisy=noisy)
    mat = primitive_time_batch(plat, np.array([[k, c, im, s, f]], np.int64),
                               noisy=noisy, columns=(prim.name,))
    return float(mat[0, 0])


def dlt_time(plat: Platform, src: str, dst: str, c: int, im: int,
             noisy: bool = True) -> float:
    """Simulated data-layout-transformation time (seconds)."""
    if src == dst:
        return 0.0
    col = _DLT_PAIRS_NI.index((src, dst))
    return float(dlt_time_batch(plat, np.array([[c, im]], np.int64),
                                noisy=noisy)[0, col])


# ---------------------------------------------------------------------------
# Scalar reference models (the pre-vectorisation implementation, kept as an
# independent oracle for equivalence tests and as the seed-equivalent
# baseline in benchmarks/selection_throughput.py)
# ---------------------------------------------------------------------------

def _bw_scalar(plat: Platform, working_set_bytes: float) -> float:
    kb = working_set_bytes / 1024.0
    levels = [(plat.l1_kb, plat.bw_l1), (plat.l2_kb, plat.bw_l2)]
    if plat.l3_kb:
        levels.append((plat.l3_kb, plat.bw_l3))
    bw = plat.bw_dram
    for size, level_bw in reversed(levels):
        frac = 1.0 / (1.0 + math.exp(4.0 * (math.log(kb + 1e-9) - math.log(size))))
        bw = bw + frac * (level_bw - bw)
    return bw


def _gemm_time_scalar(plat: Platform, M: float, N: float, K: float,
                      vec: Optional[int], trans_penalty: float = 1.0) -> float:
    flops = 2.0 * M * N * K
    eff = (plat.gemm_eff
           * M / (M + plat.sat_m)
           * N / (N + plat.sat_n)
           * K / (K + plat.sat_k))
    if vec is not None:
        if vec > plat.vec_width:
            eff *= 0.30 * plat.vec_width / vec
        elif vec == plat.vec_width:
            eff *= 1.12
        else:
            eff *= 0.72 + 0.28 * vec / plat.vec_width
    eff /= trans_penalty
    t_compute = flops / (plat.peak_gflops * 1e9 * max(eff, 1e-3))
    ws = 4.0 * (M * K + K * N + M * N)
    t_mem = ws / (_bw_scalar(plat, ws) * 1e9)
    return max(t_compute, t_mem)


def _stream_time_scalar(plat: Platform, bytes_moved: float, footprint: float,
                        eff: float = 1.0) -> float:
    return bytes_moved / (_bw_scalar(plat, footprint) * 1e9 * eff)


def _primitive_time_scalar(plat: Platform, prim: Primitive,
                           k: int, c: int, im: int, s: int, f: int,
                           noisy: bool = True) -> float:
    """Pre-vectorisation per-call model — one (layer, primitive) at a time."""
    if not prim.applicable(k, c, im, s, f):
        return float("nan")
    o = out_size(im, f, s)
    P = o * o
    t = prim.traits
    vec = t.get("vec")
    trans = _TRANS_PENALTY.get(t.get("t"), 1.0)
    fam = prim.family
    in_bytes = 4.0 * c * im * im
    w_bytes = 4.0 * k * c * f * f
    out_bytes = 4.0 * k * P
    base = plat.overhead_us * 1e-6

    if fam == "direct":
        flops = 2.0 * k * c * f * f * P
        eff = 0.22 * (plat.vec_width / 8.0) ** 0.25
        t_cmp = flops / (plat.peak_gflops * 1e9 * eff)
        reread = f * f if in_bytes > plat.l2_kb * 1024 else 1.0
        t_mem = _stream_time_scalar(plat, in_bytes * reread + w_bytes + out_bytes, in_bytes)
        time = base + max(t_cmp, t_mem)

    elif fam == "im2":
        lower_bytes = 4.0 * c * f * f * P
        scan = t.get("trav") == "scan"
        if scan:
            t_lower = _stream_time_scalar(plat, lower_bytes, in_bytes, eff=0.45)
        else:
            t_lower = _stream_time_scalar(plat, 2.0 * lower_bytes, lower_bytes, eff=0.85)
        t_g = _gemm_time_scalar(plat, k, P, c * f * f, vec, trans)
        t_out = _stream_time_scalar(plat, out_bytes, out_bytes,
                                    eff=0.8 if t.get("order") == "ki" else 1.0)
        time = base + t_lower + t_g + t_out

    elif fam == "kn2":
        t_g = f * f * _gemm_time_scalar(plat, k, im * im, c, vec, trans)
        acc_bytes = 4.0 * k * P * f * f * 2.0
        t_acc = _stream_time_scalar(plat, acc_bytes, 4.0 * k * im * im, eff=0.7)
        variant = t.get("variant", "")
        if variant.startswith("as"):
            t_acc *= 0.8
        time = base + t_g + t_acc

    elif fam in ("wino3", "wino5"):
        m = t["tile_m"]; r = f
        n = m + r - 1
        if t.get("oned"):
            tiles = o * (-(-o // m))
            tr_flops = 2.0 * (c + k) * tiles * n * n + 2.0 * k * tiles * m * n
            gemms = r * n
            t_g = gemms * _gemm_time_scalar(plat, k, tiles / max(1, n), c, vec)
        else:
            th = -(-o // m)
            tiles = th * th
            tr_flops = (2.0 * c * tiles * 2 * n * n * n
                        + 2.0 * k * c * 2 * n * n * r
                        + 2.0 * k * tiles * 2 * n * n * m)
            t_g = n * n * _gemm_time_scalar(plat, k, tiles, c, vec)
        t_tr = tr_flops / (plat.peak_gflops * 1e9 * 0.35)
        t_mem = _stream_time_scalar(plat, in_bytes + out_bytes + 4.0 * c * tiles * n * n,
                                    4.0 * c * tiles * n * n, eff=0.8)
        time = base + t_g + t_tr + t_mem

    elif fam == "c1x1":
        t_g = _gemm_time_scalar(plat, k, P, c, vec, trans)
        strided = 1.0 if s == 1 else 0.6
        t_mem = _stream_time_scalar(plat, in_bytes / (s * s) + out_bytes, in_bytes, eff=strided)
        time = base + t_g + t_mem

    elif fam == "mec":
        lower_bytes = 4.0 * c * im * f * o
        t_lower = _stream_time_scalar(plat, 2.0 * lower_bytes, lower_bytes, eff=0.8)
        t_g = f * _gemm_time_scalar(plat, k, P, c * f, vec)
        t_part = f * plat.overhead_us * 0.3e-6
        time = base + t_lower + t_g + t_part

    else:  # pragma: no cover
        raise ValueError(fam)

    if noisy:
        time *= _noise_scalar(plat, name_hash64(prim.name), k, c, im, s, f)
    return time


def _dlt_time_scalar(plat: Platform, src: str, dst: str, c: int, im: int,
                     noisy: bool = True) -> float:
    if src == dst:
        return 0.0
    bytes_moved = 2.0 * 4.0 * c * im * im
    kind = "full" if {src, dst} == {"chw", "hwc"} else "adjacent"
    eff = plat.transpose_eff[kind]
    tm = plat.overhead_us * 0.5e-6 + _stream_time_scalar(plat, bytes_moved,
                                                         bytes_moved / 2, eff=eff)
    if noisy:
        tm *= _noise_scalar(plat, name_hash64("dlt|" + L.dlt_name(src, dst)), c, im)
    return tm
