"""Analytic platform simulators for primitive execution time (DESIGN.md §2.1).

The container has one CPU, so the paper's three profiled machines (Intel
i9-9900K, AMD A10-7850K, ARM Cortex-A73) are replaced by parameterised
analytic timing models with realistic *structure*:

  * compute term: GEMM-shaped work runs at ``peak * eff(M, N, K)`` where the
    efficiency saturates in each dimension (small-dim penalties) and depends
    on SIMD width utilisation (``-vec-N`` variants);
  * memory term: ``bytes / bw(working_set)`` with a cache-hierarchy bandwidth
    staircase (L1/L2/L3/DRAM cliffs at platform-specific sizes);
  * family-specific work models: im2col pays lowering traffic, kn2 computes
    on the full image and pays accumulate traffic, Winograd pays transform
    FLOPs + tile-quantisation waste, MEC keeps a small working set but pays
    partitioned-GEMM overheads, direct has no lowering but poor compute
    efficiency;
  * per-call overhead and deterministic multiplicative lognormal noise
    (σ: intel 2.5%, amd 3%, arm 6% — the paper's observed MdRAE floors).

Crucially, platforms are *correlated but not proportional* in log-time:
cache-cliff positions, SIMD widths and GEMM efficiencies differ, so a model
trained on one platform transfers imperfectly — a constant per-primitive
factor helps (paper's "Factor Intel") but fine-tuning is required to close
the gap. This is the structure the paper's transfer study measures.

Times are in seconds.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, Optional

import numpy as np

from repro.primitives.conv import REGISTRY, Primitive, out_size
from repro.primitives import layouts as L


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    clock_ghz: float
    vec_width: int          # fp32 lanes
    fma_ports: int
    gemm_eff: float         # best-case fraction of peak for large GEMM
    l1_kb: float
    l2_kb: float
    l3_kb: float            # 0 => no L3
    bw_l1: float            # GB/s
    bw_l2: float
    bw_l3: float
    bw_dram: float
    overhead_us: float      # per primitive call
    noise_sigma: float
    # efficiency saturation constants (smaller = less small-dim penalty)
    sat_m: float
    sat_n: float
    sat_k: float
    transpose_eff: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def peak_gflops(self) -> float:
        return self.clock_ghz * self.vec_width * self.fma_ports * 2.0


INTEL = Platform(
    name="intel", clock_ghz=5.0, vec_width=8, fma_ports=2, gemm_eff=0.88,
    l1_kb=32, l2_kb=256, l3_kb=16384, bw_l1=400, bw_l2=180, bw_l3=90,
    bw_dram=38, overhead_us=1.5, noise_sigma=0.025,
    sat_m=10, sat_n=28, sat_k=22,
    transpose_eff={"adjacent": 0.62, "full": 0.38})

AMD = Platform(
    name="amd", clock_ghz=3.7, vec_width=8, fma_ports=1, gemm_eff=0.74,
    l1_kb=16, l2_kb=2048, l3_kb=0, bw_l1=220, bw_l2=80, bw_l3=0,
    bw_dram=18, overhead_us=2.8, noise_sigma=0.030,
    sat_m=14, sat_n=40, sat_k=30,
    transpose_eff={"adjacent": 0.5, "full": 0.3})

ARM = Platform(
    name="arm", clock_ghz=2.36, vec_width=4, fma_ports=1, gemm_eff=0.62,
    l1_kb=32, l2_kb=1024, l3_kb=0, bw_l1=90, bw_l2=35, bw_l3=0,
    bw_dram=7.5, overhead_us=6.0, noise_sigma=0.060,
    sat_m=18, sat_n=64, sat_k=44,
    transpose_eff={"adjacent": 0.42, "full": 0.22})

PLATFORMS: Dict[str, Platform] = {"intel": INTEL, "amd": AMD, "arm": ARM}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def _bw(plat: Platform, working_set_bytes: float) -> float:
    """Cache staircase, GB/s (smoothed cliffs)."""
    kb = working_set_bytes / 1024.0
    levels = [(plat.l1_kb, plat.bw_l1), (plat.l2_kb, plat.bw_l2)]
    if plat.l3_kb:
        levels.append((plat.l3_kb, plat.bw_l3))
    bw = plat.bw_dram
    for size, level_bw in reversed(levels):
        # logistic blend around each cliff
        frac = 1.0 / (1.0 + math.exp(4.0 * (math.log(kb + 1e-9) - math.log(size))))
        bw = bw + frac * (level_bw - bw)
    return bw


def _gemm_time(plat: Platform, M: float, N: float, K: float,
               vec: Optional[int], trans_penalty: float = 1.0) -> float:
    """Seconds for a (M,K)x(K,N) fp32 GEMM on this platform."""
    flops = 2.0 * M * N * K
    eff = (plat.gemm_eff
           * M / (M + plat.sat_m)
           * N / (N + plat.sat_n)
           * K / (K + plat.sat_k))
    # SIMD-width variants: perfect fit gives a bonus, overwide ops are
    # emulated (severe), narrow explicit vec under-uses wide units (mild).
    if vec is not None:
        if vec > plat.vec_width:
            eff *= 0.30 * plat.vec_width / vec
        elif vec == plat.vec_width:
            eff *= 1.12
        else:
            eff *= 0.72 + 0.28 * vec / plat.vec_width
    eff /= trans_penalty
    t_compute = flops / (plat.peak_gflops * 1e9 * max(eff, 1e-3))
    ws = 4.0 * (M * K + K * N + M * N)
    t_mem = ws / (_bw(plat, ws) * 1e9)
    return max(t_compute, t_mem)


def _stream_time(plat: Platform, bytes_moved: float, footprint: float,
                 eff: float = 1.0) -> float:
    return bytes_moved / (_bw(plat, footprint) * 1e9 * eff)


def _noise(plat: Platform, key: str) -> float:
    h = int.from_bytes(hashlib.sha256(key.encode()).digest()[:8], "big")
    u = (h % (1 << 52)) / float(1 << 52)
    v = ((h >> 8) % (1 << 52)) / float(1 << 52)
    # Box-Muller
    z = math.sqrt(-2.0 * math.log(max(u, 1e-12))) * math.cos(2 * math.pi * v)
    return math.exp(plat.noise_sigma * z)


_TRANS_PENALTY = {None: 1.0, "atb": 1.06, "abt": 1.06, "atbt": 1.16}


# ---------------------------------------------------------------------------
# Per-family time models
# ---------------------------------------------------------------------------

def primitive_time(plat: Platform, prim: Primitive,
                   k: int, c: int, im: int, s: int, f: int,
                   noisy: bool = True) -> float:
    """Simulated execution time (seconds) of ``prim`` on layer (k,c,im,s,f).
    Returns NaN if the primitive is inapplicable."""
    if not prim.applicable(k, c, im, s, f):
        return float("nan")
    o = out_size(im, f, s)
    P = o * o
    t = prim.traits
    vec = t.get("vec")
    trans = _TRANS_PENALTY.get(t.get("t"), 1.0)
    fam = prim.family
    in_bytes = 4.0 * c * im * im
    w_bytes = 4.0 * k * c * f * f
    out_bytes = 4.0 * k * P
    base = plat.overhead_us * 1e-6

    if fam == "direct":
        # no lowering; poor compute efficiency (no blocking), input re-read
        # f*f times when it does not fit cache.
        flops = 2.0 * k * c * f * f * P
        eff = 0.22 * (plat.vec_width / 8.0) ** 0.25
        t_cmp = flops / (plat.peak_gflops * 1e9 * eff)
        reread = f * f if in_bytes > plat.l2_kb * 1024 else 1.0
        t_mem = _stream_time(plat, in_bytes * reread + w_bytes + out_bytes, in_bytes)
        time = base + max(t_cmp, t_mem)

    elif fam == "im2":
        lower_bytes = 4.0 * c * f * f * P
        scan = t.get("trav") == "scan"
        # copy materialises the patch matrix (write+read), scan gathers with
        # poorer locality but half the traffic.
        if scan:
            t_lower = _stream_time(plat, lower_bytes, in_bytes, eff=0.45)
        else:
            t_lower = _stream_time(plat, 2.0 * lower_bytes, lower_bytes, eff=0.85)
        t_g = _gemm_time(plat, k, P, c * f * f, vec, trans)
        # ki (chw) output from pixel-major GEMM pays a strided-write factor
        t_out = _stream_time(plat, out_bytes, out_bytes,
                             eff=0.8 if t.get("order") == "ki" else 1.0)
        time = base + t_lower + t_g + t_out

    elif fam == "kn2":
        # f*f GEMMs over the FULL image + shifted accumulation traffic.
        t_g = f * f * _gemm_time(plat, k, im * im, c, vec, trans)
        acc_bytes = 4.0 * k * P * f * f * 2.0
        t_acc = _stream_time(plat, acc_bytes, 4.0 * k * im * im, eff=0.7)
        variant = t.get("variant", "")
        if variant.startswith("as"):
            t_acc *= 0.8    # single fused reduction
        time = base + t_g + t_acc

    elif fam in ("wino3", "wino5"):
        m = t["tile_m"]; r = f
        n = m + r - 1
        if t.get("oned"):
            tiles = o * (-(-o // m))          # rows x row-tiles
            tr_flops = 2.0 * (c + k) * tiles * n * n + 2.0 * k * tiles * m * n
            gemms = r * n                      # r kernel-rows x n points
            t_g = gemms * _gemm_time(plat, k, tiles / max(1, n), c, vec)
        else:
            th = -(-o // m)
            tiles = th * th                    # tile quantisation waste here
            tr_flops = (2.0 * c * tiles * 2 * n * n * n     # input transform
                        + 2.0 * k * c * 2 * n * n * r       # kernel transform
                        + 2.0 * k * tiles * 2 * n * n * m)  # output transform
            t_g = n * n * _gemm_time(plat, k, tiles, c, vec)
        t_tr = tr_flops / (plat.peak_gflops * 1e9 * 0.35)
        t_mem = _stream_time(plat, in_bytes + out_bytes + 4.0 * c * tiles * n * n,
                             4.0 * c * tiles * n * n, eff=0.8)
        time = base + t_g + t_tr + t_mem

    elif fam == "c1x1":
        t_g = _gemm_time(plat, k, P, c, vec, trans)
        strided = 1.0 if s == 1 else 0.6
        t_mem = _stream_time(plat, in_bytes / (s * s) + out_bytes, in_bytes, eff=strided)
        time = base + t_g + t_mem

    elif fam == "mec":
        # partial lowering: ow strips of (h x f) columns; f partitioned GEMMs.
        lower_bytes = 4.0 * c * im * f * o
        t_lower = _stream_time(plat, 2.0 * lower_bytes, lower_bytes, eff=0.8)
        # f partitioned GEMMs, each (M=k, N=P, K=c*f): total flops unchanged,
        # but each GEMM sees a smaller K (worse efficiency) and a small
        # per-partition call overhead — MEC trades time for memory.
        t_g = f * _gemm_time(plat, k, P, c * f, vec)
        t_part = f * plat.overhead_us * 0.3e-6
        time = base + t_lower + t_g + t_part

    else:  # pragma: no cover
        raise ValueError(fam)

    if noisy:
        time *= _noise(plat, f"{plat.name}|{prim.name}|{k},{c},{im},{s},{f}")
    return time


def dlt_time(plat: Platform, src: str, dst: str, c: int, im: int,
             noisy: bool = True) -> float:
    """Simulated data-layout-transformation time (seconds)."""
    if src == dst:
        return 0.0
    bytes_moved = 2.0 * 4.0 * c * im * im
    # chw<->hwc moves the innermost axis (worst); others swap adjacent axes.
    kind = "full" if {src, dst} == {"chw", "hwc"} else "adjacent"
    eff = plat.transpose_eff[kind]
    tm = plat.overhead_us * 0.5e-6 + _stream_time(plat, bytes_moved, bytes_moved / 2, eff=eff)
    if noisy:
        tm *= _noise(plat, f"{plat.name}|dlt|{src}->{dst}|{c},{im}")
    return tm
