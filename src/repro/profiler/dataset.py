"""Profiler dataset construction (paper §3.2).

Primitive dataset rows:  (k, c, im, s, f) -> (R_1 ... R_N)   N = |registry|
DLT dataset rows:        (c, im)          -> (R_1 ... R_9)

Undefined entries (inapplicable primitive) are NaN. Datasets are built either
from a platform simulator (full scale) or from the real-CPU profiler
(reduced scale); both return the same ``PerfDataset`` structure, and both are
split 80/10/10 after shuffling (paper §4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.primitives import conv
from repro.primitives.conv import PRIMITIVE_NAMES, REGISTRY
from repro.primitives import layouts as L
from repro.profiler import pools
from repro.profiler.simulators import (PLATFORMS, Platform, dlt_time_batch,
                                       primitive_time_batch)


@dataclasses.dataclass
class PerfDataset:
    feats: np.ndarray        # (N, F) raw feature rows
    times: np.ndarray        # (N, P) runtimes, NaN = undefined
    columns: List[str]
    feature_names: List[str]
    platform: str

    def split(self, seed: int = 0, fractions=(0.8, 0.1, 0.1)) -> Tuple["PerfDataset", "PerfDataset", "PerfDataset"]:
        n = self.feats.shape[0]
        rng = np.random.default_rng(seed)
        idx = rng.permutation(n)
        n_train = int(fractions[0] * n)
        n_val = int(fractions[1] * n)
        parts = (idx[:n_train], idx[n_train:n_train + n_val], idx[n_train + n_val:])
        return tuple(
            PerfDataset(self.feats[p], self.times[p], self.columns,
                        self.feature_names, self.platform)
            for p in parts)

    def subsample(self, fraction: float, seed: int = 0) -> "PerfDataset":
        """Random subset — the paper's transfer-learning data fractions."""
        n = self.feats.shape[0]
        m = max(1, int(round(fraction * n)))
        rng = np.random.default_rng(seed)
        idx = rng.choice(n, size=m, replace=False)
        return PerfDataset(self.feats[idx], self.times[idx], self.columns,
                           self.feature_names, self.platform)

    def family_subset(self, family: str) -> "PerfDataset":
        """Keep only columns of one primitive family (Table 5 experiments).
        Rows with no defined entry for the family are dropped."""
        cols = [i for i, n in enumerate(self.columns)
                if conv.family_of(n) == family]
        times = self.times[:, cols]
        keep = np.isfinite(times).any(axis=1)
        return PerfDataset(self.feats[keep], times[keep],
                           [self.columns[i] for i in cols],
                           self.feature_names, self.platform)

    @property
    def n(self) -> int:
        return self.feats.shape[0]

    def fingerprint(self) -> str:
        """Content hash over features, runtimes and column names — the
        dataset identity used for artifact keying (repro.service.artifacts).
        Simulator datasets hash identically across runs (deterministic
        noise); host-profiled datasets hash per measurement."""
        import hashlib
        h = hashlib.sha256()
        h.update(("|".join(self.columns) + "@" + self.platform).encode())
        h.update(np.ascontiguousarray(self.feats, np.float64).tobytes())
        h.update(np.ascontiguousarray(self.times, np.float64).tobytes())
        return h.hexdigest()[:16]

    # -- persistence (ArtifactStore dataset warm-start) ---------------------
    def save(self, path: str) -> None:
        """Single-file .npz round-trip (HostPlatform persists its profiled
        datasets so real-CPU runs warm-start instead of re-measuring)."""
        np.savez(path,
                 feats=np.asarray(self.feats, np.float64),
                 times=np.asarray(self.times, np.float64),
                 columns=np.array(self.columns, dtype=np.str_),
                 feature_names=np.array(self.feature_names, dtype=np.str_),
                 platform=np.array(self.platform, dtype=np.str_))

    @classmethod
    def load(cls, path: str) -> "PerfDataset":
        with np.load(path) as z:
            return cls(feats=z["feats"], times=z["times"],
                       columns=[str(c) for c in z["columns"]],
                       feature_names=[str(f) for f in z["feature_names"]],
                       platform=str(z["platform"]))


def merge_served(datasets: Sequence[PerfDataset]) -> Optional[PerfDataset]:
    """Union several served-traffic datasets (local + fleet-pooled) into one
    sample for ``compose_sample`` (DESIGN.md §14.3).

    Columns are unioned and sorted; each source's rows embed into the union
    with NaN for columns it never measured, exactly like a partially
    applicable profiled row. Row order is source order then within-source
    order, so merging is deterministic for deterministic inputs and the
    merged fingerprint is stable across hosts that pooled the same
    evidence. ``served_info`` summarises the pool (sources, per-source row
    counts, summed dispatches)."""
    datasets = [d for d in datasets if d is not None and d.n]
    if not datasets:
        return None
    if len({d.platform for d in datasets}) != 1:
        raise ValueError("merge_served: mixed platforms "
                         f"{sorted({d.platform for d in datasets})}")
    feature_names = list(datasets[0].feature_names)
    columns = sorted(set().union(*(d.columns for d in datasets)))
    col_idx = {c: j for j, c in enumerate(columns)}
    feats, times = [], []
    for d in datasets:
        if list(d.feature_names) != feature_names:
            raise ValueError("merge_served: mismatched feature names")
        block = np.full((d.n, len(columns)), np.nan)
        for j, c in enumerate(d.columns):
            block[:, col_idx[c]] = d.times[:, j]
        feats.append(np.asarray(d.feats, np.float64))
        times.append(block)
    out = PerfDataset(np.concatenate(feats), np.concatenate(times),
                      columns, feature_names, datasets[0].platform)
    infos = [getattr(d, "served_info", None) or {} for d in datasets]
    out.served_info = {
        "sources": len(datasets),
        "rows": [int(d.n) for d in datasets],
        "dispatches": int(sum(i.get("dispatches", 0) for i in infos)),
    }
    return out


def observations_to_dataset(feats: np.ndarray,
                            assigned: Sequence[str],
                            bucket_times: Sequence[Tuple[int, np.ndarray]],
                            *,
                            columns: Sequence[str],
                            platform: str,
                            feature_names: Sequence[str] = ("k", "c", "im",
                                                            "s", "f"),
                            info: Optional[Dict] = None,
                            probes: Optional[Sequence[Tuple[np.ndarray, str,
                                                            float]]] = None
                            ) -> PerfDataset:
    """Fold served-dispatch attributions into a ``PerfDataset`` the
    calibration path can consume (DESIGN.md §8.5).

    ``feats`` is the served network's (L, 5) assigned layer configs,
    ``assigned`` the primitive column per layer, and ``bucket_times`` one
    ``(batch_bucket, (L,) attributed per-image seconds)`` entry per pow2
    batch bucket observed (``DriftMonitor.attributed``). Per bucket, layers
    sharing a config collapse into one dataset row — two layers with the
    same config and column attribute identically, and the same config under
    two different columns fills both entries of one row; every other column
    stays NaN (unmeasured), exactly like a partially-applicable profiled row.

    The output is deterministic for deterministic input: rows are ordered by
    (bucket, config), so the same buffer snapshot always fingerprints — and
    ``save``/``load`` round-trips — byte-identically.

    ``info`` (the attribution summary: dispatches, per-bucket counts and
    drift) is attached as ``served_info`` so downstream consumers —
    ``platforms.compose_sample`` and the recalibration report — can surface
    the batch-shape mix the served sample was drawn from. It is metadata
    only: ``save``/``load`` does not persist it.

    ``probes`` are single-layer probe-dispatch measurements (DESIGN.md
    §14.4): ``(config_row, column, seconds)`` triples appended as their own
    rows after the bucket rows, sorted by (config, column) — each probe
    measured one column directly, so its row carries exactly one finite
    entry. Probe columns must already be in ``columns``.
    """
    feats = np.asarray(feats, np.float64)
    assigned = list(assigned)
    columns = list(columns)
    if feats.ndim != 2 or len(assigned) != feats.shape[0]:
        raise ValueError(f"feats {feats.shape} vs {len(assigned)} assigned "
                         f"columns")
    missing = sorted(set(assigned) - set(columns))
    if missing:
        raise ValueError(f"assigned columns {missing} not in dataset "
                         f"columns")
    col_idx = {c: j for j, c in enumerate(columns)}
    out_feats: List[np.ndarray] = []
    out_times: List[np.ndarray] = []
    for bucket, times in sorted(bucket_times, key=lambda bt: bt[0]):
        times = np.asarray(times, np.float64)
        if times.shape != (feats.shape[0],):
            raise ValueError(f"bucket {bucket}: times {times.shape} vs "
                             f"{feats.shape[0]} layers")
        rows: Dict[Tuple[float, ...], np.ndarray] = {}
        for i in range(feats.shape[0]):
            key = tuple(feats[i])
            row = rows.get(key)
            if row is None:
                row = rows[key] = np.full(len(columns), np.nan)
            row[col_idx[assigned[i]]] = times[i]
        for key in sorted(rows):
            out_feats.append(np.asarray(key, np.float64))
            out_times.append(rows[key])
    probe_rows = []
    for cfg, col, seconds in (probes or ()):
        cfg = np.asarray(cfg, np.float64).reshape(-1)
        if cfg.shape != (feats.shape[1] if feats.size else len(cfg),):
            raise ValueError(f"probe config shape {cfg.shape}")
        if col not in col_idx:
            raise ValueError(f"probe column {col!r} not in dataset columns")
        probe_rows.append((tuple(cfg), col, float(seconds)))
    for cfg, col, seconds in sorted(probe_rows, key=lambda p: (p[0], p[1])):
        row = np.full(len(columns), np.nan)
        row[col_idx[col]] = seconds
        out_feats.append(np.asarray(cfg, np.float64))
        out_times.append(row)
    if not out_feats:
        raise ValueError("no observations to convert")
    ds = PerfDataset(np.stack(out_feats), np.stack(out_times),
                     columns, list(feature_names), platform)
    if info is not None or probe_rows:
        ds.served_info = dict(info or {})
        if probe_rows:
            ds.served_info["probes"] = len(probe_rows)
    return ds


def simulate_primitive_dataset(platform: str,
                               max_triplets: Optional[int] = None,
                               noisy: bool = True) -> PerfDataset:
    plat = PLATFORMS[platform]
    cfgs = pools.config_pool(max_triplets=max_triplets)
    feats = np.array(cfgs, np.float64)
    # one vectorised pass over all configs × all registry columns
    times = primitive_time_batch(plat, np.array(cfgs, np.int64), noisy=noisy)
    return PerfDataset(feats, times, list(PRIMITIVE_NAMES),
                       ["k", "c", "im", "s", "f"], platform)


def simulate_dlt_dataset(platform: str,
                         max_pairs: Optional[int] = None,
                         noisy: bool = True) -> PerfDataset:
    plat = PLATFORMS[platform]
    pairs = pools.dlt_pool(max_pairs=max_pairs)
    names = [L.dlt_name(s, d) for (s, d) in L.dlt_pairs() if s != d]
    feats = np.array(pairs, np.float64)
    times = dlt_time_batch(plat, np.array(pairs, np.int64), noisy=noisy)
    return PerfDataset(feats, times, names, ["c", "im"], platform)
