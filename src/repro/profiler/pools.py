"""Layer-configuration pools (paper §3.2.1, Tables 1/2/7).

The paper collects 475 unique (c, k, im) triplets from a pool of common
architectures, crosses them with the (f, s) grid from Table 1 and filters
impossible combinations (f > im). We build the triplet pool from our CNN zoo
plus the paper's explicit parameter ranges.
"""
from __future__ import annotations

import itertools
from typing import List, Sequence, Tuple

from repro.models import cnn_zoo

# Table 1 common ranges
F_VALUES = (1, 3, 5, 7, 9, 11)
S_VALUES = (1, 2, 4)


def triplet_pool() -> List[Tuple[int, int, int]]:
    """(c, k, im) triplets as they occur in the zoo (Table 7 analogue)."""
    return cnn_zoo.pool_triplets()


def config_pool(max_triplets: int | None = None,
                f_values: Sequence[int] = F_VALUES,
                s_values: Sequence[int] = S_VALUES) -> List[Tuple[int, int, int, int, int]]:
    """(k, c, im, s, f) layer configurations: triplets x (f, s) grid with
    impossible values filtered (paper §3.2.1)."""
    trips = triplet_pool()
    if max_triplets is not None:
        trips = trips[:: max(1, len(trips) // max_triplets)][:max_triplets]
    out = []
    for (c, k, im) in trips:
        for f, s in itertools.product(f_values, s_values):
            if f > im:
                continue
            out.append((k, c, im, s, f))
    return out


def dlt_pool(max_pairs: int | None = None) -> List[Tuple[int, int]]:
    """(c, im) pairs for the DLT dataset — both layer inputs and outputs
    occur as transformed tensors."""
    pairs = set()
    for (c, k, im) in triplet_pool():
        pairs.add((c, im))
        pairs.add((k, im))
    pairs = sorted(pairs)
    if max_pairs is not None:
        pairs = pairs[:: max(1, len(pairs) // max_pairs)][:max_pairs]
    return pairs
