"""Real-hardware profiler: measures actual primitive execution times on this
container's CPU (paper §4.1 methodology: jit-compiled, warmed up, median of
repeats, normally-distributed input data).

Used for the reduced-scale real-hardware validation (DESIGN.md §2.1): the
full-size datasets come from the platform simulators, but this module proves
the pipeline — profile, train, select, execute — works end-to-end on a
physical machine.
"""
from __future__ import annotations

import time
from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.primitives.conv import PRIMITIVE_NAMES, REGISTRY, RUNNABLE
from repro.primitives import layouts as L
from repro.profiler.dataset import PerfDataset


@lru_cache(maxsize=4096)
def _jitted_primitive(name: str, c: int, im: int, k: int, f: int, s: int):
    p = REGISTRY[name]
    impl = p.impl

    @jax.jit
    def run(x, w):
        return impl(x, w, s)
    return run


def time_callable(fn, *args, repeats: int = 25, warmup: int = 2) -> float:
    """Median wall time of ``fn(*args)`` with block_until_ready (paper
    profiles each primitive 25 times and takes the median)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def profile_primitive(name: str, k: int, c: int, im: int, s: int, f: int,
                      repeats: int = 25, rng: Optional[np.random.Generator] = None) -> float:
    """Measured runtime (seconds); NaN if inapplicable or simulated-only."""
    p = REGISTRY[name]
    if p.impl is None or not p.applicable(k, c, im, s, f):
        return float("nan")
    rng = rng or np.random.default_rng(0)
    x_chw = jnp.asarray(rng.standard_normal((c, im, im)), jnp.float32)
    x = L.from_chw(x_chw, p.in_layout)
    w = jnp.asarray(rng.standard_normal((k, c, f, f)), jnp.float32)
    fn = _jitted_primitive(name, c, im, k, f, s)
    return time_callable(fn, x, w, repeats=repeats)


@lru_cache(maxsize=64)
def _jitted_dlt(src: str, dst: str):
    @jax.jit
    def run(x):
        return L.transform(x, src, dst)
    return run


def profile_dlt(src: str, dst: str, c: int, im: int, repeats: int = 25) -> float:
    if src == dst:
        return 0.0
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((c, im, im)), jnp.float32)
    x = L.from_chw(x, src)
    return time_callable(_jitted_dlt(src, dst), x, repeats=repeats)


def profile_primitive_batch(configs: Sequence[Tuple[int, int, int, int, int]],
                            columns: Optional[Sequence[str]] = None,
                            repeats: int = 25) -> np.ndarray:
    """(L, P) measured runtimes over ``configs`` × ``columns`` — the batch
    counterpart of ``profile_primitive`` (same matrix contract as the
    simulator's ``primitive_time_batch``). Measurement is inherently serial,
    but jitted callables and the input RNG are shared across the batch."""
    cols = list(columns) if columns is not None else list(RUNNABLE)
    out = np.full((len(configs), len(cols)), np.nan)
    rng = np.random.default_rng(0)
    for i, (k, c, im, s, f) in enumerate(np.asarray(configs, int)):
        for j, name in enumerate(cols):
            out[i, j] = profile_primitive(name, int(k), int(c), int(im), int(s),
                                          int(f), repeats=repeats, rng=rng)
    return out


def profile_dlt_batch(pairs: Sequence[Tuple[int, int]],
                      repeats: int = 25) -> np.ndarray:
    """(M, 6) measured DLT runtimes in ``layouts.dlt_pairs()`` order with
    identity pairs excluded — batch counterpart of ``profile_dlt``."""
    ni = [(s, d) for (s, d) in L.dlt_pairs() if s != d]
    out = np.zeros((len(pairs), len(ni)))
    for i, (c, im) in enumerate(np.asarray(pairs, int)):
        for j, (s, d) in enumerate(ni):
            out[i, j] = profile_dlt(s, d, int(c), int(im), repeats=repeats)
    return out


def profile_primitive_dataset(configs: Sequence[Tuple[int, int, int, int, int]],
                              primitives: Optional[Sequence[str]] = None,
                              repeats: int = 9) -> PerfDataset:
    """Profile ``configs`` x ``primitives`` on this host. Runnable primitives
    only. This is the expensive stage the paper replaces — we keep it small."""
    prims = list(primitives) if primitives is not None else list(RUNNABLE)
    feats = np.array(configs, np.float64)
    times = profile_primitive_batch(configs, prims, repeats=repeats)
    return PerfDataset(feats, times, prims, ["k", "c", "im", "s", "f"], "host-cpu")


def profile_dlt_dataset(pairs: Sequence[Tuple[int, int]], repeats: int = 9) -> PerfDataset:
    names = [L.dlt_name(s, d) for (s, d) in L.dlt_pairs() if s != d]
    feats = np.array(pairs, np.float64)
    times = profile_dlt_batch(pairs, repeats=repeats)
    return PerfDataset(feats, times, names, ["c", "im"], "host-cpu")
