"""Assigned input-shape cells and per-(arch x shape) input_specs.

Four shape cells (assignment brief):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (serve)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 token, KV=seq)
  long_500k    seq 524,288 global_batch 1     -> serve_step; SSM/hybrid/SWA only

``input_specs`` returns weak-type-correct ShapeDtypeStructs for every model
input — no device allocation, the dry-run pattern. Modality frontends are
stubs: internvl2 gets 256 precomputed patch embeddings, whisper gets frame
embeddings of the full sequence length (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic-decode archs (DESIGN.md §4)."""
    if shape == "long_500k":
        return cfg.supports_long_decode
    return True


def input_specs(cfg: ArchConfig, shape: str, dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train/prefill: {'tokens', 'labels'?, 'prefix_embeds'?, 'enc_embeds'?}
    decode:        {'tokens' (B,1), 'pos' (), 'cache': {...}}
    """
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len
    if not cell_applicable(cfg, shape):
        raise ValueError(f"{cfg.name} does not run {shape} (full attention)")

    if cell.step in ("train", "prefill"):
        s_text = S - (cfg.prefix_tokens if cfg.prefix_tokens else 0)
        specs: Dict = {"tokens": SDS((B, s_text), jnp.int32)}
        if cell.step == "train":
            specs["labels"] = SDS((B, s_text), jnp.int32)
        if cfg.prefix_tokens:
            specs["prefix_embeds"] = SDS((B, cfg.prefix_tokens, cfg.d_model), dtype)
        if cfg.kind == "encdec":
            specs["enc_embeds"] = SDS((B, S, cfg.d_model), dtype)
        return specs

    # decode: one new token against a cache of S. eval_shape — the cache is
    # never allocated (decode_32k caches run to terabytes globally).
    from repro.models import transformer as T
    cache_specs = jax.eval_shape(
        lambda: T.init_cache(cfg, B, S, enc_len=S if cfg.kind == "encdec" else 0,
                             dtype=dtype))
    return {
        "tokens": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
        "cache": cache_specs,
    }
