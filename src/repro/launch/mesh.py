"""Production mesh construction (MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a function — importing this module never touches
jax device state. Single-pod: (data=16, model=16) = 256 chips. Multi-pod:
(pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries pure data
parallelism whose gradient all-reduce crosses the inter-pod links (DCN on
real deployments — the dry-run proves the axis shards; at 1000+ nodes the
same code runs with pod > 2).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"importing jax (repro.launch.dryrun does this)")
    return jax.make_mesh(shape, axes,
                         devices=devs[:n],
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 4):
    """Small host-device mesh for distribution tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=data*model)."""
    n = data * model
    if len(jax.devices()) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
