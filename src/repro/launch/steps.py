"""Step-function factories: train_step / prefill_step / serve_step per arch,
plus the sharding trees the launcher and dry-run bind them with.

train_step is the full update: loss -> grads -> optimizer. llama3-405b uses
Adafactor (factored second moments) so optimizer state fits v5e HBM
(DESIGN.md §4); everything else uses AdamW.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist import sharding as SH
from repro.models import transformer as T
from repro.train import optim as optim_lib

OPTIMIZER_FOR_ARCH = {"llama3_405b": "adafactor"}
DEFAULT_LR = 3e-4


def optimizer_for(cfg: ArchConfig) -> Tuple[str, optim_lib.Optimizer]:
    name = OPTIMIZER_FOR_ARCH.get(cfg.name, "adamw")
    if name == "adafactor":
        return name, optim_lib.adafactor(DEFAULT_LR)
    return name, optim_lib.adamw(DEFAULT_LR, weight_decay=0.1)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt: optim_lib.Optimizer,
                    grad_accum: int = 1,
                    aspec: Optional[T.ActShard] = None,
                    grad_dtype=None) -> Callable:
    """Full training step. With ``grad_accum > 1`` the global batch is split
    into microbatches scanned sequentially (memory/throughput knob).

    ``grad_dtype=jnp.bfloat16`` enables gradient compression: gradients are
    cast before the cross-replica reduction, halving the DP/pod-axis
    all-reduce bytes (the DCN-crossing collective on multi-pod meshes) at
    the cost of ~8 bits of gradient mantissa — the standard large-fleet
    trade (optimizer statistics stay f32)."""

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: T.loss_fn(p, cfg, batch, aspec=aspec), has_aux=True)(params)
        else:
            def micro(i, carry):
                acc_loss, acc_grads = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // grad_accum), x.shape[0] // grad_accum, 0)
                    if hasattr(x, "ndim") and x.ndim else x, batch)
                (l, _), g = jax.value_and_grad(
                    lambda p: T.loss_fn(p, cfg, mb, aspec=aspec), has_aux=True)(params)
                return (acc_loss + l, jax.tree.map(jnp.add, acc_grads, g))
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            loss, grads = jax.lax.fori_loop(
                0, grad_accum, micro, (jnp.zeros((), jnp.float32), zero))
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        if grad_dtype is not None:
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ArchConfig, aspec: Optional[T.ActShard] = None) -> Callable:
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch["tokens"],
                         prefix_embeds=batch.get("prefix_embeds"),
                         enc_embeds=batch.get("enc_embeds"), aspec=aspec)
    return prefill_step


def make_serve_step(cfg: ArchConfig, aspec: Optional[T.ActShard] = None) -> Callable:
    def serve_step(params, cache, tokens, pos):
        return T.decode_step(params, cfg, cache, tokens, pos, aspec=aspec)
    return serve_step


def make_aspec(mesh: Mesh, global_batch: int, seq_parallel: bool = False
               ) -> Optional[T.ActShard]:
    """Activation-sharding constraints for this mesh/batch. Batch axes are
    dropped when the batch does not divide them (long_500k B=1)."""
    dp = SH.dp_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    if global_batch % n != 0:
        dp = ()
    return T.ActShard(dp=dp, tp="model", seq=seq_parallel,
                      tp_size=mesh.shape.get("model", 0))


# ---------------------------------------------------------------------------
# Optimizer-state shardings (mirror the parameter shardings)
# ---------------------------------------------------------------------------

def make_opt_shardings(mesh: Mesh, params_like: Any, opt_name: str,
                       fsdp: bool = True) -> Any:
    repl = NamedSharding(mesh, P())

    def pspec(path, leaf):
        return SH.param_spec(SH._path_str(path), tuple(leaf.shape), mesh, fsdp=fsdp)

    if opt_name in ("adam", "adamw"):
        mirror = jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(mesh, pspec(p, l)), params_like)
        return {"step": repl, "m": mirror, "v": mirror}

    if opt_name == "adafactor":
        def factored(path, leaf):
            spec = pspec(path, leaf)
            t = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
            if len(leaf.shape) >= 2 and min(leaf.shape[-1], leaf.shape[-2]) >= 128:
                return {"vr": NamedSharding(mesh, P(*t[:-1])),
                        "vc": NamedSharding(mesh, P(*(t[:-2] + (t[-1],)))),
                        "v": None}
            return {"vr": None, "vc": None, "v": NamedSharding(mesh, P(*t))}
        return {"step": repl,
                "v": jax.tree_util.tree_map_with_path(factored, params_like)}

    raise ValueError(opt_name)


# ---------------------------------------------------------------------------
# Full dry-run binding for one (arch x shape x mesh) cell
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BoundStep:
    fn: Callable
    args: tuple                  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    static_info: Dict[str, Any]


def bind_cell(cfg: ArchConfig, shape_name: str, mesh: Mesh,
              fsdp_train: bool = True, grad_accum: int = 1,
              serve_fsdp: Optional[bool] = None,
              seq_parallel: bool = False) -> BoundStep:
    """Build (fn, SDS args, shardings) for a dry-run cell."""
    from repro.launch import shapes as SHP
    cell = SHP.SHAPES[shape_name]
    specs = SHP.input_specs(cfg, shape_name)
    repl = NamedSharding(mesh, P())
    aspec = make_aspec(mesh, cell.global_batch, seq_parallel)

    def params_sds():
        return jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))

    if cell.step == "train":
        opt_name, opt = optimizer_for(cfg)
        p_sds = params_sds()
        o_sds = jax.eval_shape(opt.init, p_sds)
        p_sh = SH.make_param_shardings(mesh, p_sds, fsdp=fsdp_train)
        o_sh = make_opt_shardings(mesh, p_sds, opt_name, fsdp=fsdp_train)
        b_sh = SH.make_batch_shardings(mesh, specs)
        fn = make_train_step(cfg, opt, grad_accum=grad_accum, aspec=aspec)
        return BoundStep(fn, (p_sds, o_sds, specs), (p_sh, o_sh, b_sh),
                         (p_sh, o_sh, repl),
                         {"step": "train", "optimizer": opt_name})

    if cell.step == "prefill":
        p_sds = params_sds()
        # serving keeps parameters 2D-sharded only when TP-only does not fit
        big = cfg.n_params() * 2 > 8e9 * mesh.shape["model"]
        use_fsdp = serve_fsdp if serve_fsdp is not None else big
        p_sh = SH.make_param_shardings(mesh, p_sds, fsdp=use_fsdp)
        b_sh = SH.make_batch_shardings(mesh, specs)
        fn = make_prefill_step(cfg, aspec=aspec)
        with mesh:   # _cst sharding constraints need the mesh in context
            cache_sds = jax.eval_shape(fn, p_sds, specs)[1]
        c_sh = SH.make_cache_shardings(mesh, cache_sds)
        return BoundStep(fn, (p_sds, specs), (p_sh, b_sh), (repl, c_sh),
                         {"step": "prefill", "params_fsdp": use_fsdp})

    # decode
    p_sds = params_sds()
    big = cfg.n_params() * 2 > 8e9 * mesh.shape["model"]
    use_fsdp = serve_fsdp if serve_fsdp is not None else big
    p_sh = SH.make_param_shardings(mesh, p_sds, fsdp=use_fsdp)
    c_sh = SH.make_cache_shardings(mesh, specs["cache"])
    tok_sh = SH.make_batch_shardings(mesh, specs["tokens"])
    fn = make_serve_step(cfg, aspec=aspec)
    args = (p_sds, specs["cache"], specs["tokens"], specs["pos"])
    in_sh = (p_sh, c_sh, tok_sh, repl)
    out_sh = (repl, c_sh)
    return BoundStep(fn, args, in_sh, out_sh,
                     {"step": "decode", "params_fsdp": use_fsdp})
