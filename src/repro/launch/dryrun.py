import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DEVICES", "512")).strip()
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run driver.

For one (architecture x input-shape x mesh) cell:
  lower -> compile -> memory_analysis -> cost_analysis -> HLO roofline terms
and write a JSON artifact under artifacts/dryrun/. Run all cells with
``python -m repro.launch.dryrun --all`` (each cell in a subprocess so the
forced device count matches its mesh: 256 single-pod, 512 multi-pod).

This is the proof-of-coherence for the production mesh: sharding mismatch,
compile-time OOM or an unsupported collective fails the cell loudly.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             fsdp: bool = True, grad_accum: int = 1,
             seq_parallel: bool = True, save_hlo: bool = False) -> dict:
    import jax
    from repro.configs import base as CB
    from repro.dist import hloanalysis as HA
    from repro.launch import shapes as SHP
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps as ST

    cfg = CB.get(arch)
    if not SHP.cell_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": "full attention: no long-decode"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    bound = ST.bind_cell(cfg, shape, mesh, fsdp_train=fsdp, grad_accum=grad_accum,
                         seq_parallel=seq_parallel)

    donate = (0, 1) if bound.static_info.get("step") == "train" else \
             ((1,) if bound.static_info.get("step") == "decode" else ())
    with mesh:
        lowered = jax.jit(bound.fn, in_shardings=bound.in_shardings,
                          out_shardings=bound.out_shardings,
                          donate_argnums=donate).lower(*bound.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    costs = HA.analyze(hlo_text)

    cell = SHP.SHAPES[shape]
    if cell.step == "train":
        tokens = cell.seq_len * cell.global_batch
        n = cfg.n_active_params() if cfg.moe is not None else cfg.n_params()
        model_flops = 6.0 * n * tokens
    elif cell.step == "prefill":
        tokens = cell.seq_len * cell.global_batch
        n = cfg.n_active_params() if cfg.moe is not None else cfg.n_params()
        model_flops = 2.0 * n * tokens
    else:  # decode: one token per sequence
        n = cfg.n_active_params() if cfg.moe is not None else cfg.n_params()
        model_flops = 2.0 * n * cell.global_batch

    roof = HA.roofline_from_costs(costs, n_chips, model_flops)
    result = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "ok", "n_chips": n_chips,
        "step_kind": cell.step, **bound.static_info,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        },
        "cost_analysis": {k: ca.get(k) for k in
                          ("flops", "bytes accessed", "transcendentals")
                          if k in ca},
        "hlo": {
            "flops_per_device": costs.flops,
            "bytes_per_device": costs.bytes,
            "collective_bytes": dict(costs.collective_bytes),
            "collective_count": dict(costs.collective_count),
        },
        "roofline": roof.to_dict(),
        "n_params": cfg.n_params(),
    }
    if save_hlo:
        hpath = os.path.join(out_dir, f"{arch}.{shape}.{'multi' if multi_pod else 'single'}.hlo.txt")
        with open(hpath, "w") as f:
            f.write(hlo_text)
        result["hlo_path"] = hpath
    return result


def _artifact_path(out_dir: str, arch: str, shape: str, multi_pod: bool,
                   tag: str = "") -> str:
    suffix = "multi" if multi_pod else "single"
    tag = f".{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}.{shape}.{suffix}{tag}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell in subprocesses")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="", help="artifact suffix for perf experiments")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true",
                    help="disable Megatron-style sequence-parallel residual stream (baseline)")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true", help="rerun cached cells")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro.configs.base import ASSIGNED_ARCHS
        shapes = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
        failures = []
        for mesh_kind in ("single", "multi"):
            for arch in ASSIGNED_ARCHS:
                for shape in shapes:
                    path = _artifact_path(args.out, arch, shape, mesh_kind == "multi", args.tag)
                    if os.path.exists(path) and not args.force:
                        print(f"cached  {path}")
                        continue
                    devices = "512" if mesh_kind == "multi" else "256"
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                           "--out", args.out]
                    if args.no_fsdp:
                        cmd.append("--no-fsdp")
                    if args.no_seq_parallel:
                        cmd.append("--no-seq-parallel")
                    if args.tag:
                        cmd += ["--tag", args.tag]
                    if args.grad_accum != 1:
                        cmd += ["--grad-accum", str(args.grad_accum)]
                    env = dict(os.environ, REPRO_DEVICES=devices,
                               PYTHONPATH=os.environ.get("PYTHONPATH", "src"))
                    t0 = time.time()
                    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                                       timeout=args.timeout)
                    status = "OK" if r.returncode == 0 else "FAIL"
                    print(f"{status:5s} {arch:20s} {shape:12s} {mesh_kind:6s} "
                          f"{time.time()-t0:6.1f}s")
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh_kind, r.stderr[-2000:]))
        for f in failures:
            print("FAILURE:", f[0], f[1], f[2], "\n", f[3][:1000])
        return 1 if failures else 0

    # single cell (this process owns the forced device count)
    result = {"arch": args.arch, "shape": args.shape,
              "multi_pod": args.mesh == "multi", "status": "error"}
    try:
        result = run_cell(args.arch, args.shape, args.mesh == "multi", args.out,
                          fsdp=not args.no_fsdp, grad_accum=args.grad_accum,
                          seq_parallel=not args.no_seq_parallel,
                          save_hlo=args.save_hlo)
    except Exception:
        result["traceback"] = traceback.format_exc()
        print(result["traceback"], file=sys.stderr)
    path = _artifact_path(args.out, args.arch, args.shape, args.mesh == "multi", args.tag)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    mem = result.get("memory", {})
    roof = result.get("roofline", {})
    print(json.dumps({k: result.get(k) for k in
                      ("arch", "shape", "multi_pod", "status", "compile_s")}))
    if result["status"] == "ok":
        print(f"per-device bytes: args={mem['argument_bytes']/1e9:.2f}G "
              f"temp={mem['temp_bytes']/1e9:.2f}G | "
              f"terms: compute={roof['compute_s']*1e3:.2f}ms "
              f"memory={roof['memory_s']*1e3:.2f}ms "
              f"collective={roof['collective_s']*1e3:.2f}ms "
              f"dominant={roof['dominant']}")
    return 0 if result["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
