"""LM decode demo: prefill + batched decode over any assigned arch.

``python -m repro.launch.lm_decode --arch mixtral_8x7b --tokens 32``

(Formerly ``repro.launch.serve``; renamed so the CNN serving front end —
``python -m repro.service.server`` — owns the "serve" name.)

Demonstrates the serve path the decode_32k/long_500k dry-run cells lower:
prefill builds the cache, then single-token steps extend it (ring-buffered
for windowed archs). Reduced config on CPU (--smoke default).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3_6b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import base as cb
    from repro.models import transformer as T

    cfg = cb.get(args.arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, P, N = args.batch, args.prompt_len, args.tokens
    total = P + N
    prompt = (jnp.arange(B * P).reshape(B, P) * 11 + 1) % cfg.vocab

    kw = {}
    if cfg.prefix_tokens:
        kw["prefix_embeds"] = jnp.zeros((B, 8, cfg.d_model), jnp.float32)
    if cfg.kind == "encdec":
        kw["enc_embeds"] = jnp.zeros((B, P, cfg.d_model), jnp.float32)

    t0 = time.perf_counter()
    logits, cache = jax.jit(lambda p: T.prefill(p, cfg, prompt, **kw))(params)
    print(f"[serve] prefill {P} tokens: {(time.perf_counter()-t0)*1e3:.0f} ms")

    # grow KV caches to the full decode horizon
    def grow(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v", "ckv", "kr") and a.ndim >= 3 and a.shape[2] == P:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, total - P)
            return jnp.pad(a, pad)
        return a
    cache = jax.tree_util.tree_map_with_path(grow, cache)

    step = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(N - 1):
        logits, cache = step(params, cache, tok, jnp.asarray(P + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    seq = jnp.concatenate(out, 1)
    print(f"[serve] decoded {N-1} x {B} tokens in {dt*1e3:.0f} ms "
          f"({B*(N-1)/dt:.1f} tok/s)")
    print(f"[serve] sample: {np.asarray(seq[0])[:12].tolist()}")


if __name__ == "__main__":
    main()
