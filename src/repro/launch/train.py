"""Production training launcher.

``python -m repro.launch.train --arch llama3_405b --shape train_4k``

On a real TPU slice this binds the assigned arch x shape cell to the
production mesh and runs the fault-tolerant loop:
  * resume-from-latest checkpoint on start (node failure / preemption);
  * atomic step-tagged checkpoints every --ckpt-every steps;
  * stateless-shardable data (batch index -> bytes), so restarts and
    elastic re-shards never replay or skip data;
  * per-step wall/loss logging with a straggler watchdog (a step exceeding
    --straggler-factor x the trailing median is logged loudly — on real
    fleets this feeds the controller that evicts the slow host).

On this CPU container it runs the same loop on reduced configs
(--smoke, default) — the multi-pod path is exercised by dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args()

    import jax
    from repro.ckpt.manager import CheckpointManager
    from repro.configs import base as cb
    from repro.data.lm import make_batch
    from repro.launch import steps as ST
    from repro.models import transformer as T

    cfg = cb.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        batch, seq = args.batch, args.seq
    else:
        from repro.launch.shapes import SHAPES
        cell = SHAPES[args.shape]
        batch, seq = cell.global_batch, cell.seq_len

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_name, opt = ST.optimizer_for(cfg)
    opt_state = opt.init(params)
    mgr = CheckpointManager(f"{args.ckpt_dir}/{cfg.name}", keep=3)
    start, restored = mgr.restore_latest(
        jax.eval_shape(lambda: (params, opt_state)))
    if start is not None:
        params, opt_state = restored
        print(f"[train] resumed from step {start}")
    start = start or 0

    step_fn = jax.jit(ST.make_train_step(cfg, opt), donate_argnums=(0, 1))
    durations: list = []
    for step in range(start + 1, args.steps + 1):
        b = make_batch(cfg, batch, seq, step)
        t0 = time.perf_counter()
        params, opt_state, loss = step_fn(params, opt_state, b)
        jax.block_until_ready(loss)
        dt = time.perf_counter() - t0
        if len(durations) >= 5:
            med = float(np.median(durations[-20:]))
            if dt > args.straggler_factor * med:
                print(f"[train] STRAGGLER step {step}: {dt:.2f}s vs median {med:.2f}s")
        durations.append(dt)
        if step % 10 == 0 or step == start + 1:
            print(f"[train] step {step:5d} loss {float(loss):.4f} {dt*1e3:.0f}ms")
        if step % args.ckpt_every == 0:
            path = mgr.save(step, (params, opt_state), extra={"loss": float(loss)})
            print(f"[train] checkpoint -> {path}")
    mgr.save(args.steps, (params, opt_state))
    print("[train] done")


if __name__ == "__main__":
    main()
