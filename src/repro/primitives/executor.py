"""Executor: run a CNN with a given primitive assignment on this host
(paper Fig 2 step iv). Supports chains and DAGs with concat/add joins;
inserts the data-layout transformations the assignment implies and can time
each component — the real-hardware end of the pipeline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn_zoo import CNNSpec, ConvLayer, JoinNode
from repro.primitives.conv import REGISTRY
from repro.primitives import layouts as L

_C_AXIS = {"chw": 0, "hcw": 1, "hwc": 2}
_SPATIAL_AXES = {"chw": (1, 2), "hcw": (0, 2), "hwc": (0, 1)}

# Jitted primitive/DLT callables cached across ``execute`` calls, keyed by
# (primitive, input shape, stride) — repeated serving traffic over the same
# network reuses compiled code instead of re-tracing every call.
_JIT_CACHE: Dict[Tuple, Callable] = {}


def clear_jit_cache() -> None:
    _JIT_CACHE.clear()


def _cached_primitive(prim, x: jnp.ndarray, w: jnp.ndarray, stride: int) -> Callable:
    key = ("prim", prim.name, x.shape, str(x.dtype), w.shape, stride)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        impl = prim.impl
        fn = jax.jit(lambda a, b: impl(a, b, stride))
        _JIT_CACHE[key] = fn
    return fn


def _cached_dlt(src: str, dst: str, x: jnp.ndarray) -> Callable:
    key = ("dlt", src, dst, x.shape, str(x.dtype))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(lambda a: L.transform(a, src, dst))
        _JIT_CACHE[key] = fn
    return fn


def _crop_to_common(vals, layout: str):
    ah, aw = _SPATIAL_AXES[layout]
    h = min(v.shape[ah] for v in vals)
    w = min(v.shape[aw] for v in vals)
    out = []
    for v in vals:
        sl = [slice(None)] * 3
        oh, ow = (v.shape[ah] - h) // 2, (v.shape[aw] - w) // 2
        sl[ah] = slice(oh, oh + h)
        sl[aw] = slice(ow, ow + w)
        out.append(v[tuple(sl)])
    return out


@dataclasses.dataclass
class ExecutionReport:
    outputs: Dict[int, jnp.ndarray]
    primitive_seconds: Dict[int, float]
    dlt_seconds: Dict[Tuple[int, int], float]

    @property
    def total_seconds(self) -> float:
        return sum(self.primitive_seconds.values()) + sum(self.dlt_seconds.values())


def _consumers(spec: CNNSpec) -> Dict[int, List[int]]:
    out: Dict[int, List[int]] = {i: [] for i in range(len(spec.nodes))}
    for u, v in spec.edges:
        out[u].append(v)
    return out


def _producers(spec: CNNSpec) -> Dict[int, List[int]]:
    out: Dict[int, List[int]] = {i: [] for i in range(len(spec.nodes))}
    for u, v in spec.edges:
        out[v].append(u)
    return out


def _topo_order(spec: CNNSpec) -> List[int]:
    prods = _producers(spec)
    indeg = {i: len(p) for i, p in prods.items()}
    ready = [i for i, d in indeg.items() if d == 0]
    order = []
    cons = _consumers(spec)
    while ready:
        n = ready.pop()
        order.append(n)
        for v in cons[n]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if len(order) != len(spec.nodes):
        raise ValueError("cycle in CNN spec")
    return order


def make_weights(spec: CNNSpec, seed: int = 0) -> Dict[int, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    out = {}
    for i, node in enumerate(spec.nodes):
        if isinstance(node, ConvLayer):
            w = rng.standard_normal((node.k, node.c, node.f, node.f)) / (node.f * np.sqrt(node.c))
            out[i] = jnp.asarray(w, jnp.float32)
    return out


def execute(spec: CNNSpec, assignment: Dict[int, str],
            weights: Optional[Dict[int, jnp.ndarray]] = None,
            x: Optional[jnp.ndarray] = None,
            measure: bool = False, repeats: int = 5) -> ExecutionReport:
    """Run the network under ``assignment``. Inputs of source conv nodes are
    drawn from N(0,1) (paper §4.1.1) unless ``x`` is given (chw).

    With ``measure=True`` every primitive call and DLT is individually timed
    (jitted, warmed, median of ``repeats``); otherwise times are zeros and
    only outputs are produced (correctness path).
    """
    weights = weights if weights is not None else make_weights(spec)
    order = _topo_order(spec)
    prods = _producers(spec)
    tensors: Dict[int, jnp.ndarray] = {}      # node -> output in its layout
    layouts: Dict[int, str] = {}
    prim_secs: Dict[int, float] = {}
    dlt_secs: Dict[Tuple[int, int], float] = {}
    rng = np.random.default_rng(1)

    def timed(jfn, *args) -> Tuple[jnp.ndarray, float]:
        y = jax.block_until_ready(jfn(*args))
        if not measure:
            return y, 0.0
        samples = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(*args))
            samples.append(time.perf_counter() - t0)
        return y, float(np.median(samples))

    def fetch_input(node_idx: int, want_layout: str) -> jnp.ndarray:
        """Gather and layout-convert the producer tensors for ``node_idx``."""
        ps = prods[node_idx]
        vals = []
        for p in ps:
            v, src = tensors[p], layouts[p]
            if src != want_layout:
                v2, dt = timed(_cached_dlt(src, want_layout, v), v)
                dlt_secs[(p, node_idx)] = dlt_secs.get((p, node_idx), 0.0) + dt
                v = v2
            vals.append(v)
        return vals

    for i in order:
        node = spec.nodes[i]
        if isinstance(node, ConvLayer):
            prim = REGISTRY[assignment[i]]
            if prim.impl is None:
                raise ValueError(f"assignment uses simulated-only primitive {prim.name}")
            if prods[i]:
                (xin,) = fetch_input(i, prim.in_layout)
            else:
                x0 = (x if x is not None else
                      jnp.asarray(rng.standard_normal((node.c, node.im, node.im)), jnp.float32))
                xin = L.from_chw(x0, prim.in_layout)
            y, dt = timed(_cached_primitive(prim, xin, weights[i], node.s), xin, weights[i])
            tensors[i], layouts[i] = y, prim.out_layout
            prim_secs[i] = dt
        else:
            lay = assignment[i]
            vals = fetch_input(i, lay)
            # Branches run valid (un-padded) convolutions, so spatial sizes
            # can differ by a few pixels across branch depths; centre-crop to
            # the smallest (real deployments pad — padding does not change
            # the primitive-selection problem, see DESIGN.md §9).
            vals = _crop_to_common(vals, lay)
            if node.kind == "concat":
                y = jnp.concatenate(vals, axis=_C_AXIS[lay])
            elif node.kind == "add":
                y = vals[0]
                for v in vals[1:]:
                    y = y + v
            else:
                raise ValueError(node.kind)
            tensors[i], layouts[i] = y, lay

    return ExecutionReport(tensors, prim_secs, dlt_secs)
