"""Executor: run a CNN with a given primitive assignment on this host
(paper Fig 2 step iv). Supports chains and DAGs with concat/add joins;
inserts the data-layout transformations the assignment implies and can time
each component — the real-hardware end of the pipeline.

Two paths share this entry point:

* **compiled** (default for ``measure=False``): the whole assigned DAG is
  lowered by ``repro.primitives.plan.compile_plan`` into one jitted batched
  function — a single dispatch per call instead of ~2xN Python-level ones;
* **interpreted**: per-node jitted callables with explicit DLT dispatches —
  the per-component *measurement* path (``measure=True``), and the oracle
  the compiled plan is tested against.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn_zoo import CNNSpec, ConvLayer, EltwiseLayer, JoinNode
from repro.primitives.conv import REGISTRY, resolve, split_tile
from repro.primitives import layouts as L
from repro.primitives import plan as P
from repro.primitives.variants import conv_variant_call


# Jitted primitive/DLT callables cached across ``execute`` calls, keyed by
# (primitive, input shape, stride) — repeated serving traffic over the same
# network reuses compiled code instead of re-tracing every call. LRU-bounded
# so long-running multi-network serving cannot grow it without limit.
_JIT_CACHE: "OrderedDict[Tuple, Callable]" = OrderedDict()
_JIT_CACHE_CAP = 256


def clear_jit_cache() -> None:
    _JIT_CACHE.clear()


def evict_prim_entries(columns) -> int:
    """Drop cached primitive callables for the given (full, possibly
    tile-suffixed) column names — all shapes/strides. Called by the serving
    layer when a retired (net, generation) leaves columns no live
    registration uses (DESIGN.md §13.3). Returns the eviction count."""
    cols = set(columns)
    if not cols:
        return 0
    dead = [k for k in _JIT_CACHE if k[0] == "prim" and k[1] in cols]
    for k in dead:
        del _JIT_CACHE[k]
    return len(dead)


def _cached(key: Tuple, make: Callable[[], Callable]) -> Callable:
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = make()
        _JIT_CACHE[key] = fn
    else:
        _JIT_CACHE.move_to_end(key)
    while len(_JIT_CACHE) > _JIT_CACHE_CAP:
        _JIT_CACHE.popitem(last=False)
    return fn


def _cached_primitive(column: str, x: jnp.ndarray, w: jnp.ndarray,
                      stride: int) -> Callable:
    """Jitted callable for a (possibly tile-suffixed) column name. The FULL
    column name keys the cache — two tile variants of one base primitive are
    distinct compiled kernels, and must never share an entry."""
    base, variant = split_tile(column)
    prim = REGISTRY[base]
    key = ("prim", column, x.shape, str(x.dtype), w.shape, stride)
    if variant is None:
        impl = prim.impl
        return _cached(key, lambda: jax.jit(lambda a, b: impl(a, b, stride)))
    return _cached(key, lambda: jax.jit(
        lambda a, b: conv_variant_call(prim, variant, a, b, stride)))


def _cached_dlt(src: str, dst: str, x: jnp.ndarray) -> Callable:
    key = ("dlt", src, dst, x.shape, str(x.dtype))
    return _cached(key, lambda: jax.jit(lambda a: L.transform(a, src, dst)))


@dataclasses.dataclass
class ExecutionReport:
    outputs: Dict[int, jnp.ndarray]
    primitive_seconds: Dict[int, float]
    dlt_seconds: Dict[Tuple[int, int], float]

    @property
    def total_seconds(self) -> float:
        return sum(self.primitive_seconds.values()) + sum(self.dlt_seconds.values())


def make_weights(spec: CNNSpec, seed: int = 0) -> Dict[int, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    out = {}
    for i, node in enumerate(spec.nodes):
        if isinstance(node, ConvLayer):
            w = rng.standard_normal((node.k, node.c, node.f, node.f)) / (node.f * np.sqrt(node.c))
            out[i] = jnp.asarray(w, jnp.float32)
        elif isinstance(node, EltwiseLayer) and node.kind == "bias":
            out[i] = jnp.asarray(rng.standard_normal((node.c,)), jnp.float32)
    return out


def source_inputs(spec: CNNSpec, x: Optional[jnp.ndarray] = None) -> Dict[int, jnp.ndarray]:
    """chw input per source conv node: ``x`` if given, else N(0,1) draws
    (paper §4.1.1) — in topo order, so both executor paths see identical
    arrays for the same spec."""
    rng = np.random.default_rng(1)
    out: Dict[int, jnp.ndarray] = {}
    for i in P.source_nodes(spec):
        node = spec.nodes[i]
        if x is not None:
            out[i] = jnp.asarray(x, jnp.float32)
        else:
            out[i] = jnp.asarray(rng.standard_normal((node.c, node.im, node.im)),
                                 jnp.float32)
    return out


def execute(spec: CNNSpec, assignment: Dict[int, str],
            weights: Optional[Dict[int, jnp.ndarray]] = None,
            x: Optional[jnp.ndarray] = None,
            measure: bool = False, repeats: int = 5,
            compiled: Optional[bool] = None) -> ExecutionReport:
    """Run the network under ``assignment``. Inputs of source conv nodes are
    drawn from N(0,1) (paper §4.1.1) unless ``x`` is given (chw).

    With ``measure=True`` every primitive call and DLT is individually timed
    (jitted, warmed, median of ``repeats``) on the interpreted path;
    otherwise the call is a thin wrapper over the compiled whole-graph plan
    (``compiled=False`` forces the interpreted path without timing).
    """
    weights = weights if weights is not None else make_weights(spec)
    if compiled is None:
        compiled = not measure
    if measure or not compiled:
        return _execute_interpreted(spec, assignment, weights, x, measure, repeats)

    xs = source_inputs(spec, x)
    plan = P.compile_plan(spec, assignment,
                          tuple((1,) + v.shape for v in xs.values()),
                          outputs="all")
    outs = plan({i: v[None] for i, v in xs.items()}, weights)
    outputs = {i: o[0] for i, o in outs.items()}
    prim_secs = {i: 0.0 for i, n in enumerate(spec.nodes) if isinstance(n, ConvLayer)}
    return ExecutionReport(outputs, prim_secs, {})


def _execute_interpreted(spec: CNNSpec, assignment: Dict[int, str],
                         weights: Dict[int, jnp.ndarray],
                         x: Optional[jnp.ndarray],
                         measure: bool, repeats: int) -> ExecutionReport:
    order = P.topo_order(spec)
    prods = P.producers(spec)
    xs = source_inputs(spec, x)
    tensors: Dict[int, jnp.ndarray] = {}      # node -> output in its layout
    layouts: Dict[int, str] = {}
    prim_secs: Dict[int, float] = {}
    dlt_secs: Dict[Tuple[int, int], float] = {}

    def timed(jfn, *args) -> Tuple[jnp.ndarray, float]:
        y = jax.block_until_ready(jfn(*args))
        if not measure:
            return y, 0.0
        samples = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(*args))
            samples.append(time.perf_counter() - t0)
        return y, float(np.median(samples))

    def fetch_input(node_idx: int, want_layout: str) -> jnp.ndarray:
        """Gather and layout-convert the producer tensors for ``node_idx``."""
        ps = prods[node_idx]
        vals = []
        for p in ps:
            v, src = tensors[p], layouts[p]
            if src != want_layout:
                v2, dt = timed(_cached_dlt(src, want_layout, v), v)
                dlt_secs[(p, node_idx)] = dlt_secs.get((p, node_idx), 0.0) + dt
                v = v2
            vals.append(v)
        return vals

    for i in order:
        node = spec.nodes[i]
        if isinstance(node, ConvLayer):
            prim = resolve(assignment[i])
            if prim.impl is None:
                raise ValueError(f"assignment uses simulated-only primitive {prim.name}")
            if prods[i]:
                (xin,) = fetch_input(i, prim.in_layout)
            else:
                xin = L.from_chw(xs[i], prim.in_layout)
            y, dt = timed(_cached_primitive(assignment[i], xin, weights[i], node.s),
                          xin, weights[i])
            tensors[i], layouts[i] = y, prim.out_layout
            prim_secs[i] = dt
        elif isinstance(node, EltwiseLayer):
            lay = assignment[i]
            (v,) = fetch_input(i, lay)
            if node.kind == "relu":
                y = jnp.maximum(v, 0.0)
            elif node.kind == "bias":
                shape = [1, 1, 1]
                shape[L.C_AXIS[lay]] = node.c
                y = v + weights[i].reshape(shape)
            else:
                raise ValueError(node.kind)
            tensors[i], layouts[i] = y, lay
        else:
            lay = assignment[i]
            vals = fetch_input(i, lay)
            # Branches run valid (un-padded) convolutions, so spatial sizes
            # can differ by a few pixels across branch depths; centre-crop to
            # the smallest (real deployments pad — padding does not change
            # the primitive-selection problem, see DESIGN.md §10).
            vals = P.crop_to_common(vals, lay)
            if node.kind == "concat":
                y = jnp.concatenate(vals, axis=L.C_AXIS[lay])
            elif node.kind == "add":
                y = vals[0]
                for v in vals[1:]:
                    y = y + v
            else:
                raise ValueError(node.kind)
            tensors[i], layouts[i] = y, lay

    return ExecutionReport(tensors, prim_secs, dlt_secs)
