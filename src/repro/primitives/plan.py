"""Plan compiler: lower an assigned CNN DAG into ONE jitted batched function.

The interpreted executor (repro.primitives.executor) dispatches ~2xN jitted
callables per image — one per primitive plus one per materialised DLT. The
paper's end product, though, is an *assignment* whose value is realised at
inference time; serving wants the assigned network treated as a single
compiled artifact (cf. Anderson & Gregg's PBQP formulation, and TASO's
whole-graph substitution view). ``compile_plan`` does that lowering:

* the topo-ordered DAG (convs, DLTs, concat/add joins, centre-crops) becomes
  one traced function over a leading batch axis, jitted once and cached by
  ``(spec, assignment, batch_shape)``;
* adjacent DLT -> primitive pairs are *fused*: a DLT is an axis permutation,
  so each edge carries a composed permutation that is (a) dropped when it is
  the identity, (b) inlined into the consumer's traced call otherwise —
  inside one XLA program the transpose fuses into the consumer's first read
  and the intermediate layout copy never materialises in HBM;
* primitives run through their batched entry points
  (``conv.batch_impl`` — rank-polymorphic impls, vmap fallback).

Lowering rules, fusion criteria and batch semantics: DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models.cnn_zoo import CNNSpec, ConvLayer, EltwiseLayer, JoinNode
from repro.primitives import layouts as L
from repro.primitives.conv import (REGISTRY, Primitive, batch_impl, resolve,
                                   split_tile, variant_compatible)
from repro.primitives.variants import conv_variant_call



# ---------------------------------------------------------------------------
# Graph utilities (shared with the interpreted executor)
# ---------------------------------------------------------------------------

def consumers(spec: CNNSpec) -> Dict[int, List[int]]:
    out: Dict[int, List[int]] = {i: [] for i in range(len(spec.nodes))}
    for u, v in spec.edges:
        out[u].append(v)
    return out


def producers(spec: CNNSpec) -> Dict[int, List[int]]:
    out: Dict[int, List[int]] = {i: [] for i in range(len(spec.nodes))}
    for u, v in spec.edges:
        out[v].append(u)
    return out


def topo_order(spec: CNNSpec) -> List[int]:
    prods = producers(spec)
    indeg = {i: len(p) for i, p in prods.items()}
    ready = [i for i, d in indeg.items() if d == 0]
    order = []
    cons = consumers(spec)
    while ready:
        n = ready.pop()
        order.append(n)
        for v in cons[n]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if len(order) != len(spec.nodes):
        raise ValueError("cycle in CNN spec")
    return order


def source_nodes(spec: CNNSpec) -> List[int]:
    """Producer-less conv nodes, in topo order (the network inputs)."""
    prods = producers(spec)
    return [i for i in topo_order(spec)
            if not prods[i] and isinstance(spec.nodes[i], ConvLayer)]


def sink_nodes(spec: CNNSpec) -> List[int]:
    cons = consumers(spec)
    return [i for i in range(len(spec.nodes)) if not cons[i]]


def crop_to_common(vals: Sequence[jnp.ndarray], layout: str) -> List[jnp.ndarray]:
    """Centre-crop a list of same-layout tensors to the smallest spatial size
    (rank-polymorphic: layout describes the trailing three axes)."""
    ah, aw = L.SPATIAL_AXES[layout]
    h = min(v.shape[v.ndim - 3 + ah] for v in vals)
    w = min(v.shape[v.ndim - 3 + aw] for v in vals)
    out = []
    for v in vals:
        lead = v.ndim - 3
        sl = [slice(None)] * v.ndim
        oh = (v.shape[lead + ah] - h) // 2
        ow = (v.shape[lead + aw] - w) // 2
        sl[lead + ah] = slice(oh, oh + h)
        sl[lead + aw] = slice(ow, ow + w)
        out.append(v[tuple(sl)])
    return out


# ---------------------------------------------------------------------------
# Lowered steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """Elementwise work folded into a ConvStep's kernel epilogue
    (bias -> residual -> ReLU, applied on the output tile before the HBM
    writeback — DESIGN.md §13.2). ``alias`` is the last fused node: the
    conv step now *produces* that node's output."""
    alias: int
    bias: Optional[int] = None                          # EltwiseLayer node (weights key)
    residual: Optional[Tuple[int, Tuple[int, int, int]]] = None  # (producer, perm)
    relu: bool = False

    @property
    def ops(self) -> Tuple[str, ...]:
        out = []
        if self.bias is not None:
            out.append("bias")
        if self.residual is not None:
            out.append("residual")
        if self.relu:
            out.append("relu")
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class ConvStep:
    node: int
    prim: Primitive
    stride: int
    src: Optional[int]                    # None => network input
    perm: Tuple[int, int, int]            # fused DLT into prim.in_layout
    variant: Optional[str] = None         # Pallas tile variant ("mm-*", ...)
    epilogue: Optional[EpilogueSpec] = None

    @property
    def out_node(self) -> int:
        """Node id this step's output stands for (the epilogue alias when
        elementwise consumers were folded in)."""
        return self.epilogue.alias if self.epilogue is not None else self.node


@dataclasses.dataclass(frozen=True)
class JoinStep:
    node: int
    kind: str                             # "concat" | "add"
    layout: str
    ins: Tuple[Tuple[int, Tuple[int, int, int]], ...]   # (producer, fused perm)


@dataclasses.dataclass(frozen=True)
class EltwiseStep:
    """Un-fused elementwise node (epilogue fusion off, or layout/ordering
    made folding impossible)."""
    node: int
    kind: str                             # "relu" | "bias"
    src: int
    perm: Tuple[int, int, int]
    layout: str


PlanStep = Union[ConvStep, JoinStep, EltwiseStep]


def _out_spatial(node) -> int:
    return node.out_im if isinstance(node, ConvLayer) else node.im


def lower(spec: CNNSpec, assignment: Dict[int, str], *,
          epilogues: bool = False) -> Tuple[List[PlanStep], Dict[int, str]]:
    """Lower the assigned DAG to a step list with DLT fusion applied.

    Returns the steps in topo order plus each node's produced layout. Every
    edge carries at most one axis permutation (identity permutations are
    eliminated at this stage, non-identity ones are inlined by the emitter).

    Tile columns ("base@variant") lower to the variant's Pallas kernel entry
    point (``primitives.variants``); ``variant_compatible`` pairs only —
    selection filters through ``conv.is_runnable`` so a rejection here means
    a hand-written assignment. With ``epilogues=True`` eligible elementwise
    consumers (bias add, ReLU, 2-input residual add) of an epilogue-capable
    conv are folded into the producing ConvStep's ``EpilogueSpec``: the conv
    step moves to the consumer's topo position and produces the consumer's
    output (``out_node``) — fusion criteria in DESIGN.md §13.2.
    """
    prods = producers(spec)
    cons = consumers(spec)
    steps: List[Optional[PlanStep]] = []
    prod_step: Dict[int, int] = {}        # node -> index of producing step
    layout_of: Dict[int, str] = {}

    def fusable(p: int, lay: str) -> Optional[ConvStep]:
        """The ConvStep producing node ``p`` if an epilogue can fold onto it:
        epilogue-capable base, chw output matching ``lay``, ``p`` consumed
        exactly once (by the node being lowered)."""
        st = steps[prod_step[p]] if p in prod_step else None
        if (isinstance(st, ConvStep) and st.prim.traits.get("epilogue")
                and st.prim.out_layout == "chw" and lay == "chw"
                and len(cons[p]) == 1):
            return st
        return None

    def refuse(p: int, st: ConvStep, ep: EpilogueSpec) -> None:
        """Move ``st`` (producer of ``p``) to the current topo position with
        the grown epilogue — its output now stands for ``ep.alias``."""
        steps[prod_step[p]] = None
        steps.append(dataclasses.replace(st, epilogue=ep))
        prod_step[ep.alias] = len(steps) - 1
        layout_of[ep.alias] = "chw"

    for i in topo_order(spec):
        node = spec.nodes[i]
        if isinstance(node, ConvLayer):
            base, variant = split_tile(assignment[i])
            prim = REGISTRY.get(base)
            if prim is None or prim.impl is None:
                raise ValueError(f"assignment uses simulated-only primitive {base}")
            if variant is not None and not variant_compatible(base, variant):
                raise ValueError(f"tile variant {variant!r} cannot lower "
                                 f"through {base!r} (node {i})")
            ps = prods[i]
            if len(ps) > 1:
                raise ValueError(f"conv node {i} has {len(ps)} producers")
            if ps:
                pm = L.perm(layout_of[ps[0]], prim.in_layout)
                steps.append(ConvStep(i, prim, node.s, ps[0], pm, variant))
            else:
                pm = L.perm("chw", prim.in_layout)     # inputs arrive chw
                steps.append(ConvStep(i, prim, node.s, None, pm, variant))
            prod_step[i] = len(steps) - 1
            layout_of[i] = prim.out_layout
        elif isinstance(node, EltwiseLayer):
            lay = assignment[i]
            if lay not in L.LAYOUTS:
                raise ValueError(f"eltwise node {i} assigned non-layout {lay!r}")
            (p,) = prods[i]
            st = fusable(p, lay) if epilogues else None
            ep = st.epilogue if st is not None else None
            if st is not None and node.kind == "bias" and (
                    ep is None or (ep.bias is None and ep.residual is None
                                   and not ep.relu)):
                refuse(p, st, EpilogueSpec(alias=i, bias=i,
                                           residual=ep.residual if ep else None,
                                           relu=False))
            elif st is not None and node.kind == "relu" and (
                    ep is None or not ep.relu):
                refuse(p, st, dataclasses.replace(
                    ep or EpilogueSpec(alias=i), alias=i, relu=True))
            else:
                pm = L.perm(layout_of[p], lay)
                steps.append(EltwiseStep(i, node.kind, p, pm, lay))
                prod_step[i] = len(steps) - 1
                layout_of[i] = lay
        else:
            lay = assignment[i]
            if lay not in L.LAYOUTS:
                raise ValueError(f"join node {i} assigned non-layout {lay!r}")
            ins = tuple((p, L.perm(layout_of[p], lay)) for p in prods[i])
            fused = False
            if epilogues and node.kind == "add" and len(ins) == 2:
                for (p, _), (q, qpm) in ((ins[0], ins[1]), (ins[1], ins[0])):
                    st = fusable(p, lay)
                    ep = st.epilogue if st is not None else None
                    # conv output must be the join's (smallest) spatial size —
                    # the other operand centre-crops onto it; one residual
                    # per step, and never after a folded ReLU
                    if (st is not None
                            and (ep is None or (ep.residual is None
                                                and not ep.relu))
                            and _out_spatial(spec.nodes[p]) == node.im):
                        refuse(p, st, EpilogueSpec(
                            alias=i, bias=ep.bias if ep else None,
                            residual=(q, qpm), relu=False))
                        fused = True
                        break
            if not fused:
                steps.append(JoinStep(i, node.kind, lay, ins))
                prod_step[i] = len(steps) - 1
                layout_of[i] = lay
    return [st for st in steps if st is not None], layout_of


def heuristic_assignment(spec: CNNSpec) -> Dict[int, str]:
    """Deterministic runnable assignment (no profiling): GEMM-lowered convs,
    pointwise GEMM for 1x1, chw joins — the shape of a typical selection.
    Shared by the executor benchmark and the plan tests."""
    asg: Dict[int, str] = {}
    for i, node in enumerate(spec.nodes):
        if isinstance(node, ConvLayer):
            asg[i] = "conv-1x1-gemm-ab-ki" if node.f == 1 else "im2col-copy-ab-ki"
        else:
            asg[i] = "chw"
    return asg


def fused_dlt_count(steps: Sequence[PlanStep]) -> Tuple[int, int]:
    """(eliminated identity DLTs, inlined transposes) across the plan edges."""
    fused = inlined = 0
    for st in steps:
        if isinstance(st, JoinStep):
            perms = [pm for _, pm in st.ins]
        else:
            perms = [st.perm]
            if isinstance(st, ConvStep) and st.epilogue is not None \
                    and st.epilogue.residual is not None:
                perms.append(st.epilogue.residual[1])
        for pm in perms:
            if L.is_identity(pm):
                fused += 1
            else:
                inlined += 1
    return fused, inlined


def epilogue_signature(steps: Sequence[PlanStep]) -> Tuple[Tuple[int, int, Tuple[str, ...]], ...]:
    """(conv node, alias node, fused ops) per epilogue-fused step — the
    plan's fusion fingerprint (part of benchmark rows and plan identity)."""
    return tuple((st.node, st.epilogue.alias, st.epilogue.ops)
                 for st in steps
                 if isinstance(st, ConvStep) and st.epilogue is not None)


# ---------------------------------------------------------------------------
# Plan compilation + cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledPlan:
    """One jitted function for the whole assigned network.

    ``__call__(x, weights)`` takes a batched chw input (n, c, im, im) — or a
    ``{source node: array}`` dict for multi-input specs — and returns
    ``{node: batched output in its native layout}`` for the requested output
    set. Steady-state serving is a single dispatch per request batch.
    """
    spec: CNNSpec
    assignment: Dict[int, str]
    steps: List[PlanStep]
    layouts: Dict[int, str]               # node -> produced layout
    sources: List[int]
    sinks: List[int]
    outputs: str                          # "sinks" | "all"
    fn: Callable                          # jitted (xs dict, weights) -> outputs
    epilogues: bool = False               # epilogue fusion pass applied
    epilogue_signature: Tuple = ()        # (conv, alias, ops) per fused step

    def __call__(self, x, weights: Dict[int, jnp.ndarray]) -> Dict[int, jnp.ndarray]:
        xs = self._as_inputs(x)
        return self.fn(xs, weights)

    def _as_inputs(self, x) -> Dict[int, jnp.ndarray]:
        if isinstance(x, dict):
            return {int(k): jnp.asarray(v) for k, v in x.items()}
        if len(self.sources) != 1:
            raise ValueError(f"spec has {len(self.sources)} inputs; pass a dict")
        return {self.sources[0]: jnp.asarray(x)}


def _crop_center(r: jnp.ndarray, oh: int, ow: int) -> jnp.ndarray:
    """Centre-crop trailing spatial axes to (oh, ow) — the chw analogue of
    ``crop_to_common`` for a single residual operand."""
    h, w = r.shape[-2:]
    dh, dw = (h - oh) // 2, (w - ow) // 2
    return r[..., dh:dh + oh, dw:dw + ow]


def _emit(steps: List[PlanStep], want: List[int]) -> Callable:
    """Build the traced function replaying ``steps`` over a leading batch."""
    def fn(xs: Dict[int, jnp.ndarray], weights: Dict[int, jnp.ndarray]):
        tensors: Dict[int, jnp.ndarray] = {}
        for st in steps:
            if isinstance(st, ConvStep):
                v = xs[st.node] if st.src is None else tensors[st.src]
                v = L.apply_perm(v, st.perm)          # fused DLT (no-op if id)
                w = weights[st.node]
                ep = st.epilogue
                bias = res = None
                relu = False
                if ep is not None:
                    bias = weights[ep.bias] if ep.bias is not None else None
                    relu = ep.relu
                    if ep.residual is not None:
                        q, pm = ep.residual
                        f = w.shape[-1]
                        oh = (v.shape[-2] - f) // st.stride + 1
                        ow = (v.shape[-1] - f) // st.stride + 1
                        res = _crop_center(L.apply_perm(tensors[q], pm), oh, ow)
                if st.variant is not None:
                    y = conv_variant_call(st.prim, st.variant, v, w,
                                          st.stride, bias=bias, residual=res,
                                          relu=relu)
                else:
                    y = batch_impl(st.prim)(v, w, st.stride)
                    if bias is not None:              # chw-out (fusion criterion)
                        y = y + bias[:, None, None]
                    if res is not None:
                        y = y + res
                    if relu:
                        y = jnp.maximum(y, 0.0)
                tensors[st.out_node] = y
            elif isinstance(st, EltwiseStep):
                v = L.apply_perm(tensors[st.src], st.perm)
                if st.kind == "relu":
                    y = jnp.maximum(v, 0.0)
                elif st.kind == "bias":
                    b = weights[st.node]
                    shape = [1, 1, 1]
                    shape[L.C_AXIS[st.layout]] = b.shape[0]
                    y = v + b.reshape(shape)
                else:
                    raise ValueError(st.kind)
                tensors[st.node] = y
            else:
                vals = [L.apply_perm(tensors[p], pm) for p, pm in st.ins]
                vals = crop_to_common(vals, st.layout)
                if st.kind == "concat":
                    axis = -3 + L.C_AXIS[st.layout]
                    y = jnp.concatenate(vals, axis=axis)
                elif st.kind == "add":
                    y = vals[0]
                    for v in vals[1:]:
                        y = y + v
                else:
                    raise ValueError(st.kind)
                tensors[st.node] = y
        return {i: tensors[i] for i in want}
    return fn


def _spec_key(spec: CNNSpec) -> Tuple:
    return (spec.name, tuple(spec.nodes), tuple(spec.edges))


_PLAN_CACHE: "OrderedDict[Tuple, CompiledPlan]" = OrderedDict()
_PLAN_CACHE_CAP = 64


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def evict_plans(spec: CNNSpec, assignment: Dict[int, str]) -> int:
    """Drop every cached plan for (``spec``, ``assignment``) — all batch
    shapes, output modes and epilogue settings. Called when a served
    generation retires (hot_swap / re-register): stale compiled plans for
    dead generations must not pin jitted executables in memory. Returns the
    number of evicted entries."""
    skey = _spec_key(spec)
    akey = tuple(sorted(assignment.items()))
    dead = [k for k in _PLAN_CACHE if k[0] == skey and k[1] == akey]
    for k in dead:
        del _PLAN_CACHE[k]
    return len(dead)


def compile_plan(spec: CNNSpec, assignment: Dict[int, str],
                 batch_shape: Optional[Tuple[int, ...]] = None, *,
                 outputs: str = "sinks",
                 epilogues: Optional[bool] = None) -> CompiledPlan:
    """Compile (and cache) the whole-graph batched plan for ``assignment``.

    ``batch_shape`` is the (n, c, im, im) input shape the caller will feed —
    part of the cache key so steady-state serving of a known shape is a dict
    lookup followed by one jitted dispatch (``None`` = shape-generic entry;
    jax.jit re-specialises per concrete shape either way). ``outputs`` picks
    the returned node set: "sinks" (serving) or "all" (the interpreted
    executor's report surface).

    ``epilogues`` controls the elementwise-fusion pass (DESIGN.md §13.2):
    default on for "sinks" plans, forced off for "all" (fused interior nodes
    would not be reportable — "all" is the unfused oracle surface). The
    flag is part of the cache key; since the fused-epilogue set is a pure
    function of (spec, assignment, flag), the key also pins the plan's
    ``epilogue_signature``. Tile variants are keyed through the assignment's
    full column names.
    """
    if outputs not in ("sinks", "all"):
        raise ValueError(outputs)
    eff_ep = (outputs == "sinks") if epilogues is None \
        else (epilogues and outputs == "sinks")
    key = (_spec_key(spec), tuple(sorted(assignment.items())),
           batch_shape, outputs, eff_ep)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_CACHE.move_to_end(key)
        return plan
    steps, layout_of = lower(spec, assignment, epilogues=eff_ep)
    sinks = sink_nodes(spec)
    want = sinks if outputs == "sinks" else list(range(len(spec.nodes)))
    plan = CompiledPlan(spec, dict(assignment), steps, layout_of,
                        source_nodes(spec), sinks, outputs,
                        jax.jit(_emit(steps, want)),
                        epilogues=eff_ep,
                        epilogue_signature=epilogue_signature(steps))
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _PLAN_CACHE_CAP:
        _PLAN_CACHE.popitem(last=False)
    return plan
