"""Plan compiler: lower an assigned CNN DAG into ONE jitted batched function.

The interpreted executor (repro.primitives.executor) dispatches ~2xN jitted
callables per image — one per primitive plus one per materialised DLT. The
paper's end product, though, is an *assignment* whose value is realised at
inference time; serving wants the assigned network treated as a single
compiled artifact (cf. Anderson & Gregg's PBQP formulation, and TASO's
whole-graph substitution view). ``compile_plan`` does that lowering:

* the topo-ordered DAG (convs, DLTs, concat/add joins, centre-crops) becomes
  one traced function over a leading batch axis, jitted once and cached by
  ``(spec, assignment, batch_shape)``;
* adjacent DLT -> primitive pairs are *fused*: a DLT is an axis permutation,
  so each edge carries a composed permutation that is (a) dropped when it is
  the identity, (b) inlined into the consumer's traced call otherwise —
  inside one XLA program the transpose fuses into the consumer's first read
  and the intermediate layout copy never materialises in HBM;
* primitives run through their batched entry points
  (``conv.batch_impl`` — rank-polymorphic impls, vmap fallback).

Lowering rules, fusion criteria and batch semantics: DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models.cnn_zoo import CNNSpec, ConvLayer
from repro.primitives import layouts as L
from repro.primitives.conv import REGISTRY, Primitive, batch_impl, resolve



# ---------------------------------------------------------------------------
# Graph utilities (shared with the interpreted executor)
# ---------------------------------------------------------------------------

def consumers(spec: CNNSpec) -> Dict[int, List[int]]:
    out: Dict[int, List[int]] = {i: [] for i in range(len(spec.nodes))}
    for u, v in spec.edges:
        out[u].append(v)
    return out


def producers(spec: CNNSpec) -> Dict[int, List[int]]:
    out: Dict[int, List[int]] = {i: [] for i in range(len(spec.nodes))}
    for u, v in spec.edges:
        out[v].append(u)
    return out


def topo_order(spec: CNNSpec) -> List[int]:
    prods = producers(spec)
    indeg = {i: len(p) for i, p in prods.items()}
    ready = [i for i, d in indeg.items() if d == 0]
    order = []
    cons = consumers(spec)
    while ready:
        n = ready.pop()
        order.append(n)
        for v in cons[n]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if len(order) != len(spec.nodes):
        raise ValueError("cycle in CNN spec")
    return order


def source_nodes(spec: CNNSpec) -> List[int]:
    """Producer-less conv nodes, in topo order (the network inputs)."""
    prods = producers(spec)
    return [i for i in topo_order(spec)
            if not prods[i] and isinstance(spec.nodes[i], ConvLayer)]


def sink_nodes(spec: CNNSpec) -> List[int]:
    cons = consumers(spec)
    return [i for i in range(len(spec.nodes)) if not cons[i]]


def crop_to_common(vals: Sequence[jnp.ndarray], layout: str) -> List[jnp.ndarray]:
    """Centre-crop a list of same-layout tensors to the smallest spatial size
    (rank-polymorphic: layout describes the trailing three axes)."""
    ah, aw = L.SPATIAL_AXES[layout]
    h = min(v.shape[v.ndim - 3 + ah] for v in vals)
    w = min(v.shape[v.ndim - 3 + aw] for v in vals)
    out = []
    for v in vals:
        lead = v.ndim - 3
        sl = [slice(None)] * v.ndim
        oh = (v.shape[lead + ah] - h) // 2
        ow = (v.shape[lead + aw] - w) // 2
        sl[lead + ah] = slice(oh, oh + h)
        sl[lead + aw] = slice(ow, ow + w)
        out.append(v[tuple(sl)])
    return out


# ---------------------------------------------------------------------------
# Lowered steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvStep:
    node: int
    prim: Primitive
    stride: int
    src: Optional[int]                    # None => network input
    perm: Tuple[int, int, int]            # fused DLT into prim.in_layout


@dataclasses.dataclass(frozen=True)
class JoinStep:
    node: int
    kind: str                             # "concat" | "add"
    layout: str
    ins: Tuple[Tuple[int, Tuple[int, int, int]], ...]   # (producer, fused perm)


PlanStep = Union[ConvStep, JoinStep]


def lower(spec: CNNSpec, assignment: Dict[int, str]) -> Tuple[List[PlanStep], Dict[int, str]]:
    """Lower the assigned DAG to a step list with DLT fusion applied.

    Returns the steps in topo order plus each node's produced layout. Every
    edge carries at most one axis permutation (identity permutations are
    eliminated at this stage, non-identity ones are inlined by the emitter).
    """
    prods = producers(spec)
    steps: List[PlanStep] = []
    layout_of: Dict[int, str] = {}
    for i in topo_order(spec):
        node = spec.nodes[i]
        if isinstance(node, ConvLayer):
            # tile columns lower to their base primitive's impl (the tile is
            # a Pallas dispatch hint, not a different algorithm)
            prim = resolve(assignment[i])
            if prim.impl is None:
                raise ValueError(f"assignment uses simulated-only primitive {prim.name}")
            ps = prods[i]
            if len(ps) > 1:
                raise ValueError(f"conv node {i} has {len(ps)} producers")
            if ps:
                pm = L.perm(layout_of[ps[0]], prim.in_layout)
                steps.append(ConvStep(i, prim, node.s, ps[0], pm))
            else:
                pm = L.perm("chw", prim.in_layout)     # inputs arrive chw
                steps.append(ConvStep(i, prim, node.s, None, pm))
            layout_of[i] = prim.out_layout
        else:
            lay = assignment[i]
            if lay not in L.LAYOUTS:
                raise ValueError(f"join node {i} assigned non-layout {lay!r}")
            ins = tuple((p, L.perm(layout_of[p], lay)) for p in prods[i])
            steps.append(JoinStep(i, node.kind, lay, ins))
            layout_of[i] = lay
    return steps, layout_of


def heuristic_assignment(spec: CNNSpec) -> Dict[int, str]:
    """Deterministic runnable assignment (no profiling): GEMM-lowered convs,
    pointwise GEMM for 1x1, chw joins — the shape of a typical selection.
    Shared by the executor benchmark and the plan tests."""
    asg: Dict[int, str] = {}
    for i, node in enumerate(spec.nodes):
        if isinstance(node, ConvLayer):
            asg[i] = "conv-1x1-gemm-ab-ki" if node.f == 1 else "im2col-copy-ab-ki"
        else:
            asg[i] = "chw"
    return asg


def fused_dlt_count(steps: Sequence[PlanStep]) -> Tuple[int, int]:
    """(eliminated identity DLTs, inlined transposes) across the plan edges."""
    fused = inlined = 0
    for st in steps:
        perms = ([st.perm] if isinstance(st, ConvStep) else [pm for _, pm in st.ins])
        for pm in perms:
            if L.is_identity(pm):
                fused += 1
            else:
                inlined += 1
    return fused, inlined


# ---------------------------------------------------------------------------
# Plan compilation + cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledPlan:
    """One jitted function for the whole assigned network.

    ``__call__(x, weights)`` takes a batched chw input (n, c, im, im) — or a
    ``{source node: array}`` dict for multi-input specs — and returns
    ``{node: batched output in its native layout}`` for the requested output
    set. Steady-state serving is a single dispatch per request batch.
    """
    spec: CNNSpec
    assignment: Dict[int, str]
    steps: List[PlanStep]
    layouts: Dict[int, str]               # node -> produced layout
    sources: List[int]
    sinks: List[int]
    outputs: str                          # "sinks" | "all"
    fn: Callable                          # jitted (xs dict, weights) -> outputs

    def __call__(self, x, weights: Dict[int, jnp.ndarray]) -> Dict[int, jnp.ndarray]:
        xs = self._as_inputs(x)
        return self.fn(xs, weights)

    def _as_inputs(self, x) -> Dict[int, jnp.ndarray]:
        if isinstance(x, dict):
            return {int(k): jnp.asarray(v) for k, v in x.items()}
        if len(self.sources) != 1:
            raise ValueError(f"spec has {len(self.sources)} inputs; pass a dict")
        return {self.sources[0]: jnp.asarray(x)}


def _emit(steps: List[PlanStep], want: List[int]) -> Callable:
    """Build the traced function replaying ``steps`` over a leading batch."""
    def fn(xs: Dict[int, jnp.ndarray], weights: Dict[int, jnp.ndarray]):
        tensors: Dict[int, jnp.ndarray] = {}
        for st in steps:
            if isinstance(st, ConvStep):
                v = xs[st.node] if st.src is None else tensors[st.src]
                v = L.apply_perm(v, st.perm)          # fused DLT (no-op if id)
                tensors[st.node] = batch_impl(st.prim)(v, weights[st.node], st.stride)
            else:
                vals = [L.apply_perm(tensors[p], pm) for p, pm in st.ins]
                vals = crop_to_common(vals, st.layout)
                if st.kind == "concat":
                    axis = -3 + L.C_AXIS[st.layout]
                    y = jnp.concatenate(vals, axis=axis)
                elif st.kind == "add":
                    y = vals[0]
                    for v in vals[1:]:
                        y = y + v
                else:
                    raise ValueError(st.kind)
                tensors[st.node] = y
        return {i: tensors[i] for i in want}
    return fn


def _spec_key(spec: CNNSpec) -> Tuple:
    return (spec.name, tuple(spec.nodes), tuple(spec.edges))


_PLAN_CACHE: "OrderedDict[Tuple, CompiledPlan]" = OrderedDict()
_PLAN_CACHE_CAP = 64


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def compile_plan(spec: CNNSpec, assignment: Dict[int, str],
                 batch_shape: Optional[Tuple[int, ...]] = None, *,
                 outputs: str = "sinks") -> CompiledPlan:
    """Compile (and cache) the whole-graph batched plan for ``assignment``.

    ``batch_shape`` is the (n, c, im, im) input shape the caller will feed —
    part of the cache key so steady-state serving of a known shape is a dict
    lookup followed by one jitted dispatch (``None`` = shape-generic entry;
    jax.jit re-specialises per concrete shape either way). ``outputs`` picks
    the returned node set: "sinks" (serving) or "all" (the interpreted
    executor's report surface).
    """
    if outputs not in ("sinks", "all"):
        raise ValueError(outputs)
    key = (_spec_key(spec), tuple(sorted(assignment.items())),
           batch_shape, outputs)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_CACHE.move_to_end(key)
        return plan
    steps, layout_of = lower(spec, assignment)
    sinks = sink_nodes(spec)
    want = sinks if outputs == "sinks" else list(range(len(spec.nodes)))
    plan = CompiledPlan(spec, dict(assignment), steps, layout_of,
                        source_nodes(spec), sinks, outputs,
                        jax.jit(_emit(steps, want)))
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _PLAN_CACHE_CAP:
        _PLAN_CACHE.popitem(last=False)
    return plan
