"""Data layouts and data-layout transformations (DLTs), paper §3.2.2.

The primitive suite uses three single-image layouts for a (c, im, im)
activation tensor:

    chw — c × im × im   (channels-first; paper's "c x im x im")
    hcw — im × c × im   (paper's "im x c x im")
    hwc — im × im × c   (channels-last; paper's "im x im x c")

There are 9 ordered DLT pairs including identity (cost 0). A DLT's cost
depends only on (c, im) and the pair — exactly the feature set the DLT
performance model consumes.

All transforms are rank-polymorphic: the layout describes the *last three*
axes, so a batched (n, c, im, im) tensor — or any stack of images — goes
through the same API. The plan compiler (repro.primitives.plan) relies on
this to lower whole-batch DLTs, and on ``perm``/``compose`` to fuse DLT
chains into a single transpose.
"""
from __future__ import annotations

import itertools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

LAYOUTS = ("chw", "hcw", "hwc")

# channel / spatial axis positions within the trailing three (image) axes
C_AXIS = {"chw": 0, "hcw": 1, "hwc": 2}
SPATIAL_AXES = {"chw": (1, 2), "hcw": (0, 2), "hwc": (0, 1)}

# permutation that maps a chw tensor to the given layout
_FROM_CHW = {
    "chw": (0, 1, 2),
    "hcw": (1, 0, 2),
    "hwc": (1, 2, 0),
}


def _invert(perm: Tuple[int, int, int]) -> Tuple[int, int, int]:
    inv = [0, 0, 0]
    for i, p in enumerate(perm):
        inv[p] = i
    return tuple(inv)


def perm(src: str, dst: str) -> Tuple[int, int, int]:
    """Axis permutation (over the trailing image axes) realising src -> dst."""
    # chw -> dst applied after src -> chw
    return compose(_invert(_FROM_CHW[src]), _FROM_CHW[dst])


def compose(p: Tuple[int, int, int], q: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Permutation applying ``p`` then ``q`` (both as transpose arguments)."""
    return tuple(p[a] for a in q)


def is_identity(p: Tuple[int, int, int]) -> bool:
    return tuple(p) == (0, 1, 2)


def _full_perm(x: jnp.ndarray, p: Tuple[int, int, int]) -> Tuple[int, ...]:
    """Extend an image-axis permutation over the leading (batch) axes."""
    lead = x.ndim - 3
    if lead < 0:
        raise ValueError(f"layout transforms need rank >= 3, got {x.shape}")
    return tuple(range(lead)) + tuple(lead + a for a in p)


def apply_perm(x: jnp.ndarray, p: Tuple[int, int, int]) -> jnp.ndarray:
    """Transpose the trailing image axes by ``p``, batch axes untouched."""
    if is_identity(p):
        return x
    return jnp.transpose(x, _full_perm(x, p))


def from_chw(x: jnp.ndarray, layout: str) -> jnp.ndarray:
    return apply_perm(x, _FROM_CHW[layout])


def to_chw(x: jnp.ndarray, layout: str) -> jnp.ndarray:
    return apply_perm(x, _invert(_FROM_CHW[layout]))


def transform(x: jnp.ndarray, src: str, dst: str) -> jnp.ndarray:
    """Apply the DLT src -> dst (trailing image axes; leading axes = batch)."""
    if src == dst:
        return x
    return apply_perm(x, perm(src, dst))


def dlt_pairs() -> list[Tuple[str, str]]:
    """All 9 ordered layout pairs, identity included (paper profiles all 9)."""
    return list(itertools.product(LAYOUTS, LAYOUTS))


def dlt_name(src: str, dst: str) -> str:
    return f"{src}->{dst}"


DLT_NAMES = [dlt_name(s, d) for s, d in dlt_pairs()]


def dlt_index(src: str, dst: str) -> int:
    return DLT_NAMES.index(dlt_name(src, dst))
