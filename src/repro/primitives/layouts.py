"""Data layouts and data-layout transformations (DLTs), paper §3.2.2.

The primitive suite uses three single-image layouts for a (c, im, im)
activation tensor:

    chw — c × im × im   (channels-first; paper's "c x im x im")
    hcw — im × c × im   (paper's "im x c x im")
    hwc — im × im × c   (channels-last; paper's "im x im x c")

There are 9 ordered DLT pairs including identity (cost 0). A DLT's cost
depends only on (c, im) and the pair — exactly the feature set the DLT
performance model consumes.
"""
from __future__ import annotations

import itertools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

LAYOUTS = ("chw", "hcw", "hwc")

# permutation that maps a chw tensor to the given layout
_FROM_CHW = {
    "chw": (0, 1, 2),
    "hcw": (1, 0, 2),
    "hwc": (1, 2, 0),
}


def from_chw(x: jnp.ndarray, layout: str) -> jnp.ndarray:
    return jnp.transpose(x, _FROM_CHW[layout])


def to_chw(x: jnp.ndarray, layout: str) -> jnp.ndarray:
    perm = _FROM_CHW[layout]
    inv = [0, 0, 0]
    for i, p in enumerate(perm):
        inv[p] = i
    return jnp.transpose(x, inv)


def transform(x: jnp.ndarray, src: str, dst: str) -> jnp.ndarray:
    """Apply the DLT src -> dst."""
    if src == dst:
        return x
    return from_chw(to_chw(x, src), dst)


def dlt_pairs() -> list[Tuple[str, str]]:
    """All 9 ordered layout pairs, identity included (paper profiles all 9)."""
    return list(itertools.product(LAYOUTS, LAYOUTS))


def dlt_name(src: str, dst: str) -> str:
    return f"{src}->{dst}"


DLT_NAMES = [dlt_name(s, d) for s, d in dlt_pairs()]


def dlt_index(src: str, dst: str) -> int:
    return DLT_NAMES.index(dlt_name(src, dst))
