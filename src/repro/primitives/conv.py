"""Convolution primitive families (paper §3.1, appendix Table 6).

Every primitive computes the same valid, un-padded 2-D cross-correlation

    y[k, i, j] = sum_{c, a, b} x[c, i*s + a, j*s + b] * w[k, c, a, b]

but differs in *how*: data restructuring (im2col/im2row lowering, MEC partial
lowering, kn2 shift-accumulate, Winograd transform), GEMM orientation
(`ab`/`atb`/... transpose variants), traversal (`copy` = slice-stacked
lowering, `scan` = gather-indexed lowering) and input/output data layout
(chw / hcw / hwc). Implementations take the image in the primitive's
``in_layout`` and produce its ``out_layout``; weights are always (k, c, f, f).

17 primitives are runnable JAX implementations (validated against
``reference_conv`` = ``lax.conv_general_dilated``); the remaining entries of
the paper's Table 6 (SIMD-width `-vec-N` and residual transpose variants —
CPU-register-level distinctions that JAX/XLA does not expose) exist as
*simulated-only* registry entries used by the profiler simulators
(DESIGN.md §2.3).

Every runnable implementation is rank-polymorphic over leading batch axes:
the layout describes the trailing three axes, so a (n, c, im, im) batch goes
through the same code path with the GEMM stages broadcasting over ``n`` —
the batched entry point the plan compiler (DESIGN.md §6) lowers to. Use
``batch_impl``/``run_primitive_batch`` for the batched API (vmap fallback
for any future impl whose traits set ``batch=False``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from functools import lru_cache, partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.primitives import layouts as L


# ---------------------------------------------------------------------------
# Reference oracle
# ---------------------------------------------------------------------------

def reference_conv(x_chw: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Oracle: XLA's native convolution, NCHW single image."""
    y = jax.lax.conv_general_dilated(
        x_chw[None], w, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y[0]


def out_size(im: int, f: int, s: int) -> int:
    return (im - f) // s + 1


# ---------------------------------------------------------------------------
# Lowerings
#
# All lowerings operate on the trailing image axes; any leading axes are
# batch and broadcast straight through the GEMM stages.
# ---------------------------------------------------------------------------

def _t(x: jnp.ndarray, perm: Tuple[int, ...]) -> jnp.ndarray:
    """Transpose the trailing ``len(perm)`` axes, leading (batch) untouched."""
    lead = x.ndim - len(perm)
    return jnp.transpose(x, tuple(range(lead)) + tuple(lead + p for p in perm))


def _patches_copy_chw(x: jnp.ndarray, f: int, s: int) -> jnp.ndarray:
    """Slice-stacked ("copy") lowering: (..., c*f*f, oh*ow), (c, a, b) order."""
    c, h, w = x.shape[-3:]
    oh, ow = out_size(h, f, s), out_size(w, f, s)
    cols = []
    for a in range(f):
        for b in range(f):
            cols.append(x[..., a:a + (oh - 1) * s + 1:s, b:b + (ow - 1) * s + 1:s])
    pat = jnp.stack(cols, axis=-3)           # (..., c, f*f, oh, ow)
    return pat.reshape(*x.shape[:-3], c * f * f, oh * ow)


def _patches_scan_chw(x: jnp.ndarray, f: int, s: int) -> jnp.ndarray:
    """Gather-indexed ("scan") lowering — same result, different traversal."""
    c, h, w = x.shape[-3:]
    oh, ow = out_size(h, f, s), out_size(w, f, s)
    ih = (jnp.arange(oh) * s)[:, None] + jnp.arange(f)[None, :]   # (oh, f)
    iw = (jnp.arange(ow) * s)[:, None] + jnp.arange(f)[None, :]   # (ow, f)
    # gather -> (..., c, oh, f, ow, f)
    pat = jnp.take(jnp.take(x, ih, axis=-2), iw, axis=-1)
    pat = _t(pat, (0, 2, 4, 1, 3))           # (..., c, f, f, oh, ow)
    return pat.reshape(*x.shape[:-3], c * f * f, oh * ow)


def _w_mat(w: jnp.ndarray) -> jnp.ndarray:
    """(k, c*f*f) with (c, a, b) ordering — matches chw patch lowering."""
    k = w.shape[0]
    return w.reshape(k, -1)


def _w_mat_rows(w: jnp.ndarray) -> jnp.ndarray:
    """(k, f*f*c) with (a, b, c) ordering — matches hwc row lowering."""
    k = w.shape[0]
    return jnp.transpose(w, (0, 2, 3, 1)).reshape(k, -1)


def _patches_rows_hwc(x: jnp.ndarray, f: int, s: int, scan: bool) -> jnp.ndarray:
    """Row lowering from an hwc image: (..., oh*ow, f*f*c), (a, b, c) order."""
    h, w, c = x.shape[-3:]
    oh, ow = out_size(h, f, s), out_size(w, f, s)
    if scan:
        ih = (jnp.arange(oh) * s)[:, None] + jnp.arange(f)[None, :]
        iw = (jnp.arange(ow) * s)[:, None] + jnp.arange(f)[None, :]
        # gather -> (..., oh, f, ow, f, c)
        pat = jnp.take(jnp.take(x, ih, axis=-3), iw, axis=-2)
        pat = _t(pat, (0, 2, 1, 3, 4))              # (..., oh, ow, f, f, c)
    else:
        rows = []
        for a in range(f):
            for b in range(f):
                rows.append(x[..., a:a + (oh - 1) * s + 1:s, b:b + (ow - 1) * s + 1:s, :])
        pat = jnp.stack(rows, axis=-2)              # (..., oh, ow, f*f, c)
    return pat.reshape(*x.shape[:-3], oh * ow, f * f * c)


# ---------------------------------------------------------------------------
# im2col / im2row family
# ---------------------------------------------------------------------------

def im2col(x: jnp.ndarray, w: jnp.ndarray, s: int, *, scan: bool, out_ik: bool) -> jnp.ndarray:
    c, h, wd = x.shape[-3:]
    f = w.shape[2]
    oh, ow = out_size(h, f, s), out_size(wd, f, s)
    pat = (_patches_scan_chw if scan else _patches_copy_chw)(x, f, s)
    wm = _w_mat(w)
    lead = x.shape[:-3]
    if out_ik:
        y = jnp.swapaxes(pat, -1, -2) @ wm.T       # (..., P, k)  "atb-ik"
        return y.reshape(*lead, oh, ow, w.shape[0])        # hwc
    y = wm @ pat                                   # (..., k, P)  "ab-ki"
    return y.reshape(*lead, w.shape[0], oh, ow)            # chw


def im2row(x: jnp.ndarray, w: jnp.ndarray, s: int, *, scan: bool, out_ik: bool) -> jnp.ndarray:
    h, wd, c = x.shape[-3:]
    f = w.shape[2]
    oh, ow = out_size(h, f, s), out_size(wd, f, s)
    pat = _patches_rows_hwc(x, f, s, scan)
    wm = _w_mat_rows(w)
    lead = x.shape[:-3]
    if out_ik:
        y = pat @ wm.T                             # (..., P, k)
        return y.reshape(*lead, oh, ow, w.shape[0])        # hwc
    y = wm @ jnp.swapaxes(pat, -1, -2)             # (..., k, P)
    return y.reshape(*lead, w.shape[0], oh, ow)            # chw


# ---------------------------------------------------------------------------
# kn2 family (sum of f*f pointwise GEMMs, shift-accumulated; stride 1)
# ---------------------------------------------------------------------------

def kn2row(x: jnp.ndarray, w: jnp.ndarray, s: int, *, stacked: bool = False) -> jnp.ndarray:
    """chw -> chw. One (k,c)@(c,h*w) GEMM per kernel offset on the *full*
    image, then shifted accumulation of the valid region."""
    c, h, wd = x.shape[-3:]
    k, _, f, _ = w.shape
    oh, ow = out_size(h, f, s), out_size(wd, f, s)
    lead = x.shape[:-3]
    xf = x.reshape(*lead, c, h * wd)
    if stacked:  # "-as" variant: all offsets at once, one reduction
        g = jnp.transpose(w, (2, 3, 0, 1)).reshape(f * f * k, c)
        full = (g @ xf).reshape(*lead, f, f, k, h, wd)
        parts = [full[..., a, b, :, a:a + oh:1, b:b + ow:1]
                 for a in range(f) for b in range(f)]
        return jnp.sum(jnp.stack(parts), axis=0)
    acc = jnp.zeros((*lead, k, oh, ow), x.dtype)
    for a in range(f):
        for b in range(f):
            full = (w[:, :, a, b] @ xf).reshape(*lead, k, h, wd)
            acc = acc + full[..., a:a + oh, b:b + ow]
    return acc


def kn2col(x: jnp.ndarray, w: jnp.ndarray, s: int) -> jnp.ndarray:
    """hwc -> hwc. Image-major GEMM per offset."""
    h, wd, c = x.shape[-3:]
    k, _, f, _ = w.shape
    oh, ow = out_size(h, f, s), out_size(wd, f, s)
    lead = x.shape[:-3]
    xf = x.reshape(*lead, h * wd, c)
    acc = jnp.zeros((*lead, oh, ow, k), x.dtype)
    for a in range(f):
        for b in range(f):
            full = (xf @ w[:, :, a, b].T).reshape(*lead, h, wd, k)
            acc = acc + full[..., a:a + oh, b:b + ow, :]
    return acc


# ---------------------------------------------------------------------------
# Winograd family (stride 1)
# ---------------------------------------------------------------------------

# F(2x2, 3x3)
_BT_4 = np.array([[1, 0, -1, 0],
                  [0, 1, 1, 0],
                  [0, -1, 1, 0],
                  [0, 1, 0, -1]], np.float64)
_G_23 = np.array([[1, 0, 0],
                  [0.5, 0.5, 0.5],
                  [0.5, -0.5, 0.5],
                  [0, 0, 1]], np.float64)
_AT_2_3 = np.array([[1, 1, 1, 0],
                    [0, 1, -1, -1]], np.float64)

# n=6 point set {0, 1, -1, 2, -2, inf}
_BT_6 = np.array([[4, 0, -5, 0, 1, 0],
                  [0, -4, -4, 1, 1, 0],
                  [0, 4, -4, -1, 1, 0],
                  [0, -2, -1, 2, 1, 0],
                  [0, 2, -1, -2, 1, 0],
                  [0, 4, 0, -5, 0, 1]], np.float64)
_AT_4_3 = np.array([[1, 1, 1, 1, 1, 0],
                    [0, 1, -1, 2, -2, 0],
                    [0, 1, 1, 4, 4, 0],
                    [0, 1, -1, 8, -8, 1]], np.float64)
_AT_2_5 = np.array([[1, 1, 1, 1, 1, 0],
                    [0, 1, -1, 2, -2, 1]], np.float64)


def _derive_G(AT: np.ndarray, BT: np.ndarray, m: int, r: int) -> np.ndarray:
    """Solve for G from the Winograd identity AT @ diag(G g) @ BT == S(g)
    for kernel basis vectors — numerically robust, avoids transcription bugs
    in hand-copied G matrices. Residual is asserted tiny."""
    n = m + r - 1
    # column k of the linear map: vec(outer(AT[:, k], BT[k, :]))
    M = np.stack([np.outer(AT[:, k], BT[k, :]).ravel() for k in range(n)], axis=1)
    G = np.zeros((n, r))
    for i in range(r):
        S = np.zeros((m, n))
        for t in range(m):
            S[t, t + i] = 1.0
        sol, res, *_ = np.linalg.lstsq(M, S.ravel(), rcond=None)
        if not np.allclose(M @ sol, S.ravel(), atol=1e-9):
            raise RuntimeError("winograd G derivation failed")
        G[:, i] = sol
    return G


_G_43 = _derive_G(_AT_4_3, _BT_6, 4, 3)
_G_25 = _derive_G(_AT_2_5, _BT_6, 2, 5)

_WINO_SETS = {
    (2, 3): (_AT_2_3, _G_23, _BT_4),
    (4, 3): (_AT_4_3, _G_43, _BT_6),
    (2, 5): (_AT_2_5, _G_25, _BT_6),
}


def winograd2d(x: jnp.ndarray, w: jnp.ndarray, s: int, *, m: int, r: int) -> jnp.ndarray:
    """chw -> chw, F(mxm, rxr), stride 1."""
    assert s == 1
    AT, G, BT = (jnp.asarray(a, x.dtype) for a in _WINO_SETS[(m, r)])
    c, h, wd = x.shape[-3:]
    k, _, f, _ = w.shape
    n = m + r - 1
    oh, ow = h - r + 1, wd - r + 1
    th, tw = -(-oh // m), -(-ow // m)
    ph, pw = (th - 1) * m + n, (tw - 1) * m + n
    lead = x.shape[:-3]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, ph - h), (0, pw - wd)])
    # overlapping n x n tiles at stride m: slice-stack over in-tile offsets
    rows = []
    for a in range(n):
        cols = []
        for b in range(n):
            cols.append(xp[..., a:a + (th - 1) * m + 1:m, b:b + (tw - 1) * m + 1:m])
        rows.append(jnp.stack(cols, -1))
    tiles = jnp.stack(rows, -2)                       # (..., c, th, tw, n, n)
    V = jnp.einsum("an,...cijnb,bm->...cijam", BT, tiles, BT.T)
    U = jnp.einsum("an,kcnb,bm->kcam", G, w, G.T)      # (k, c, n, n)
    M = jnp.einsum("kcab,...cijab->...kijab", U, V)    # (..., k, th, tw, n, n)
    Y = jnp.einsum("an,...kijnb,bm->...kijam", AT, M, AT.T)
    y = _t(Y, (0, 1, 3, 2, 4)).reshape(*lead, k, th * m, tw * m)
    return y[..., :oh, :ow]


def winograd1d(x: jnp.ndarray, w: jnp.ndarray, s: int, *, m: int, r: int) -> jnp.ndarray:
    """chw -> chw. 1-D F(m, r) along rows, direct sum over kernel rows
    (paper's 'winograd-2-3' / 'winograd-2-5' style)."""
    assert s == 1
    AT, G, BT = (jnp.asarray(a, x.dtype) for a in _WINO_SETS[(m, r)])
    c, h, wd = x.shape[-3:]
    k, _, f, _ = w.shape
    n = m + r - 1
    oh, ow = h - r + 1, wd - r + 1
    tw = -(-ow // m)
    pw = (tw - 1) * m + n
    lead = x.shape[:-3]
    acc = jnp.zeros((*lead, k, oh, ow), x.dtype)
    for a in range(r):  # kernel rows handled directly
        xrow = x[..., a:a + oh, :]                     # (..., c, oh, wd)
        xrow = jnp.pad(xrow, [(0, 0)] * (x.ndim - 1) + [(0, pw - wd)])
        segs = jnp.stack([xrow[..., b:b + (tw - 1) * m + 1:m] for b in range(n)], -1)
        V = segs @ BT.T                                # (..., c, oh, tw, n)
        U = jnp.einsum("nr,kcr->kcn", G, w[:, :, a, :])
        M = jnp.einsum("kcn,...citn->...kitn", U, V)
        Y = M @ AT.T                                   # (..., k, oh, tw, m)
        acc = acc + Y.reshape(*lead, k, oh, tw * m)[..., :ow]
    return acc


# ---------------------------------------------------------------------------
# conv-1x1 family
# ---------------------------------------------------------------------------

def conv1x1(x: jnp.ndarray, w: jnp.ndarray, s: int, *, ik: bool) -> jnp.ndarray:
    g = w[:, :, 0, 0]                                  # (k, c)
    if ik:   # hwc -> hwc
        xs = x[..., ::s, ::s, :]
        return xs @ g.T
    xs = x[..., ::s, ::s]                              # chw -> chw
    c, oh, ow = xs.shape[-3:]
    y = g @ xs.reshape(*xs.shape[:-2], oh * ow)
    return y.reshape(*xs.shape[:-3], g.shape[0], oh, ow)


# ---------------------------------------------------------------------------
# MEC family (memory-efficient convolution, Cho & Brandt)
# ---------------------------------------------------------------------------

def mec_col(x: jnp.ndarray, w: jnp.ndarray, s: int) -> jnp.ndarray:
    """chw -> chw. Lower along width only (L: ow strips of f columns), then
    f partitioned small GEMMs along the height."""
    c, h, wd = x.shape[-3:]
    k, _, f, _ = w.shape
    oh, ow = out_size(h, f, s), out_size(wd, f, s)
    strips = jnp.stack([x[..., j * s:j * s + f] for j in range(ow)], -4)  # (..., ow, c, h, f)
    parts = []
    for a in range(f):
        blk = strips[..., a:a + (oh - 1) * s + 1:s, :]    # (..., ow, c, oh, f)
        parts.append(jnp.einsum("...jcib,kcb->...kij", blk, w[:, :, a, :]))
    return jnp.sum(jnp.stack(parts), axis=0)              # (..., k, oh, ow)


def mec_row(x: jnp.ndarray, w: jnp.ndarray, s: int) -> jnp.ndarray:
    """hwc -> hwc. Lower along height; partitioned GEMMs along width."""
    h, wd, c = x.shape[-3:]
    k, _, f, _ = w.shape
    oh, ow = out_size(h, f, s), out_size(wd, f, s)
    strips = jnp.stack([x[..., i * s:i * s + f, :, :] for i in range(oh)], -4)  # (..., oh, f, wd, c)
    parts = []
    for b in range(f):
        blk = strips[..., b:b + (ow - 1) * s + 1:s, :]     # (..., oh, f, ow, c)
        parts.append(jnp.einsum("...iajc,kca->...ijk", blk, w[:, :, :, b]))
    return jnp.sum(jnp.stack(parts), axis=0)               # (..., oh, ow, k)


# ---------------------------------------------------------------------------
# direct family
# ---------------------------------------------------------------------------

def direct_sum2d(x: jnp.ndarray, w: jnp.ndarray, s: int) -> jnp.ndarray:
    """chw -> chw. Offset-sliced multiply-accumulate without a GEMM
    lowering — the 'six nested loops' structure, vectorised over pixels."""
    c, h, wd = x.shape[-3:]
    k, _, f, _ = w.shape
    oh, ow = out_size(h, f, s), out_size(wd, f, s)
    acc = jnp.zeros((*x.shape[:-3], k, oh, ow), x.dtype)
    for a in range(f):
        for b in range(f):
            sl = x[..., a:a + (oh - 1) * s + 1:s, b:b + (ow - 1) * s + 1:s]
            acc = acc + jnp.einsum("...cij,kc->...kij", sl, w[:, :, a, b])
    return acc


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Primitive:
    name: str
    family: str                       # direct | im2 | kn2 | wino3 | wino5 | c1x1 | mec
    in_layout: str
    out_layout: str
    impl: Optional[Callable]          # (x, w, stride) -> y; None => simulated-only
    traits: dict

    def applicable(self, k: int, c: int, im: int, s: int, f: int) -> bool:
        if f > im:
            return False
        if self.family == "wino3":
            return f == 3 and s == 1 and im >= self.traits.get("tile_n", 4)
        if self.family == "wino5":
            return f == 5 and s == 1 and im >= self.traits.get("tile_n", 6)
        if self.family == "c1x1":
            return f == 1
        if self.family == "kn2":
            return s == 1
        return True


def _mk(name, family, inl, outl, impl, **traits) -> Primitive:
    return Primitive(name, family, inl, outl, impl, traits)


def build_registry() -> Dict[str, Primitive]:
    P: List[Primitive] = []
    # --- direct ---
    P.append(_mk("direct-sum2d", "direct", "chw", "chw", direct_sum2d))
    # --- im2col / im2row (16) ---
    for trav in ("copy", "scan"):
        scan = trav == "scan"
        P.append(_mk(f"im2col-{trav}-ab-ki", "im2", "chw", "chw",
                     partial(im2col, scan=scan, out_ik=False), trav=trav, order="ki",
                     epilogue=True))
        P.append(_mk(f"im2col-{trav}-atb-ik", "im2", "chw", "hwc",
                     partial(im2col, scan=scan, out_ik=True), trav=trav, order="ik"))
        P.append(_mk(f"im2col-{trav}-atb-ki", "im2", "chw", "chw", None, trav=trav, order="ki", t="atb"))
        P.append(_mk(f"im2col-{trav}-atbt-ik", "im2", "chw", "hwc", None, trav=trav, order="ik", t="atbt"))
        P.append(_mk(f"im2row-{trav}-ab-ik", "im2", "hwc", "hwc",
                     partial(im2row, scan=scan, out_ik=True), trav=trav, order="ik", row=True))
        P.append(_mk(f"im2row-{trav}-abt-ki", "im2", "hwc", "chw",
                     partial(im2row, scan=scan, out_ik=False), trav=trav, order="ki", row=True))
        P.append(_mk(f"im2row-{trav}-abt-ik", "im2", "hwc", "hwc", None, trav=trav, order="ik", row=True, t="abt"))
        P.append(_mk(f"im2row-{trav}-atbt-ki", "im2", "hwc", "chw", None, trav=trav, order="ki", row=True, t="atbt"))
    # --- kn2 (6) ---
    P.append(_mk("kn2row", "kn2", "chw", "chw", kn2row))
    P.append(_mk("kn2row-as", "kn2", "chw", "chw", partial(kn2row, stacked=True), variant="as"))
    P.append(_mk("kn2row-aa-ab", "kn2", "chw", "chw", None, variant="aa-ab"))
    P.append(_mk("kn2row-aa-atb", "kn2", "chw", "chw", None, variant="aa-atb"))
    P.append(_mk("kn2col", "kn2", "hwc", "hwc", kn2col))
    P.append(_mk("kn2col-as", "kn2", "hwc", "hwc", None, variant="as"))
    # --- wino3 (10) ---
    P.append(_mk("winograd-2-3", "wino3", "chw", "chw",
                 partial(winograd1d, m=2, r=3), tile_m=2, tile_n=4, oned=True))
    P.append(_mk("winograd-2-3-vec-4", "wino3", "chw", "chw", None, tile_m=2, tile_n=4, oned=True, vec=4))
    P.append(_mk("winograd-2x2-3x3", "wino3", "chw", "chw",
                 partial(winograd2d, m=2, r=3), tile_m=2, tile_n=4, epilogue=True))
    for v in (4, 8, 16):
        P.append(_mk(f"winograd-2x2-3x3-vec-{v}", "wino3", "chw", "chw", None, tile_m=2, tile_n=4, vec=v))
    P.append(_mk("winograd-4x4-3x3", "wino3", "chw", "chw",
                 partial(winograd2d, m=4, r=3), tile_m=4, tile_n=6, epilogue=True))
    for v in (4, 8, 16):
        P.append(_mk(f"winograd-4x4-3x3-vec-{v}", "wino3", "chw", "chw", None, tile_m=4, tile_n=6, vec=v))
    # --- wino5 (6) ---
    P.append(_mk("winograd-2-5", "wino5", "chw", "chw",
                 partial(winograd1d, m=2, r=5), tile_m=2, tile_n=6, oned=True))
    P.append(_mk("winograd-2-5-vec-4", "wino5", "chw", "chw", None, tile_m=2, tile_n=6, oned=True, vec=4))
    P.append(_mk("winograd-2x2-5x5", "wino5", "chw", "chw",
                 partial(winograd2d, m=2, r=5), tile_m=2, tile_n=6))
    for v in (4, 8, 16):
        P.append(_mk(f"winograd-2x2-5x5-vec-{v}", "wino5", "chw", "chw", None, tile_m=2, tile_n=6, vec=v))
    # --- conv-1x1 (8) ---
    P.append(_mk("conv-1x1-gemm-ab-ki", "c1x1", "chw", "chw", partial(conv1x1, ik=False), order="ki",
                 epilogue=True))
    P.append(_mk("conv-1x1-gemm-atb-ik", "c1x1", "hwc", "hwc", partial(conv1x1, ik=True), order="ik"))
    for nm, lay in (("ab-ik", "hwc"), ("abt-ki", "chw"), ("abt-ik", "hwc"),
                    ("atb-ki", "chw"), ("atbt-ik", "hwc"), ("atbt-ki", "chw")):
        P.append(_mk(f"conv-1x1-gemm-{nm}", "c1x1", lay, lay, None, order=nm.split("-")[1]))
    # --- mec (2) ---
    P.append(_mk("mec-col", "mec", "chw", "chw", mec_col))
    P.append(_mk("mec-row-partition", "mec", "hwc", "hwc", mec_row))

    reg = {p.name: p for p in P}
    assert len(reg) == len(P), "duplicate primitive names"
    return reg


REGISTRY: Dict[str, Primitive] = build_registry()
PRIMITIVE_NAMES: List[str] = list(REGISTRY)
RUNNABLE: List[str] = [n for n, p in REGISTRY.items() if p.impl is not None]
FAMILIES = ("direct", "im2", "kn2", "wino3", "wino5", "c1x1", "mec")


# ---------------------------------------------------------------------------
# Tile-config columns (DESIGN.md §9)
#
# A column name "prim@tile" denotes a base registry primitive executed under
# a specific kernel tile configuration (e.g. a Pallas matmul block shape):
# the performance model and the PBQP treat each (primitive, tile) pair as
# its own column, so tile selection IS primitive selection. Registry traits,
# layouts and applicability come from the base primitive; only the cost
# model (and its noise stream, keyed on the full column name) distinguishes
# tiles.
# ---------------------------------------------------------------------------

TILE_SEP = "@"


def split_tile(name: str) -> Tuple[str, Optional[str]]:
    """'prim@tile' -> (base primitive name, tile variant); plain registry
    names return (name, None)."""
    base, sep, variant = name.partition(TILE_SEP)
    return base, (variant if sep else None)


def resolve(name: str) -> Primitive:
    """Registry entry for a (possibly tile-suffixed) column name."""
    return REGISTRY[split_tile(name)[0]]


# Base primitives the variant-aware plan lowering (plan.py / primitives.
# variants) can route through a Pallas kernel. Generic matmul tilings
# ("mm-*") apply to every GEMM-shaped base: the lowering feeds the base's
# patch/pointwise/transform GEMM through kernels/matmul with that block
# config. "conv-bk*" is the fused im2col kernel's K-block — im2col-family
# (and 1x1, a degenerate f=1 im2col) only. "wino-*" tiles the Winograd
# point-GEMM — 2-D wino3 bases only. Everything else has no Pallas lowering.
MM_LOWERABLE_BASES = ("im2col-copy-ab-ki", "im2col-scan-ab-ki",
                      "conv-1x1-gemm-ab-ki",
                      "winograd-2x2-3x3", "winograd-4x4-3x3")
CONVBK_LOWERABLE_BASES = ("im2col-copy-ab-ki", "im2col-scan-ab-ki",
                          "conv-1x1-gemm-ab-ki")
WINO_LOWERABLE_BASES = ("winograd-2x2-3x3", "winograd-4x4-3x3")


def variant_compatible(base: str, variant: Optional[str]) -> bool:
    """True iff the plan lowering can execute ``base`` under tile ``variant``
    (kernel shape constraints consulted — PBQP must never select a tile the
    lowering would reject at compile time)."""
    if variant is None:
        return True
    p = REGISTRY.get(base)
    if p is None or p.impl is None:
        return False
    # kernel VARIANTS imports are function-scope: kernels/winograd/ops
    # imports _WINO_SETS from this module at import time
    if variant.startswith("mm-"):
        from repro.kernels.matmul.ops import VARIANTS
        return variant in VARIANTS and base in MM_LOWERABLE_BASES
    if variant.startswith("conv-bk"):
        from repro.kernels.im2col_gemm.ops import VARIANTS
        return variant in VARIANTS and base in CONVBK_LOWERABLE_BASES
    if variant.startswith("wino-"):
        from repro.kernels.winograd.ops import VARIANTS
        return variant in VARIANTS and base in WINO_LOWERABLE_BASES
    return False


def is_runnable(name: str) -> bool:
    """A tile column is runnable iff its base primitive is AND the lowering
    accepts the (base, variant) pair's kernel shape constraints."""
    base, variant = split_tile(name)
    if base not in REGISTRY or REGISTRY[base].impl is None:
        return False
    return variant is None or variant_compatible(base, variant)


def supports_epilogue(name: str) -> bool:
    """Whether the column's base primitive advertises fused elementwise
    epilogues (bias / ReLU / residual add applied before HBM writeback)."""
    base, _ = split_tile(name)
    p = REGISTRY.get(base)
    return bool(p is not None and p.traits.get("epilogue", False))


def tile_columns(bases: Sequence[str], variants: Sequence[str]) -> List[str]:
    """The (base × tile-variant) cross product as column names, filtered to
    pairs the lowering can actually execute."""
    return [f"{b}{TILE_SEP}{v}" for b in bases for v in variants
            if variant_compatible(b, v)]


def family_of(name: str) -> str:
    return resolve(name).family


# ---------------------------------------------------------------------------
# Compiled per-column trait arrays (batched estimation, DESIGN.md §2.4)
# ---------------------------------------------------------------------------

# transpose-variant codes shared with the simulators: index into this tuple
T_VARIANTS: Tuple[Optional[str], ...] = (None, "atb", "abt", "atbt")


def name_hash64(s: str) -> int:
    """Stable 64-bit key for a registry/DLT name (noise stream seeding)."""
    return int.from_bytes(hashlib.sha256(s.encode()).digest()[:8], "big")


@dataclasses.dataclass(frozen=True)
class ColumnTraits:
    """Registry traits of a column list, pre-compiled into numpy arrays so the
    simulator time models can broadcast over (configs × columns) at once."""
    names: Tuple[str, ...]
    fam: np.ndarray            # (P,) int8 index into FAMILIES
    vec: np.ndarray            # (P,) float64 SIMD lanes, 0.0 = unspecified
    t_idx: np.ndarray          # (P,) int8 index into T_VARIANTS
    scan: np.ndarray           # (P,) bool, trav == "scan"
    order_ki: np.ndarray       # (P,) bool, order == "ki"
    tile_m: np.ndarray         # (P,) int64 Winograd output tile, 0 = n/a
    tile_n: np.ndarray         # (P,) int64 Winograd input tile, 0 = n/a
    oned: np.ndarray           # (P,) bool, 1-D Winograd
    variant_as: np.ndarray     # (P,) bool, kn2 "-as" stacked accumulation
    in_layout: np.ndarray      # (P,) int8 index into layouts.LAYOUTS
    out_layout: np.ndarray     # (P,) int8 index into layouts.LAYOUTS
    key: np.ndarray            # (P,) uint64 per-column noise-stream key
    epilogue: np.ndarray       # (P,) bool, fused elementwise epilogue support

    def applicable_mask(self, k: np.ndarray, c: np.ndarray, im: np.ndarray,
                        s: np.ndarray, f: np.ndarray) -> np.ndarray:
        """(L, P) bool mask mirroring ``Primitive.applicable`` — vectorised
        over (L,) config component arrays and the compiled columns."""
        k, c, im, s, f = (np.asarray(a).reshape(-1, 1) for a in (k, c, im, s, f))
        fam = self.fam[None, :]
        wino = (fam == FAMILIES.index("wino3")) | (fam == FAMILIES.index("wino5"))
        wino_f = np.where(self.fam == FAMILIES.index("wino5"), 5, 3)[None, :]
        return ((f <= im)
                & np.where(wino, (f == wino_f) & (s == 1)
                           & (im >= self.tile_n[None, :]), True)
                & np.where(fam == FAMILIES.index("c1x1"), f == 1, True)
                & np.where(fam == FAMILIES.index("kn2"), s == 1, True))


@lru_cache(maxsize=256)
def compile_traits(names: Tuple[str, ...]) -> ColumnTraits:
    # tile columns ("prim@tile") compile to their BASE primitive's traits —
    # layouts/applicability are tile-invariant — but keep a per-column noise
    # key from the full name so every tile gets its own deterministic stream
    prims = [resolve(n) for n in names]
    t = [p.traits for p in prims]
    return ColumnTraits(
        names=tuple(names),
        fam=np.array([FAMILIES.index(p.family) for p in prims], np.int8),
        vec=np.array([float(x.get("vec", 0) or 0) for x in t], np.float64),
        t_idx=np.array([T_VARIANTS.index(x.get("t")) for x in t], np.int8),
        scan=np.array([x.get("trav") == "scan" for x in t], bool),
        order_ki=np.array([x.get("order") == "ki" for x in t], bool),
        tile_m=np.array([int(x.get("tile_m", 0)) for x in t], np.int64),
        # same defaults as Primitive.applicable: wino3 -> 4, wino5 -> 6
        tile_n=np.array([int(x.get("tile_n", {"wino3": 4, "wino5": 6}.get(p.family, 0)))
                         for p, x in zip(prims, t)], np.int64),
        oned=np.array([bool(x.get("oned", False)) for x in t], bool),
        variant_as=np.array([str(x.get("variant", "")).startswith("as") for x in t], bool),
        in_layout=np.array([L.LAYOUTS.index(p.in_layout) for p in prims], np.int8),
        out_layout=np.array([L.LAYOUTS.index(p.out_layout) for p in prims], np.int8),
        key=np.array([name_hash64(n) for n in names], np.uint64),
        epilogue=np.array([bool(x.get("epilogue", False)) for x in t], bool),
    )


def run_primitive(name: str, x_chw: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Run primitive ``name`` on a chw image, returning chw output —
    layout conversions applied around the primitive's native layouts.
    (Used by tests and the real-CPU executor; the executor also accounts
    for the DLT costs explicitly.) Tile columns run their base impl — on
    this host's XLA path the tile config is a Pallas dispatch hint, not a
    different algorithm."""
    p = resolve(name)
    if p.impl is None:
        raise ValueError(f"{name} is a simulated-only primitive")
    x = L.from_chw(x_chw, p.in_layout)
    y = p.impl(x, w, stride)
    return L.to_chw(y, p.out_layout)


# ---------------------------------------------------------------------------
# Batched entry points (plan compiler, DESIGN.md §6)
# ---------------------------------------------------------------------------

def batch_impl(prim: Primitive) -> Callable:
    """Batched callable ``(x (n, *in_layout), w, stride) -> (n, *out_layout)``.

    Every built-in runnable impl is rank-polymorphic over leading batch axes,
    so the single-image impl *is* the batched impl; a primitive whose traits
    set ``batch=False`` (e.g. an impl with hard-coded rank-3 indexing) falls
    back to ``jax.vmap`` over the single-image call.
    """
    if prim.impl is None:
        raise ValueError(f"{prim.name} is a simulated-only primitive")
    if prim.traits.get("batch", True):
        return prim.impl
    return jax.vmap(prim.impl, in_axes=(0, None, None))


def run_primitive_batch(name: str, x_chw: jnp.ndarray, w: jnp.ndarray,
                        stride: int) -> jnp.ndarray:
    """Batched ``run_primitive``: (n, c, im, im) chw in, (n, k, oh, ow) out."""
    p = resolve(name)
    fn = batch_impl(p)
    y = fn(L.from_chw(x_chw, p.in_layout), w, stride)
    return L.to_chw(y, p.out_layout)


def reference_conv_batch(x_chw: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Batched oracle: XLA's native convolution, NCHW batch."""
    return jax.lax.conv_general_dilated(
        x_chw, w, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
