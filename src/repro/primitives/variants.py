"""Variant-aware conv execution: route a (base primitive, tile variant)
column through the matching Pallas kernel entry point (DESIGN.md §13).

Until PR 9 a tile column like ``im2col-copy-ab-ki@mm-256x128x128`` priced
differently in the perf model but executed through the base XLA impl — the
PBQP-selected tile never changed the emitted kernel. ``conv_variant_call``
closes that gap:

* ``mm-*``   — the base's GEMM stage runs through ``kernels/matmul`` with
  that (bm, bk, bn) block config. For im2col bases the patch matrix is
  lowered at the jnp level and the batch is folded into the GEMM N axis
  (one kernel launch, weights shared); for 1x1 the pointwise GEMM maps
  directly; for 2-D Winograd bases the blocks map onto the point-GEMM's
  (K, C, T) tiling.
* ``conv-bk*`` — the fused im2col+GEMM kernel (patches built in VMEM) with
  that K-block, batch as a leading grid dimension.
* ``wino-*`` — the Winograd point-GEMM with that (K, T) tiling.

Compatibility is enforced by ``conv.variant_compatible`` (consulted by
``is_runnable``/``tile_columns``), so selection can never produce a pair
this module rejects. All paths accept the fused elementwise epilogue
(bias -> residual -> ReLU); semantics are identical to the base impl plus
the epilogue ops — only the schedule differs (DESIGN.md §13.1).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.primitives.conv import (Primitive, _patches_copy_chw,
                                   _patches_scan_chw, _w_mat,
                                   variant_compatible)


def _gemm_chw(wm: jnp.ndarray, x2: jnp.ndarray, variant: str, bias, res,
              relu: bool, N: int, K: int, oh: int, ow: int) -> jnp.ndarray:
    """Shared mm-* tail: wm (K, R) @ x2 (R, N*oh*ow) through the tiled
    Pallas matmul, epilogue fused, result reshaped back to (N, K, oh, ow)."""
    from repro.kernels.matmul.ops import matmul_op
    res2 = None
    if res is not None:
        res2 = res.transpose(1, 0, 2, 3).reshape(K, N * oh * ow)
    y2 = matmul_op(wm, x2, variant=variant, bias=bias, residual=res2,
                   relu=relu)                                 # (K, N*oh*ow)
    return y2.reshape(K, N, oh, ow).transpose(1, 0, 2, 3)


def conv_variant_call(prim: Primitive, variant: str, x: jnp.ndarray,
                      w: jnp.ndarray, stride: int, *,
                      bias: Optional[jnp.ndarray] = None,
                      residual: Optional[jnp.ndarray] = None,
                      relu: bool = False) -> jnp.ndarray:
    """Run chw conv ``prim`` under Pallas tile ``variant``.

    ``x`` is (C, H, W) or (N, C, H, W); ``w`` is (K, C, f, f). ``bias`` is
    (K,); ``residual`` must already be cropped to the conv's output shape.
    Numerics match ``prim.impl(x, w, stride)`` plus the epilogue ops.
    """
    if not variant_compatible(prim.name, variant):
        raise ValueError(f"variant {variant!r} cannot lower through "
                         f"{prim.name!r}")
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
        if residual is not None:
            residual = residual[None]
    N, C, H, W = x.shape
    K, _, f, _ = w.shape

    if variant.startswith("conv-bk"):
        from repro.kernels.im2col_gemm.ops import conv_im2col_batch_op
        y = conv_im2col_batch_op(x, w, stride, variant=variant, bias=bias,
                                 residual=residual, relu=relu)
    elif variant.startswith("wino-"):
        from repro.kernels.winograd.ops import VARIANTS, winograd_conv_batch
        bk, bt = VARIANTS[variant]
        y = winograd_conv_batch(x, w, m=int(prim.traits["tile_m"]), bk=bk,
                                bt=bt, bias=bias, residual=residual,
                                relu=relu)
    elif variant.startswith("mm-"):
        if prim.family == "wino3":
            from repro.kernels.matmul.ops import VARIANTS
            from repro.kernels.winograd.ops import winograd_conv_batch
            bm, bk, bn = VARIANTS[variant]
            y = winograd_conv_batch(x, w, m=int(prim.traits["tile_m"]),
                                    bk=bm, bc=bk, bt=bn, bias=bias,
                                    residual=residual, relu=relu)
        elif prim.family == "c1x1":
            xs = x[..., ::stride, ::stride]
            oh, ow = xs.shape[-2:]
            x2 = xs.reshape(N, C, oh * ow).transpose(1, 0, 2).reshape(
                C, N * oh * ow)
            y = _gemm_chw(w[:, :, 0, 0], x2, variant, bias, residual, relu,
                          N, K, oh, ow)
        else:                                     # im2 family, chw/ki
            patches = (_patches_scan_chw if prim.traits.get("trav") == "scan"
                       else _patches_copy_chw)
            pat = patches(x, f, stride)           # (N, C*f*f, oh*ow)
            oh = (H - f) // stride + 1
            ow = (W - f) // stride + 1
            x2 = pat.transpose(1, 0, 2).reshape(C * f * f, N * oh * ow)
            y = _gemm_chw(_w_mat(w), x2, variant, bias, residual, relu,
                          N, K, oh, ow)
    else:
        raise ValueError(f"unknown tile variant {variant!r}")
    return y[0] if squeeze else y
