"""Partitioned Boolean Quadratic Programming solver (paper §2.1, [9]).

Primitive selection is modelled as a PBQP instance: each layer is a node with
a cost vector over primitives (``inf`` = inapplicable), each data-dependence
between layers is an edge with a cost matrix over (producer primitive,
consumer primitive) pairs — the data-layout-transformation times.

We implement the Hames-Scholz reduction solver:
  R0  — isolated node: pick argmin.
  RI  — degree-1 node: fold into neighbour's vector.
  RII — degree-2 node: fold into an edge between its two neighbours
        (parallel edges merge by matrix addition, so series-parallel
        graphs — chains, VGG/ResNet trunks, GoogLeNet inception diamonds —
        reduce exactly).
  RN  — heuristic for irreducible degree-≥3 nodes; when used the solution
        is flagged ``optimal=False``.

A brute-force oracle (`brute_force`) is provided for property tests.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

Node = Hashable


@dataclasses.dataclass
class Solution:
    assignment: Dict[Node, int]
    cost: float
    optimal: bool

    def labelled(self, graph: "PBQPGraph") -> Dict[Node, str]:
        return {n: graph.labels[n][i] if graph.labels.get(n) else str(i)
                for n, i in self.assignment.items()}


class PBQPGraph:
    """Undirected multigraph; parallel edges merge by addition."""

    def __init__(self) -> None:
        self.costs: Dict[Node, np.ndarray] = {}
        self.adj: Dict[Node, Dict[Node, np.ndarray]] = {}
        self.labels: Dict[Node, Optional[List[str]]] = {}

    # -- construction ------------------------------------------------------
    def add_node(self, n: Node, costs: np.ndarray, labels: Optional[Sequence[str]] = None) -> None:
        costs = np.asarray(costs, np.float64)
        if costs.ndim != 1:
            raise ValueError("node costs must be a vector")
        if n in self.costs:
            raise ValueError(f"duplicate node {n!r}")
        if not np.isfinite(costs).any():
            raise ValueError(f"node {n!r} has no applicable choice (all costs inf)")
        self.costs[n] = costs
        self.adj[n] = {}
        self.labels[n] = list(labels) if labels is not None else None

    def add_edge(self, u: Node, v: Node, matrix: np.ndarray) -> None:
        if u == v:
            # Self-loop: diagonal folds into the node vector.
            m = np.asarray(matrix, np.float64)
            self.costs[u] = self.costs[u] + np.diag(m)
            return
        m = np.asarray(matrix, np.float64)
        if m.shape != (len(self.costs[u]), len(self.costs[v])):
            raise ValueError(f"edge {u!r}-{v!r} matrix shape {m.shape} != "
                             f"({len(self.costs[u])}, {len(self.costs[v])})")
        if v in self.adj[u]:
            self.adj[u][v] = self.adj[u][v] + m
            self.adj[v][u] = self.adj[u][v].T
        else:
            self.adj[u][v] = m.copy()
            self.adj[v][u] = self.adj[u][v].T

    def copy(self) -> "PBQPGraph":
        g = PBQPGraph()
        g.costs = {n: c.copy() for n, c in self.costs.items()}
        g.adj = {n: {v: m.copy() for v, m in nb.items()} for n, nb in self.adj.items()}
        g.labels = {n: (list(l) if l else None) for n, l in self.labels.items()}
        return g

    @property
    def nodes(self) -> List[Node]:
        return list(self.costs)


def _remove_node(g: PBQPGraph, n: Node) -> None:
    for v in list(g.adj[n]):
        del g.adj[v][n]
    del g.adj[n]
    del g.costs[n]


def solve(graph: PBQPGraph) -> Solution:
    g = graph.copy()
    # Reduction stack entries:
    #   ("R0", node, None)
    #   ("RI", node, neighbour, backptr[sv] -> su)
    #   ("RII", node, (v, w), backptr[sv, sw] -> su)
    #   ("RN", node, chosen_index)
    stack: List[tuple] = []
    optimal = True

    # Degree-bucketed worklist: buckets[d] is an insertion-ordered set of the
    # nodes of current degree d, so picking the next reduction is O(1)
    # amortised instead of a scan over all remaining nodes per round.
    deg: Dict[Node, int] = {n: len(g.adj[n]) for n in g.costs}
    buckets: Dict[int, Dict[Node, None]] = {}
    for n, d in deg.items():
        buckets.setdefault(d, {})[n] = None

    def _requeue(n: Node) -> None:
        d = len(g.adj[n])
        if d == deg[n]:
            return
        b = buckets[deg[n]]
        del b[n]
        if not b:
            del buckets[deg[n]]
        deg[n] = d
        buckets.setdefault(d, {})[n] = None

    def _pop(n: Node) -> None:
        b = buckets[deg[n]]
        del b[n]
        if not b:
            del buckets[deg[n]]
        del deg[n]
        neighbours = list(g.adj[n])
        _remove_node(g, n)
        for v in neighbours:
            _requeue(v)

    def _take(d: int) -> Optional[Node]:
        b = buckets.get(d)
        return next(iter(b)) if b else None

    while g.costs:
        # Prefer the cheapest applicable reduction each round.
        n0 = _take(0)
        if n0 is not None:
            # Record the *reduced* vector: later folds only add to nodes
            # still present, so at removal time this vector is final.
            stack.append(("R0", n0, int(np.argmin(g.costs[n0])), None))
            _pop(n0)
            continue
        n1 = _take(1)
        if n1 is not None:
            (v, m), = g.adj[n1].items()
            # fold: cost_v[sv] += min_su cost_u[su] + m[su, sv]
            tot = g.costs[n1][:, None] + m          # (su, sv)
            back = np.argmin(tot, axis=0)
            g.costs[v] = g.costs[v] + tot[back, np.arange(tot.shape[1])]
            stack.append(("RI", n1, v, back))
            _pop(n1)
            continue
        n2 = _take(2)
        if n2 is not None:
            (v, mv), (w, mw) = g.adj[n2].items()
            # D[sv, sw] = min_su cost_u[su] + mv[su, sv] + mw[su, sw]
            tot = (g.costs[n2][:, None, None] + mv[:, :, None] + mw[:, None, :])
            back = np.argmin(tot, axis=0)           # (sv, sw)
            d = np.min(tot, axis=0)
            stack.append(("RII", n2, (v, w), back))
            _pop(n2)
            # merge with existing v-w edge if any (parallel-edge addition)
            if w in g.adj[v]:
                g.adj[v][w] = g.adj[v][w] + d
                g.adj[w][v] = g.adj[v][w].T
            else:
                g.adj[v][w] = d
                g.adj[w][v] = d.T
            _requeue(v)
            _requeue(w)
            continue
        # RN heuristic: pick max-degree node, choose the selection that
        # minimises node cost + sum of row minima over incident edges, then
        # fold the chosen row into each neighbour's vector.
        optimal = False
        n = next(iter(buckets[max(buckets)]))
        score = g.costs[n].copy()
        for v, m in g.adj[n].items():
            score = score + np.min(m + g.costs[v][None, :], axis=1)
        su = int(np.argmin(score))
        for v, m in list(g.adj[n].items()):
            g.costs[v] = g.costs[v] + m[su]
        stack.append(("RN", n, su, None))
        _pop(n)

    # Back-substitution in reverse reduction order.
    assignment: Dict[Node, int] = {}
    for kind, n, aux, back in reversed(stack):
        if kind == "R0":
            assignment[n] = aux
        elif kind == "RI":
            assignment[n] = int(back[assignment[aux]])
        elif kind == "RII":
            v, w = aux
            assignment[n] = int(back[assignment[v], assignment[w]])
        elif kind == "RN":
            assignment[n] = int(aux)

    return Solution(assignment, evaluate(graph, assignment), optimal)


def evaluate(graph: PBQPGraph, assignment: Dict[Node, int]) -> float:
    cost = 0.0
    for n, c in graph.costs.items():
        cost += c[assignment[n]]
    seen = set()
    for u, nb in graph.adj.items():
        for v, m in nb.items():
            if (v, u) in seen:
                continue
            seen.add((u, v))
            cost += m[assignment[u], assignment[v]]
    return float(cost)


def brute_force(graph: PBQPGraph) -> Solution:
    nodes = graph.nodes
    best_cost, best_asg = np.inf, None
    for combo in itertools.product(*(range(len(graph.costs[n])) for n in nodes)):
        asg = dict(zip(nodes, combo))
        c = evaluate(graph, asg)
        if c < best_cost:
            best_cost, best_asg = c, asg
    return Solution(best_asg, float(best_cost), True)
