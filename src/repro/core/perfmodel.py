"""Performance models (paper §3.3, Fig 3): NN1 (per-primitive MLP), NN2
(shared MLP over all primitives), and a linear-regression baseline.

Pure JAX. The NN2 masked-MSE loss implements the paper's treatment of
undefined runtimes: entries where a primitive is inapplicable are NaN in the
label matrix; their squared error and gradient are exactly zero.

The public interface is numpy-in / numpy-out so the optimisation pipeline
(Fig 2) can batch all layer configurations of a CNN in one call — predicted
cost of optimising VGG-19 is milliseconds, the paper's Table 4 claim.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.normalize import LogStandardizer, mdrae
from repro.train import optim as optim_lib


# ---------------------------------------------------------------------------
# MLP core
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, sizes: Sequence[int], dtype=jnp.float32) -> list:
    """He-initialised fully connected network ``sizes[0] -> ... -> sizes[-1]``."""
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, (fan_in, fan_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (fan_in, fan_out), dtype) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((fan_out,), dtype)
        params.append({"w": w, "b": b})
    return params


def mlp_apply(params: list, x: jnp.ndarray) -> jnp.ndarray:
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def masked_mse(params: list, x: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """MSE over defined entries only. ``y`` must already have NaNs replaced by
    zeros (any finite value works; the mask kills their contribution AND their
    gradient, exactly as the paper's masking does)."""
    pred = mlp_apply(params, x)
    se = jnp.square(pred - y) * mask
    return jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Training loop with early stopping (paper Table 3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainResult:
    params: list
    train_losses: list
    val_losses: list
    best_val: float
    iterations: int
    seconds: float


def train_mlp(key: jax.Array,
              sizes: Sequence[int],
              x_train: np.ndarray, y_train: np.ndarray,
              x_val: np.ndarray, y_val: np.ndarray,
              lr: float = 1e-3,
              weight_decay: float = 1e-5,
              batch_size: int = 1024,
              patience: int = 250,
              max_iters: int = 20000,
              init_params: Optional[list] = None,
              eval_every: int = 20) -> TrainResult:
    """Adam + early stopping ("halt when validation has not improved for 250
    iterations", paper Table 3). ``init_params`` given => fine-tuning (the
    transfer-learning path; paper lowers LR by 10x for fine-tuning — callers
    pass the lowered lr)."""
    t0 = time.perf_counter()
    mask_train = np.isfinite(y_train).astype(np.float32)
    mask_val = np.isfinite(y_val).astype(np.float32)
    y_train = np.nan_to_num(y_train, nan=0.0).astype(np.float32)
    y_val = np.nan_to_num(y_val, nan=0.0).astype(np.float32)
    x_train = x_train.astype(np.float32)
    x_val = x_val.astype(np.float32)

    params = init_params if init_params is not None else init_mlp(key, sizes)
    opt = optim_lib.adamw(lr, weight_decay=weight_decay)
    opt_state = opt.init(params)

    n = x_train.shape[0]
    bs = min(batch_size, n)

    @jax.jit
    def step(params, opt_state, xb, yb, mb):
        loss, grads = jax.value_and_grad(masked_mse)(params, xb, yb, mb)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    @jax.jit
    def val_loss_fn(params):
        return masked_mse(params, x_val, y_val, mask_val)

    rng = np.random.default_rng(0)
    best_val, best_params, best_iter = np.inf, params, 0
    train_losses, val_losses = [], []
    it = 0
    while it < max_iters:
        idx = rng.integers(0, n, size=bs)
        params, opt_state, loss = step(params, opt_state, x_train[idx], y_train[idx], mask_train[idx])
        it += 1
        if it % eval_every == 0 or it == 1:
            vl = float(val_loss_fn(params))
            train_losses.append(float(loss))
            val_losses.append(vl)
            if vl < best_val - 1e-7:
                best_val, best_params, best_iter = vl, params, it
            elif it - best_iter > patience:
                break
    return TrainResult(best_params, train_losses, val_losses, float(best_val),
                       it, time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# High-level performance models
# ---------------------------------------------------------------------------

# Paper Table 3 architectures. Input dim is 5 = (k, c, im, s, f) for
# primitives and 2 = (c, im) for data-layout transformations.
NN1_HIDDEN = (16, 64, 64, 16)
NN2_HIDDEN = (128, 512, 512, 128)


@dataclasses.dataclass
class PerfModel:
    """A trained performance estimator: features -> runtimes (seconds).

    ``kind`` in {"nn1", "nn2", "lin"}. NN1 is an ensemble (one MLP per output
    column); NN2 and Lin are single models over all columns.
    """

    kind: str
    in_norm: LogStandardizer
    out_norm: LogStandardizer
    params: list              # nn2/lin: one params list; nn1: list per column
    n_outputs: int
    columns: Sequence[str]
    train_seconds: float = 0.0

    # -- prediction --------------------------------------------------------
    def predict(self, feats: np.ndarray) -> np.ndarray:
        """(N, F) raw features -> (N, n_outputs) runtimes in seconds."""
        feats = np.atleast_2d(np.asarray(feats, np.float64))
        xt = jnp.asarray(self.in_norm.transform(feats))
        if self.kind == "nn1":
            cols = [mlp_apply(p, xt) for p in self.params]
            yt = jnp.concatenate(cols, axis=1)
        else:
            yt = mlp_apply(self.params, xt)
        return self.out_norm.inverse(np.asarray(yt))

    def predict_per_image(self, feats: np.ndarray,
                          column: Optional[str] = None, *,
                          bucket: Optional[int] = None,
                          head: Optional["BucketScaleHead"] = None) -> np.ndarray:
        """Per-image predicted seconds for (config, primitive) pairs, made
        batch-shape-aware: ``head`` is a :class:`BucketScaleHead` fitted from
        served traffic and ``bucket`` the dispatch's pow2 batch bucket — the
        base prediction is multiplied by the head's relative scale at that
        bucket. Without a head (or bucket) this is the plain linear
        per-image prediction. ``column`` selects one primitive; otherwise
        all ``n_outputs`` columns are returned."""
        pred = self.predict(feats)
        if column is not None:
            j = list(self.columns).index(column)
            pred = pred[:, j]
        if head is not None and bucket is not None:
            pred = pred * head.scale(bucket)
        return pred

    def mdrae(self, feats: np.ndarray, runtimes: np.ndarray) -> float:
        return mdrae(self.predict(feats), runtimes)

    def mdrae_per_column(self, feats: np.ndarray, runtimes: np.ndarray) -> np.ndarray:
        from repro.core.normalize import mdrae_per_column
        return mdrae_per_column(self.predict(feats), runtimes)

    def fingerprint(self) -> str:
        """Content hash of the serialised model (header + parameter bytes) —
        the identity used for artifact keying (repro.service.artifacts).
        Wall-clock provenance (train_seconds) is excluded: two models with
        identical parameters must hash identically."""
        import hashlib
        state = self.to_state()
        header = {k: v for k, v in state["header"].items()
                  if k != "train_seconds"}
        h = hashlib.sha256(json.dumps(header, sort_keys=True).encode())
        for name in sorted(state["arrays"]):
            h.update(name.encode())
            h.update(np.ascontiguousarray(state["arrays"][name]).tobytes())
        return h.hexdigest()[:16]

    def subset_columns(self, columns: Sequence[str], *,
                       base_of: Optional[Callable[[str], str]] = None) -> "PerfModel":
        """A real PerfModel predicting only ``columns`` (same kind, sliced
        output layer / ensemble / normalizer) — used to transfer a wide base
        model onto a platform that profiles fewer primitives (e.g. the
        49-column simulator model onto the host's runnable subset).

        ``base_of`` maps a requested column the model does not have onto one
        it does — the tile-column transfer path: a base model over plain
        primitives expands onto a platform's (primitive, tile-config)
        columns by duplicating each base head per tile (DESIGN.md §9).
        Output column names are the *requested* names; duplicate head
        indices are allowed."""
        model_cols = list(self.columns)
        pos = {c: j for j, c in enumerate(model_cols)}

        def lookup(c: str) -> int:
            if c in pos:
                return pos[c]
            if base_of is not None:
                b = base_of(c)
                if b in pos:
                    return pos[b]
            return -1

        idx_list = [lookup(c) for c in columns]
        missing = [c for c, j in zip(columns, idx_list) if j < 0]
        if missing:
            raise ValueError(f"model has no columns {missing}")
        idx = np.asarray(idx_list)
        if list(columns) == model_cols:
            return self

        out_d = self.out_norm.to_dict()
        for k in ("mean", "std"):
            if out_d.get(k) is not None:
                out_d[k] = np.asarray(out_d[k])[idx].tolist()
        out_norm = type(self.out_norm).from_dict(out_d)

        if isinstance(self, FactorCorrectedModel):
            return FactorCorrectedModel(
                base=self.base.subset_columns(columns, base_of=base_of),
                log_factor=np.asarray(self.log_factor)[idx])
        if self.kind == "nn1":
            params = [self.params[j] for j in idx]
        else:
            head = self.params[-1]
            params = list(self.params[:-1]) + [
                {"w": head["w"][:, idx], "b": head["b"][idx]}]
        return PerfModel(kind=self.kind, in_norm=self.in_norm,
                         out_norm=out_norm, params=params,
                         n_outputs=len(idx), columns=list(columns),
                         train_seconds=self.train_seconds)

    # -- (de)serialization -------------------------------------------------
    #
    # On-disk format: a single ``.npz`` whose ``__header__`` entry is a JSON
    # document (kind, columns, normalizers, format version) and whose other
    # entries are the parameter arrays under structural names:
    #   nn2/lin:    ``l{i}.w`` / ``l{i}.b``          (layer i)
    #   nn1:        ``c{j}.l{i}.w`` / ``c{j}.l{i}.b`` (column j, layer i)
    #   factor-*:   base arrays plus ``log_factor``
    # No pickle anywhere: the file is portable, inspectable, and cannot
    # execute code on load.

    _FORMAT = "perfmodel-npz-v1"

    def _named_arrays(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        kind = self.kind
        if kind.startswith("factor-"):
            kind = kind[len("factor-"):]
        if kind == "nn1":
            for j, col_params in enumerate(self.params):
                for i, layer in enumerate(col_params):
                    out[f"c{j}.l{i}.w"] = np.asarray(layer["w"])
                    out[f"c{j}.l{i}.b"] = np.asarray(layer["b"])
        else:
            for i, layer in enumerate(self.params):
                out[f"l{i}.w"] = np.asarray(layer["w"])
                out[f"l{i}.b"] = np.asarray(layer["b"])
        return out

    def to_state(self) -> dict:
        """JSON header + named arrays (the save() payload, exposed for
        fingerprinting and tests)."""
        header = {
            "format": self._FORMAT,
            "kind": self.kind,
            "n_outputs": int(self.n_outputs),
            "columns": list(self.columns),
            "in_norm": self.in_norm.to_dict(),
            "out_norm": self.out_norm.to_dict(),
            "train_seconds": float(self.train_seconds),
        }
        arrays = self._named_arrays()
        if isinstance(self, FactorCorrectedModel):
            arrays["log_factor"] = np.asarray(self.log_factor, np.float64)
        return {"header": header, "arrays": arrays}

    def save(self, path: str) -> None:
        state = self.to_state()
        payload = dict(state["arrays"])
        payload["__header__"] = np.frombuffer(
            json.dumps(state["header"], sort_keys=True).encode(), np.uint8)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)

    @staticmethod
    def _params_from_arrays(kind: str, data: Dict[str, np.ndarray]) -> list:
        def layer_count(prefix: str) -> int:
            i = 0
            while f"{prefix}l{i}.w" in data:
                i += 1
            return i

        if kind == "nn1":
            params, j = [], 0
            while f"c{j}.l0.w" in data:
                params.append([{"w": jnp.asarray(data[f"c{j}.l{i}.w"]),
                                "b": jnp.asarray(data[f"c{j}.l{i}.b"])}
                               for i in range(layer_count(f"c{j}."))])
                j += 1
            return params
        return [{"w": jnp.asarray(data[f"l{i}.w"]),
                 "b": jnp.asarray(data[f"l{i}.b"])}
                for i in range(layer_count(""))]

    @classmethod
    def load(cls, path: str) -> "PerfModel":
        with np.load(path) as z:
            data = {k: z[k] for k in z.files}
        header = json.loads(bytes(data.pop("__header__")).decode())
        if header.get("format") != cls._FORMAT:
            raise ValueError(f"{path}: unsupported perf-model format "
                             f"{header.get('format')!r}")
        kind = header["kind"]
        base_kind = kind[len("factor-"):] if kind.startswith("factor-") else kind
        model = PerfModel(
            kind=base_kind,
            in_norm=LogStandardizer.from_dict(header["in_norm"]),
            out_norm=LogStandardizer.from_dict(header["out_norm"]),
            params=cls._params_from_arrays(base_kind, data),
            n_outputs=header["n_outputs"],
            columns=header["columns"],
            train_seconds=header.get("train_seconds", 0.0))
        if kind.startswith("factor-"):
            # a factor-corrected model round-trips as itself, correction and
            # all (the old pickle path silently dropped log_factor)
            model = FactorCorrectedModel(base=model,
                                         log_factor=data["log_factor"])
        return model


def _prep(feats, runtimes, in_norm=None, out_norm=None):
    feats = np.asarray(feats, np.float64)
    runtimes = np.asarray(runtimes, np.float64)
    if in_norm is None:
        in_norm = LogStandardizer(log=True).fit(feats)
    if out_norm is None:
        out_norm = LogStandardizer(log=True).fit(runtimes)
    return in_norm, out_norm, in_norm.transform(feats), out_norm.transform(runtimes)


def fit_perf_model(kind: str,
                   feats_train: np.ndarray, runtimes_train: np.ndarray,
                   feats_val: np.ndarray, runtimes_val: np.ndarray,
                   columns: Optional[Sequence[str]] = None,
                   seed: int = 0,
                   base: Optional[PerfModel] = None,
                   lr: Optional[float] = None,
                   max_iters: int = 20000,
                   patience: int = 250) -> PerfModel:
    """Train a performance model of ``kind`` in {"lin", "nn1", "nn2"}.

    ``base`` given => transfer learning: reuse base normalizers and start
    from base params with LR lowered 10x (paper §4.4) unless ``lr`` is set.
    """
    t0 = time.perf_counter()
    n_out = np.asarray(runtimes_train).shape[1]
    columns = list(columns) if columns is not None else [f"p{i}" for i in range(n_out)]
    in_norm = base.in_norm if base is not None else None
    out_norm = base.out_norm if base is not None else None
    in_norm, out_norm, xt, yt = _prep(feats_train, runtimes_train, in_norm, out_norm)
    xv = in_norm.transform(feats_val)
    yv = out_norm.transform(runtimes_val)
    key = jax.random.PRNGKey(seed)

    if kind == "lin":
        # Closed-form ridge per column on defined rows (baseline model).
        lam = 1e-6
        X = np.concatenate([xt, np.ones((xt.shape[0], 1), np.float32)], axis=1)
        W = np.zeros((X.shape[1], n_out), np.float64)
        for j in range(n_out):
            m = np.isfinite(yt[:, j])
            if m.sum() < X.shape[1]:
                continue
            A = X[m].astype(np.float64)
            b = yt[m, j].astype(np.float64)
            W[:, j] = np.linalg.solve(A.T @ A + lam * np.eye(A.shape[1]), A.T @ b)
        params = [{"w": jnp.asarray(W[:-1], jnp.float32), "b": jnp.asarray(W[-1], jnp.float32)}]
        return PerfModel("lin", in_norm, out_norm, params, n_out, columns,
                         train_seconds=time.perf_counter() - t0)

    if kind == "nn2":
        sizes = (xt.shape[1],) + NN2_HIDDEN + (n_out,)
        lr_eff = lr if lr is not None else (1e-4 if base is not None else 1e-3)
        res = train_mlp(key, sizes, xt, yt, xv, yv, lr=lr_eff, weight_decay=1e-5,
                        init_params=None if base is None else base.params,
                        max_iters=max_iters, patience=patience)
        return PerfModel("nn2", in_norm, out_norm, res.params, n_out, columns,
                         train_seconds=time.perf_counter() - t0)

    if kind == "nn1":
        # One small MLP per output column; single hyper-parameter set across
        # all models (paper §4.2). Base model => per-column fine-tune.
        sizes = (xt.shape[1],) + NN1_HIDDEN + (1,)
        lr_eff = lr if lr is not None else (3e-4 if base is not None else 3e-3)
        params = []
        keys = jax.random.split(key, n_out)
        for j in range(n_out):
            yj = yt[:, j:j + 1]
            yvj = yv[:, j:j + 1]
            m = np.isfinite(yj[:, 0])
            if m.sum() < 8:  # too few points: fall back to mean predictor
                params.append(init_mlp(keys[j], sizes))
                continue
            init_p = base.params[j] if base is not None else None
            res = train_mlp(keys[j], sizes, xt[m], yj[m], xv[np.isfinite(yvj[:, 0])],
                            yvj[np.isfinite(yvj[:, 0])], lr=lr_eff, weight_decay=0.0,
                            init_params=init_p, max_iters=max_iters, patience=patience)
            params.append(res.params)
        return PerfModel("nn1", in_norm, out_norm, params, n_out, columns,
                         train_seconds=time.perf_counter() - t0)

    raise ValueError(f"unknown perf model kind {kind!r}")


# ---------------------------------------------------------------------------
# Factor correction (paper §4.4 "Factor Intel")
# ---------------------------------------------------------------------------

def factor_correct(base: PerfModel,
                   feats_sample: np.ndarray,
                   runtimes_sample: np.ndarray,
                   fill_missing: bool = False) -> PerfModel:
    """Per-primitive multiplicative output correction estimated from a small
    sample of target-platform measurements (paper uses 1% ≈ 25 points).
    Returns a model whose predictions are ``base_prediction * factor[j]``.
    The factor is the geometric-mean runtime ratio per column, the MMSE
    estimator in log space.

    ``fill_missing``: columns with no finite sample entry get the mean log
    factor of the columns that have one, instead of staying uncorrected.
    Served-traffic calibration samples only measure the *assigned*
    primitives; leaving the rest at factor 1 on a uniformly drifted platform
    would make every unmeasured primitive look cheap and skew the re-solved
    selection towards exactly the columns nothing vouches for."""
    pred = base.predict(feats_sample)
    actual = np.asarray(runtimes_sample, np.float64)
    n_out = actual.shape[1]
    log_factor = np.zeros(n_out)
    observed = np.zeros(n_out, bool)
    for j in range(n_out):
        m = np.isfinite(actual[:, j]) & np.isfinite(pred[:, j]) & (pred[:, j] > 0)
        if m.any():
            log_factor[j] = np.mean(np.log(actual[m, j]) - np.log(pred[m, j]))
            observed[j] = True
    if fill_missing and observed.any() and not observed.all():
        log_factor[~observed] = np.mean(log_factor[observed])
    if isinstance(base, FactorCorrectedModel):
        # re-correction (e.g. each drift-loop generation) composes factors on
        # the underlying trained model instead of nesting wrapper on wrapper;
        # the correction above was computed against the already-factored
        # predictions, so the composed factor is their sum in log space
        return FactorCorrectedModel(base=base.base,
                                    log_factor=base.log_factor + log_factor)
    return FactorCorrectedModel(base=base, log_factor=log_factor)


@dataclasses.dataclass
class FactorCorrectedModel(PerfModel):
    """PerfModel wrapper applying per-column multiplicative correction."""
    base: PerfModel = None
    log_factor: np.ndarray = None

    def __init__(self, base: PerfModel, log_factor: np.ndarray):
        super().__init__(kind=f"factor-{base.kind}", in_norm=base.in_norm,
                         out_norm=base.out_norm, params=base.params,
                         n_outputs=base.n_outputs, columns=base.columns)
        self.base = base
        self.log_factor = log_factor

    def predict(self, feats: np.ndarray) -> np.ndarray:
        return self.base.predict(feats) * np.exp(self.log_factor)[None, :]


@dataclasses.dataclass(frozen=True)
class BucketScaleHead:
    """Per-pow2-bucket scale head: the batch-shape correction on top of a
    per-image perf model (DESIGN.md §12.3).

    The base models predict per-image cost as batch-size-invariant, but the
    pow2-bucketed serving reality is not linear: fixed dispatch overhead
    amortises with batch size and pad rows inflate small partial batches.
    The head captures that *shape* as a log-space multiplier per observed
    bucket, fitted from the served-traffic buffer (``DriftMonitor`` keys
    ``ServedObservation`` by bucket). It is normalised so the count-weighted
    mean log scale is zero — common drift (the whole platform getting
    slower) stays the drift EWMA's job; the head only redistributes cost
    across batch shapes. Unseen buckets interpolate linearly in log2(bucket)
    space and clamp at the observed ends."""

    log2_buckets: np.ndarray       # (B,) sorted log2 of observed pow2 buckets
    log_scale: np.ndarray          # (B,) log multiplier per bucket

    def __post_init__(self):
        lb = np.asarray(self.log2_buckets, np.float64)
        ls = np.asarray(self.log_scale, np.float64)
        if lb.shape != ls.shape or lb.ndim != 1 or lb.size == 0:
            raise ValueError(f"bucket/scale shape mismatch: {lb.shape} vs "
                             f"{ls.shape}")
        if not (np.isfinite(lb).all() and np.isfinite(ls).all()):
            raise ValueError("non-finite bucket scale head")
        if np.any(np.diff(lb) <= 0):
            raise ValueError("buckets must be strictly increasing")
        object.__setattr__(self, "log2_buckets", lb)
        object.__setattr__(self, "log_scale", ls)

    def scale(self, bucket: int) -> float:
        """Relative per-image cost multiplier at pow2 ``bucket`` (1.0 means
        'costs exactly the across-bucket mean')."""
        x = np.log2(max(int(bucket), 1))
        return float(np.exp(np.interp(x, self.log2_buckets, self.log_scale)))

    def buckets(self) -> list:
        return [int(b) for b in np.round(2.0 ** self.log2_buckets)]

    @classmethod
    def fit(cls, observations, *, alpha: float = 0.5,
            normalize: bool = True,
            min_obs: int = 1) -> Optional["BucketScaleHead"]:
        """Fit from ``(bucket, log_ratio)`` pairs, oldest → newest — exactly
        the served-traffic buffer's shape, where ``log_ratio`` is
        log(observed / predicted) per-image for one cleanly-timed dispatch.
        Per bucket an exponentially-weighted mean (fresh entries dominate);
        buckets with fewer than ``min_obs`` entries are dropped as noise.
        ``normalize`` subtracts the count-weighted mean so the head carries
        shape only. None when nothing (finite) was observed."""
        ew: Dict[int, float] = {}
        counts: Dict[int, int] = {}
        for bucket, log_r in observations:
            b = int(bucket)
            r = float(log_r)
            if b < 1 or not np.isfinite(r):
                continue
            ew[b] = r if b not in ew else ew[b] + alpha * (r - ew[b])
            counts[b] = counts.get(b, 0) + 1
        kept = sorted(b for b in ew if counts[b] >= max(int(min_obs), 1))
        if not kept:
            return None
        vals = np.asarray([ew[b] for b in kept], np.float64)
        if normalize:
            w = np.asarray([counts[b] for b in kept], np.float64)
            vals = vals - float(np.average(vals, weights=w))
        return cls(log2_buckets=np.log2(np.asarray(kept, np.float64)),
                   log_scale=vals)
