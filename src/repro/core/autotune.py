"""Kernel-variant selection for TPU (DESIGN.md §2.2) — the paper's technique
operating natively on the TPU stack.

"Primitives" here are Pallas matmul block configurations (bm, bk, bn) from
``repro.kernels.matmul.ops.VARIANTS``; "layers" are the matmul sites of a
transformer architecture (QKV/out projections, MLP up/down, expert GEMMs).
An NN2 performance model is trained on an analytic TPU cost surface
(MXU roofline + VMEM-tiling effects + HBM traffic, deliberately non-linear
in the block shape), then a chain PBQP selects per-site variants. On real
hardware the analytic surface is replaced by profiled timings — the pipeline
is identical (the paper's point).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import pbqp
from repro.core.perfmodel import PerfModel, fit_perf_model
from repro.kernels.matmul.ops import VARIANTS

# v5e-flavoured constants (per chip)
_PEAK = 197e12
_HBM_BW = 819e9
_VMEM_BYTES = 64 * 2 ** 20          # ~64 MiB usable VMEM per core (v5e ~128)


def matmul_sites(cfg: ArchConfig, seq: int = 4096, batch_tokens: int = 65536,
                 tp: int = 16) -> List[Tuple[str, int, int, int]]:
    """(name, M, K, N) matmul sites for one layer of ``cfg``, after TP
    sharding by ``tp`` (the per-device GEMM the kernel actually runs)."""
    d, hd = cfg.d_model, cfg.hd
    M = batch_tokens
    sites = []
    if cfg.attn_kind == "gqa":
        sites += [("wq", M, d, max(cfg.n_heads * hd // tp, 128)),
                  ("wk", M, d, max(cfg.n_kv_heads * hd // tp, 128)),
                  ("wo", M, max(cfg.n_heads * hd // tp, 128), d)]
    elif cfg.attn_kind == "mla":
        m = cfg.mla
        sites += [("wdq", M, d, m.q_lora),
                  ("wuq", M, m.q_lora, max(cfg.n_heads * (m.qk_nope + m.qk_rope) // tp, 128)),
                  ("wo", M, max(cfg.n_heads * m.v_head // tp, 128), d)]
    if cfg.moe is not None:
        ff = cfg.moe.d_ff
        tokens_per_expert = int(1.25 * M * cfg.moe.top_k / cfg.moe.n_experts)
        sites += [("expert_up", max(tokens_per_expert, 128), d, ff),
                  ("expert_down", max(tokens_per_expert, 128), ff, d)]
    elif cfg.d_ff:
        sites += [("mlp_up", M, d, max(cfg.d_ff // tp, 128)),
                  ("mlp_down", M, max(cfg.d_ff // tp, 128), d)]
    if cfg.ssm is not None:
        din = cfg.ssm.d_inner(d)
        sites += [("ssm_in", M, d, max((2 * din) // tp, 128)),
                  ("ssm_out", M, max(din // tp, 128), d)]
    return sites


def analytic_cost(M: int, K: int, N: int, bm: int, bk: int, bn: int,
                  dtype_bytes: int = 2) -> float:
    """Seconds for a tiled (M,K)x(K,N) GEMM on one v5e core. Non-linear in
    the block config: MXU alignment, VMEM residency, grid overheads and
    HBM re-streaming of operands across tile passes."""
    gm, gn, gk = -(-M // bm), -(-N // bn), -(-K // bk)
    # padding waste from tile quantisation
    eff_shape = (M / (gm * bm)) * (N / (gn * bn)) * (K / (gk * bk))
    # MXU alignment: sub-128 tiles underuse the systolic array
    align = min(bm, 128) / 128 * min(bn, 128) / 128 * min(bk, 128) / 128
    mxu_eff = 0.9 * eff_shape * (0.55 + 0.45 * align)
    # VMEM residency: working set must fit; overflow thrashes
    ws = dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn
    if ws > _VMEM_BYTES:
        mxu_eff *= 0.25
    flops = 2.0 * M * N * K
    t_compute = flops / (_PEAK * mxu_eff)
    # HBM: x re-read gn times, y re-read gm times (output-stationary tiling)
    traffic = dtype_bytes * (M * K * gn + K * N * gm) + dtype_bytes * M * N
    t_mem = traffic / _HBM_BW
    t_grid = gm * gn * gk * 1.2e-6      # per-tile dispatch overhead
    return max(t_compute, t_mem) + t_grid


def build_dataset(n: int = 3000, seed: int = 0):
    """(M, K, N, bm, bk, bn) -> seconds samples over realistic GEMM shapes."""
    rng = np.random.default_rng(seed)
    names = list(VARIANTS)
    feats, times = [], []
    for _ in range(n):
        M = int(2 ** rng.uniform(7, 17))
        K = int(2 ** rng.uniform(7, 15))
        N = int(2 ** rng.uniform(7, 15))
        row = []
        for v in names:
            bm, bk, bn = VARIANTS[v]
            row.append(analytic_cost(M, K, N, bm, bk, bn)
                       * math.exp(rng.normal(0, 0.02)))
        feats.append([M, K, N])
        times.append(row)
    return np.array(feats, float), np.array(times), names


def train_cost_model(seed: int = 0, max_iters: int = 4000) -> PerfModel:
    f, t, names = build_dataset(seed=seed)
    n = len(f)
    tr, va = slice(0, int(0.8 * n)), slice(int(0.8 * n), int(0.9 * n))
    return fit_perf_model("nn2", f[tr], t[tr], f[va], t[va], columns=names,
                          max_iters=max_iters, seed=seed)


@dataclasses.dataclass
class AutotuneResult:
    assignment: Dict[str, str]           # site -> variant
    predicted_s: float
    default_s: float                     # all sites on the first variant
    oracle_s: float                      # analytic-optimal

    @property
    def speedup_vs_default(self) -> float:
        return self.default_s / self.predicted_s if self.predicted_s else 1.0


def autotune_arch(cfg: ArchConfig, model: PerfModel, tp: int = 16,
                  batch_tokens: int = 65536) -> AutotuneResult:
    """PBQP-select a kernel variant per matmul site of ``cfg`` (chain graph;
    variant switches carry no layout cost for these kernels, so edges are
    zero — the graph degenerates to per-site argmin, which PBQP handles as
    R0 reductions; layout-carrying kernels would populate the edges)."""
    sites = matmul_sites(cfg, batch_tokens=batch_tokens, tp=tp)
    names = list(model.columns)
    feats = np.array([[m, k, n] for (_, m, k, n) in sites], float)
    pred = model.predict(feats)                      # (n_sites, n_variants)

    g = pbqp.PBQPGraph()
    for i, (site, m, k, n) in enumerate(sites):
        g.add_node(i, pred[i], labels=names)
    sol = pbqp.solve(g)
    lab = sol.labelled(g)

    true = np.array([[analytic_cost(m, k, n, *VARIANTS[v]) for v in names]
                     for (_, m, k, n) in sites])
    sel = sum(true[i, names.index(lab[i])] for i in range(len(sites)))
    default = float(true[:, 0].sum())
    oracle = float(true.min(axis=1).sum())
    return AutotuneResult({s[0]: lab[i] for i, s in enumerate(sites)},
                          float(sel), default, oracle)
