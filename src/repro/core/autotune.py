"""Kernel-variant selection for TPU (DESIGN.md §2.2) — the paper's technique
operating natively on the TPU stack.

"Primitives" here are Pallas matmul block configurations (bm, bk, bn) from
``repro.kernels.matmul.ops.VARIANTS``; "layers" are the matmul sites of a
transformer architecture (QKV/out projections, MLP up/down, expert GEMMs).
An NN2 performance model is trained on an analytic TPU cost surface
(MXU roofline + VMEM-tiling effects + HBM traffic, deliberately non-linear
in the block shape), then a chain PBQP selects per-site variants. On real
hardware the analytic surface is replaced by profiled timings — the pipeline
is identical (the paper's point).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import pbqp
from repro.core.perfmodel import PerfModel, fit_perf_model
from repro.kernels.matmul.ops import VARIANTS

# v5e-flavoured constants (per chip)
_PEAK = 197e12
_HBM_BW = 819e9
_VMEM_BYTES = 64 * 2 ** 20          # ~64 MiB usable VMEM per core (v5e ~128)


def matmul_sites(cfg: ArchConfig, seq: int = 4096, batch_tokens: int = 65536,
                 tp: int = 16) -> List[Tuple[str, int, int, int]]:
    """(name, M, K, N) matmul sites for one layer of ``cfg``, after TP
    sharding by ``tp`` (the per-device GEMM the kernel actually runs)."""
    d, hd = cfg.d_model, cfg.hd
    M = batch_tokens
    sites = []
    if cfg.attn_kind == "gqa":
        sites += [("wq", M, d, max(cfg.n_heads * hd // tp, 128)),
                  ("wk", M, d, max(cfg.n_kv_heads * hd // tp, 128)),
                  ("wo", M, max(cfg.n_heads * hd // tp, 128), d)]
    elif cfg.attn_kind == "mla":
        m = cfg.mla
        sites += [("wdq", M, d, m.q_lora),
                  ("wuq", M, m.q_lora, max(cfg.n_heads * (m.qk_nope + m.qk_rope) // tp, 128)),
                  ("wo", M, max(cfg.n_heads * m.v_head // tp, 128), d)]
    if cfg.moe is not None:
        ff = cfg.moe.d_ff
        tokens_per_expert = int(1.25 * M * cfg.moe.top_k / cfg.moe.n_experts)
        sites += [("expert_up", max(tokens_per_expert, 128), d, ff),
                  ("expert_down", max(tokens_per_expert, 128), ff, d)]
    elif cfg.d_ff:
        sites += [("mlp_up", M, d, max(cfg.d_ff // tp, 128)),
                  ("mlp_down", M, max(cfg.d_ff // tp, 128), d)]
    if cfg.ssm is not None:
        din = cfg.ssm.d_inner(d)
        sites += [("ssm_in", M, d, max((2 * din) // tp, 128)),
                  ("ssm_out", M, max(din // tp, 128), d)]
    return sites


def analytic_cost(M: int, K: int, N: int, bm: int, bk: int, bn: int,
                  dtype_bytes: int = 2) -> float:
    """Seconds for a tiled (M,K)x(K,N) GEMM on one v5e core. Non-linear in
    the block config: MXU alignment, VMEM residency, grid overheads and
    HBM re-streaming of operands across tile passes."""
    gm, gn, gk = -(-M // bm), -(-N // bn), -(-K // bk)
    # padding waste from tile quantisation
    eff_shape = (M / (gm * bm)) * (N / (gn * bn)) * (K / (gk * bk))
    # MXU alignment: sub-128 tiles underuse the systolic array
    align = min(bm, 128) / 128 * min(bn, 128) / 128 * min(bk, 128) / 128
    mxu_eff = 0.9 * eff_shape * (0.55 + 0.45 * align)
    # VMEM residency: working set must fit; overflow thrashes
    ws = dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn
    if ws > _VMEM_BYTES:
        mxu_eff *= 0.25
    flops = 2.0 * M * N * K
    t_compute = flops / (_PEAK * mxu_eff)
    # HBM: x re-read gn times, y re-read gm times (output-stationary tiling)
    traffic = dtype_bytes * (M * K * gn + K * N * gm) + dtype_bytes * M * N
    t_mem = traffic / _HBM_BW
    t_grid = gm * gn * gk * 1.2e-6      # per-tile dispatch overhead
    return max(t_compute, t_mem) + t_grid


# ---------------------------------------------------------------------------
# Conv-layer tile profiling (DESIGN.md §9): the autotune cost surface as a
# Platform profiler. Each CNN layer config (k, c, im, s, f) lowers to the
# GEMM its base primitive would run on the Pallas path; each
# (primitive, tile-config) registry column prices that GEMM under its block
# shape via ``analytic_cost``. The result is the same (L, P) matrix contract
# the simulators produce — NaN where the base primitive is inapplicable,
# deterministic lognormal noise keyed on the full column name — so the NN2
# model, ``calibrate()`` and the PBQP consume tile columns exactly like
# primitives.
# ---------------------------------------------------------------------------

# Pallas-backed base primitives (PR 2 batch kernels): im2col lowerings ride
# the im2col_gemm kernel, winograd the winograd batch kernel, 1x1 the plain
# tiled matmul. Only runnable bases — tile columns must stay executable.
PALLAS_CONV_BASES: Tuple[str, ...] = (
    "im2col-copy-ab-ki",
    "im2col-scan-ab-ki",
    "winograd-2x2-3x3",
    "winograd-4x4-3x3",
    "conv-1x1-gemm-ab-ki",
)

_TILE_SIGMA = 0.03                  # lognormal noise floor of the profiler
_MASK52 = (1 << 52) - 1


def pallas_columns(bases: Sequence[str] = PALLAS_CONV_BASES,
                   variants: Optional[Sequence[str]] = None) -> List[str]:
    """The (base primitive × matmul tile variant) column set."""
    from repro.primitives.conv import tile_columns
    return tile_columns(bases, list(variants) if variants is not None
                        else list(VARIANTS))


def _mix64(x: np.ndarray) -> np.ndarray:
    """Vectorised splitmix64 finaliser (same stream idiom as the platform
    simulators — deterministic, counter-based, no RNG state)."""
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def _lognormal(h: np.ndarray, sigma: float) -> np.ndarray:
    u = (h & np.uint64(_MASK52)).astype(np.float64) / float(1 << 52)
    v = ((h >> np.uint64(8)) & np.uint64(_MASK52)).astype(np.float64) / float(1 << 52)
    z = np.sqrt(-2.0 * np.log(np.maximum(u, 1e-12))) * np.cos(2 * np.pi * v)
    return np.exp(sigma * z)


def _analytic_cost_np(M, K, N, bm: int, bk: int, bn: int,
                      dtype_bytes: int = 2) -> np.ndarray:
    """Broadcasting twin of ``analytic_cost`` (identical math; the VMEM
    branch becomes a where)."""
    M, K, N = (np.asarray(a, np.float64) for a in (M, K, N))
    gm, gn, gk = np.ceil(M / bm), np.ceil(N / bn), np.ceil(K / bk)
    eff_shape = (M / (gm * bm)) * (N / (gn * bn)) * (K / (gk * bk))
    align = min(bm, 128) / 128 * min(bn, 128) / 128 * min(bk, 128) / 128
    mxu_eff = 0.9 * eff_shape * (0.55 + 0.45 * align)
    ws = dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn
    if ws > _VMEM_BYTES:
        mxu_eff = mxu_eff * 0.25
    flops = 2.0 * M * N * K
    t_compute = flops / (_PEAK * np.maximum(mxu_eff, 1e-9))
    traffic = dtype_bytes * (M * K * gn + K * N * gm) + dtype_bytes * M * N
    t_mem = traffic / _HBM_BW
    t_grid = gm * gn * gk * 1.2e-6
    return np.maximum(t_compute, t_mem) + t_grid


def _variant_blocks(variant: Optional[str]) -> Tuple[int, int, int]:
    """(bm, bk, bn) GEMM blocks a tile variant lowers to (DESIGN.md §13).

    ``mm-*`` names the blocks directly. ``conv-bkB`` is the fused
    im2col+GEMM kernel whose B-sized block tiles the output-channel (GEMM M)
    axis; ``wino-KxT`` tiles the point-GEMM's (K, T) = (M, N) axes. The
    perf model must price the blocks the kernel actually runs with, or PBQP
    ranks those columns by a config they never execute."""
    if variant is None:
        return (128, 128, 128)
    if variant in VARIANTS:                            # mm-BMxBKxBN
        return VARIANTS[variant]
    if variant.startswith("conv-bk"):
        from repro.kernels.im2col_gemm.ops import VARIANTS as CONV_VARIANTS
        b = CONV_VARIANTS.get(variant)
        return (b, 128, 128) if b else (128, 128, 128)
    if variant.startswith("wino-"):
        from repro.kernels.winograd.ops import VARIANTS as WINO_VARIANTS
        kt = WINO_VARIANTS.get(variant)
        return (kt[0], 128, kt[1]) if kt else (128, 128, 128)
    return (128, 128, 128)


def conv_tile_time_batch(configs: np.ndarray,
                         columns: Optional[Sequence[str]] = None,
                         *, noisy: bool = True,
                         time_scale: float = 1.0) -> np.ndarray:
    """(L, 5) conv configs -> (L, P) per-image runtimes over tile columns.

    Per base family the layer lowers to:
      * im2col:   (k, c·f²) @ (c·f², oh·ow) — one GEMM per image;
      * 1x1:      (k, c) @ (c, oh·ow);
      * winograd: n² pointwise (k, c) @ (c, tiles) GEMMs plus input/output
        transform traffic (n = tile_m + r − 1, tiles = ⌈oh/m⌉·⌈ow/m⌉).
    NaN where the base primitive is inapplicable (same structural mask the
    selection layer uses).
    """
    from repro.primitives.conv import FAMILIES, compile_traits, split_tile
    names = tuple(columns) if columns is not None else tuple(pallas_columns())
    cfg = np.asarray(configs, np.int64)
    if cfg.ndim != 2 or cfg.shape[1] != 5:
        raise ValueError(f"configs must be (L, 5), got {cfg.shape}")
    tr = compile_traits(names)
    ki, ci, imi, si, fi = (cfg[:, j] for j in range(5))
    app = tr.applicable_mask(ki, ci, imi, si, fi)            # (L, P)
    o = (imi - fi) // np.maximum(si, 1) + 1                  # (L,)
    k = ki.astype(np.float64)
    c = ci.astype(np.float64)
    f = fi.astype(np.float64)
    P = o.astype(np.float64) ** 2

    out = np.empty((cfg.shape[0], len(names)), np.float64)
    for j, name in enumerate(names):
        base, variant = split_tile(name)
        bm, bk, bn = _variant_blocks(variant)
        if base.startswith("conv-1x1"):
            t = _analytic_cost_np(k, c, P, bm, bk, bn)
        elif base.startswith("winograd"):
            m = int(tr.tile_m[j]) or 2
            r = 5 if tr.fam[j] == FAMILIES.index("wino5") else 3
            n = m + r - 1
            tiles = np.ceil(o / m) ** 2
            t = (n * n) * _analytic_cost_np(k, c, tiles, bm, bk, bn)
            # input/output tile transforms stream through HBM
            t = t + 2.0 * 2 * (c + k) * n * n * tiles / _HBM_BW
        else:                                      # im2col lowerings
            t = _analytic_cost_np(k, c * f * f, P, bm, bk, bn)
            # lowering traffic: the patch matrix is materialised once
            t = t + 2.0 * c * f * f * P / _HBM_BW
        out[:, j] = t
    if noisy:
        h = _mix64(tr.key[None, :].astype(np.uint64))
        for fld in (ki, ci, imi, si, fi):
            h = _mix64(h ^ fld.astype(np.uint64)[:, None])
        out *= _lognormal(h, _TILE_SIGMA)
    out *= time_scale
    out[~app] = np.nan
    return out


# non-identity DLT columns in layouts.dlt_pairs() order, priced as HBM
# permute traffic (full chw<->hwc transposes stream worse than adjacent
# swaps — same structure as the CPU simulators' staircase, TPU-flavoured)
def pallas_dlt_time_batch(pairs: np.ndarray, *, noisy: bool = True,
                          time_scale: float = 1.0) -> np.ndarray:
    from repro.primitives import layouts as L
    from repro.primitives.conv import name_hash64
    pr = np.asarray(pairs, np.int64)
    if pr.ndim != 2 or pr.shape[1] != 2:
        raise ValueError(f"pairs must be (M, 2), got {pr.shape}")
    ni = [(s, d) for (s, d) in L.dlt_pairs() if s != d]
    eff = np.array([0.35 if {s, d} == {"chw", "hwc"} else 0.6
                    for (s, d) in ni])
    keys = np.array([name_hash64("pallas-dlt|" + L.dlt_name(s, d))
                     for (s, d) in ni], np.uint64)
    c = pr[:, 0].astype(np.float64)
    im = pr[:, 1].astype(np.float64)
    bytes_moved = 2.0 * 4.0 * c * im * im                    # read + write
    out = bytes_moved[:, None] / (_HBM_BW * eff[None, :]) + 2e-6
    if noisy:
        h = _mix64(keys[None, :])
        for fld in (pr[:, 0], pr[:, 1]):
            h = _mix64(h ^ fld.astype(np.uint64)[:, None])
        out *= _lognormal(h, _TILE_SIGMA)
    return out * time_scale


class PallasTileProvider:
    """CostProvider over (primitive, tile) columns backed by the analytic
    TPU surface — plays 'profiled on the accelerator' for selection."""

    def __init__(self, columns: Optional[Sequence[str]] = None, *,
                 noisy: bool = True, time_scale: float = 1.0):
        self.columns = (list(columns) if columns is not None
                        else pallas_columns())
        self.noisy = noisy
        self.time_scale = time_scale

    def primitive_cost_matrix(self, configs: np.ndarray) -> np.ndarray:
        if len(configs) == 0:
            return np.zeros((0, len(self.columns)))
        return conv_tile_time_batch(configs, self.columns, noisy=self.noisy,
                                    time_scale=self.time_scale)

    def dlt_cost_matrix(self, pairs: np.ndarray) -> np.ndarray:
        if len(pairs) == 0:
            from repro.primitives import layouts as L
            n = sum(1 for (s, d) in L.dlt_pairs() if s != d)
            return np.zeros((0, n))
        return pallas_dlt_time_batch(pairs, noisy=self.noisy,
                                     time_scale=self.time_scale)


def build_dataset(n: int = 3000, seed: int = 0):
    """(M, K, N, bm, bk, bn) -> seconds samples over realistic GEMM shapes."""
    rng = np.random.default_rng(seed)
    names = list(VARIANTS)
    feats, times = [], []
    for _ in range(n):
        M = int(2 ** rng.uniform(7, 17))
        K = int(2 ** rng.uniform(7, 15))
        N = int(2 ** rng.uniform(7, 15))
        row = []
        for v in names:
            bm, bk, bn = VARIANTS[v]
            row.append(analytic_cost(M, K, N, bm, bk, bn)
                       * math.exp(rng.normal(0, 0.02)))
        feats.append([M, K, N])
        times.append(row)
    return np.array(feats, float), np.array(times), names


def train_cost_model(seed: int = 0, max_iters: int = 4000) -> PerfModel:
    f, t, names = build_dataset(seed=seed)
    n = len(f)
    tr, va = slice(0, int(0.8 * n)), slice(int(0.8 * n), int(0.9 * n))
    return fit_perf_model("nn2", f[tr], t[tr], f[va], t[va], columns=names,
                          max_iters=max_iters, seed=seed)


@dataclasses.dataclass
class AutotuneResult:
    assignment: Dict[str, str]           # site -> variant
    predicted_s: float
    default_s: float                     # all sites on the first variant
    oracle_s: float                      # analytic-optimal

    @property
    def speedup_vs_default(self) -> float:
        return self.default_s / self.predicted_s if self.predicted_s else 1.0


def autotune_arch(cfg: ArchConfig, model: PerfModel, tp: int = 16,
                  batch_tokens: int = 65536) -> AutotuneResult:
    """PBQP-select a kernel variant per matmul site of ``cfg`` (chain graph;
    variant switches carry no layout cost for these kernels, so edges are
    zero — the graph degenerates to per-site argmin, which PBQP handles as
    R0 reductions; layout-carrying kernels would populate the edges)."""
    sites = matmul_sites(cfg, batch_tokens=batch_tokens, tp=tp)
    names = list(model.columns)
    feats = np.array([[m, k, n] for (_, m, k, n) in sites], float)
    pred = model.predict(feats)                      # (n_sites, n_variants)

    g = pbqp.PBQPGraph()
    for i, (site, m, k, n) in enumerate(sites):
        g.add_node(i, pred[i], labels=names)
    sol = pbqp.solve(g)
    lab = sol.labelled(g)

    true = np.array([[analytic_cost(m, k, n, *VARIANTS[v]) for v in names]
                     for (_, m, k, n) in sites])
    sel = sum(true[i, names.index(lab[i])] for i in range(len(sites)))
    default = float(true[:, 0].sum())
    oracle = float(true.min(axis=1).sum())
    return AutotuneResult({s[0]: lab[i] for i, s in enumerate(sites)},
                          float(sel), default, oracle)
