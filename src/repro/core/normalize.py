"""Log-standardization of performance data (paper §3.3, "Data Point
Normalization").

The paper trains on ``z = log(x)`` then standardizes ``(z - mean(z)) / std(z)``
per column, handling undefined entries (primitive inapplicable to a layer
shape) as NaN that must not contribute to statistics, loss, or gradients.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class LogStandardizer:
    """Fit on (N, D) data with NaN for undefined entries; column-wise stats.

    ``log=True`` applies the paper's log transform before standardizing —
    used for runtimes (outputs) and for the layer-shape features (inputs),
    whose ranges span orders of magnitude (k, c in [1, 2048]).
    """

    log: bool = True
    mean_: Optional[np.ndarray] = None
    std_: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray) -> "LogStandardizer":
        z = self._pre(np.asarray(x, np.float64))
        self.mean_ = np.nanmean(z, axis=0)
        std = np.nanstd(z, axis=0)
        # Constant columns (e.g. a primitive defined for a single stride)
        # standardize to zero instead of exploding.
        self.std_ = np.where(std < 1e-12, 1.0, std)
        return self

    def _pre(self, x: np.ndarray) -> np.ndarray:
        if self.log:
            with np.errstate(divide="ignore", invalid="ignore"):
                return np.log(x)
        return x

    def transform(self, x: np.ndarray) -> np.ndarray:
        if self.mean_ is None:
            raise RuntimeError("fit() before transform()")
        z = self._pre(np.asarray(x, np.float64))
        return ((z - self.mean_) / self.std_).astype(np.float32)

    def fit_transform(self, x: np.ndarray) -> np.ndarray:
        return self.fit(x).transform(x)

    def inverse(self, xt: np.ndarray) -> np.ndarray:
        if self.mean_ is None:
            raise RuntimeError("fit() before inverse()")
        z = np.asarray(xt, np.float64) * self.std_ + self.mean_
        return (np.exp(z) if self.log else z).astype(np.float64)

    # -- (de)serialization for checkpointing ------------------------------
    def to_dict(self) -> dict:
        return {"log": self.log,
                "mean": None if self.mean_ is None else self.mean_.tolist(),
                "std": None if self.std_ is None else self.std_.tolist()}

    @classmethod
    def from_dict(cls, d: dict) -> "LogStandardizer":
        obj = cls(log=d["log"])
        obj.mean_ = None if d["mean"] is None else np.asarray(d["mean"], np.float64)
        obj.std_ = None if d["std"] is None else np.asarray(d["std"], np.float64)
        return obj


def mdrae(pred: np.ndarray, actual: np.ndarray) -> float:
    """Median relative absolute error |yhat - y| / y (paper §3.3), computed
    over defined entries only."""
    pred = np.asarray(pred, np.float64)
    actual = np.asarray(actual, np.float64)
    mask = np.isfinite(actual) & np.isfinite(pred) & (actual > 0)
    if not mask.any():
        return float("nan")
    rae = np.abs(pred[mask] - actual[mask]) / actual[mask]
    return float(np.median(rae))


def mdrae_per_column(pred: np.ndarray, actual: np.ndarray) -> np.ndarray:
    """Per-primitive MdRAE (paper Figs 4-6 are per-primitive bars)."""
    pred = np.asarray(pred, np.float64)
    actual = np.asarray(actual, np.float64)
    out = np.full(actual.shape[1], np.nan)
    for j in range(actual.shape[1]):
        out[j] = mdrae(pred[:, j], actual[:, j])
    return out
