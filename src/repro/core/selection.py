"""Primitive selection pipeline (paper Fig 2).

  (i)   extract layer configurations from the network spec,
  (ii)  estimate primitive + DLT runtimes (performance model, batched — all
        layers in one forward pass) or look up measured/simulated times,
  (iii) solve the PBQP for the optimal per-layer assignment,
  (iv)  emit the assignment for the executor.

Join nodes (concat/residual-add) become 3-choice layout nodes with zero node
cost (DESIGN.md §3), keeping inception-style graphs exactly reducible.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core import pbqp
from repro.core.perfmodel import PerfModel
from repro.models.cnn_zoo import CNNSpec, ConvLayer, JoinNode
from repro.primitives.conv import (PRIMITIVE_NAMES, REGISTRY, compile_traits,
                                   resolve)
from repro.primitives import layouts as L


# ---------------------------------------------------------------------------
# Cost providers
# ---------------------------------------------------------------------------

class CostProvider(Protocol):
    columns: Sequence[str]

    def primitive_cost_matrix(self, configs: np.ndarray) -> np.ndarray:
        """(L, 5) configs -> (L, P) runtimes (NaN = inapplicable)."""

    def dlt_cost_matrix(self, pairs: np.ndarray) -> np.ndarray:
        """(M, 2) (c, im) pairs -> (M, 6) non-identity DLT runtimes in
        ``layouts.dlt_pairs()`` order (identity excluded)."""


_DLT_COLS = [L.dlt_name(s, d) for (s, d) in L.dlt_pairs() if s != d]


class SimulatedProvider:
    """Ground-truth provider backed by a platform simulator — plays the role
    of 'profiled on the device' in the paper's comparisons."""

    def __init__(self, platform: str, noisy: bool = True,
                 columns: Optional[Sequence[str]] = None):
        from repro.profiler.simulators import (PLATFORMS, dlt_time_batch,
                                               primitive_time_batch)
        self._plat = PLATFORMS[platform]
        self._ptime_batch = primitive_time_batch
        self._dtime_batch = dlt_time_batch
        self.noisy = noisy
        self.columns = list(columns) if columns is not None else list(PRIMITIVE_NAMES)

    def primitive_cost_matrix(self, configs: np.ndarray) -> np.ndarray:
        if len(configs) == 0:
            return np.zeros((0, len(self.columns)))
        return self._ptime_batch(self._plat, np.asarray(configs, np.int64),
                                 noisy=self.noisy, columns=tuple(self.columns))

    def dlt_cost_matrix(self, pairs: np.ndarray) -> np.ndarray:
        if len(pairs) == 0:
            return np.zeros((0, len(_DLT_COLS)))
        return self._dtime_batch(self._plat, np.asarray(pairs, np.int64),
                                 noisy=self.noisy)


class ModelProvider:
    """Performance-model provider (the paper's contribution): one batched
    forward pass per network for primitives and one for DLTs.

    ``columns`` restricts selection to a subset of the model's output columns
    (e.g. the runnable primitives when the assignment must execute on this
    host) without retraining — predictions are sliced per call."""

    def __init__(self, prim_model: PerfModel, dlt_model: PerfModel,
                 columns: Optional[Sequence[str]] = None):
        self.prim_model = prim_model
        self.dlt_model = dlt_model
        if columns is None:
            self.columns = list(prim_model.columns)
            self._col_idx = None
        else:
            model_cols = list(prim_model.columns)
            missing = [c for c in columns if c not in model_cols]
            if missing:
                raise ValueError(f"model has no columns {missing}")
            self.columns = list(columns)
            self._col_idx = np.array([model_cols.index(c) for c in columns])

    def primitive_cost_matrix(self, configs: np.ndarray) -> np.ndarray:
        pred = self.prim_model.predict(np.asarray(configs, np.float64))
        if self._col_idx is not None:
            pred = pred[:, self._col_idx]
        # applicability is structural knowledge, not predicted
        cfg = np.asarray(configs, np.int64)
        mask = compile_traits(tuple(self.columns)).applicable_mask(
            cfg[:, 0], cfg[:, 1], cfg[:, 2], cfg[:, 3], cfg[:, 4])
        pred[~mask] = np.nan
        return pred

    def dlt_cost_matrix(self, pairs: np.ndarray) -> np.ndarray:
        return self.dlt_model.predict(np.asarray(pairs, np.float64))


class MeasuredProvider:
    """Real-CPU provider (profiles on demand; expensive — the paper's point)."""

    def __init__(self, repeats: int = 9, columns: Optional[Sequence[str]] = None):
        from repro.primitives.conv import RUNNABLE
        from repro.profiler import host
        self._host = host
        self.repeats = repeats
        self.columns = list(columns) if columns is not None else list(RUNNABLE)

    def primitive_cost_matrix(self, configs: np.ndarray) -> np.ndarray:
        return self._host.profile_primitive_batch(
            np.asarray(configs, int), self.columns, repeats=self.repeats)

    def dlt_cost_matrix(self, pairs: np.ndarray) -> np.ndarray:
        return self._host.profile_dlt_batch(np.asarray(pairs, int),
                                            repeats=self.repeats)


# ---------------------------------------------------------------------------
# PBQP construction
# ---------------------------------------------------------------------------

def _edge_tensor(node) -> Tuple[int, int]:
    """(c, im) of the tensor a node produces."""
    if isinstance(node, ConvLayer):
        return node.k, node.out_im
    return node.c, node.im


def _out_layout(node, choice: str) -> str:
    if isinstance(node, ConvLayer):
        # resolve, not REGISTRY[...]: tile columns ("base@mm-MxKxN")
        # inherit their base primitive's layouts
        return resolve(choice).out_layout
    return choice           # join nodes choose a layout directly


def _in_layout(node, choice: str) -> str:
    if isinstance(node, ConvLayer):
        return resolve(choice).in_layout
    return choice


def _node_choices(node, columns: Sequence[str]) -> List[str]:
    if isinstance(node, ConvLayer):
        return list(columns)
    return list(L.LAYOUTS)


@dataclasses.dataclass
class SelectionResult:
    assignment: Dict[int, str]       # node idx -> primitive name / layout
    solver_cost: float
    optimal: bool
    estimate_seconds: float          # step (ii) wall time
    solver_seconds: float            # step (iii) wall time

    @property
    def total_seconds(self) -> float:
        return self.estimate_seconds + self.solver_seconds


# (src, dst) layout indices of the 6 non-identity DLT columns, for scattering
# a provider DLT row into a dense (layouts × layouts) table
_DLT_SRC_IDX = np.array([L.LAYOUTS.index(s) for (s, d) in L.dlt_pairs() if s != d])
_DLT_DST_IDX = np.array([L.LAYOUTS.index(d) for (s, d) in L.dlt_pairs() if s != d])


def build_pbqp(spec: CNNSpec, provider: CostProvider) -> pbqp.PBQPGraph:
    columns = list(provider.columns)
    convs = [(i, n) for i, n in enumerate(spec.nodes) if isinstance(n, ConvLayer)]
    configs = np.array([n.config for _, n in convs], np.float64)
    cost_mat = provider.primitive_cost_matrix(configs) if len(convs) else np.zeros((0, len(columns)))

    # batched DLT prediction for every distinct produced tensor, scattered
    # into dense (layouts × layouts) tables: tables[p, src, dst]
    pair_list = sorted({_edge_tensor(spec.nodes[u]) for (u, v) in spec.edges})
    pair_idx = {p: i for i, p in enumerate(pair_list)}
    dlt_mat = (provider.dlt_cost_matrix(np.array(pair_list, np.float64))
               if pair_list else np.zeros((0, len(_DLT_COLS))))
    tables = np.zeros((len(pair_list), len(L.LAYOUTS), len(L.LAYOUTS)))
    tables[:, _DLT_SRC_IDX, _DLT_DST_IDX] = np.maximum(dlt_mat, 0.0)

    # per-choice layout index vectors: conv nodes from the compiled registry
    # traits of the provider's columns, join nodes choose a layout directly
    traits = compile_traits(tuple(columns))
    join_idx = np.arange(len(L.LAYOUTS))
    out_idx = {i: (traits.out_layout if isinstance(n, ConvLayer) else join_idx)
               for i, n in enumerate(spec.nodes)}
    in_idx = {i: (traits.in_layout if isinstance(n, ConvLayer) else join_idx)
              for i, n in enumerate(spec.nodes)}

    g = pbqp.PBQPGraph()
    conv_cost = {i: cost_mat[r] for r, (i, _) in enumerate(convs)}
    for i, node in enumerate(spec.nodes):
        choices = _node_choices(node, columns)
        if isinstance(node, ConvLayer):
            vec = np.where(np.isfinite(conv_cost[i]), conv_cost[i], np.inf)
            vec = np.maximum(vec, 0.0)
        else:
            vec = np.zeros(len(choices))
        g.add_node(i, vec, labels=choices)

    for (u, v) in spec.edges:
        tab = tables[pair_idx[_edge_tensor(spec.nodes[u])]]
        # every edge matrix is one gather: (producer out-layout, consumer
        # in-layout) per choice pair — no Python loop over primitive pairs
        m = tab[out_idx[u][:, None], in_idx[v][None, :]]
        g.add_edge(u, v, m)
    return g


def select(spec: CNNSpec, provider: CostProvider) -> SelectionResult:
    t0 = time.perf_counter()
    g = build_pbqp(spec, provider)
    t1 = time.perf_counter()
    sol = pbqp.solve(g)
    t2 = time.perf_counter()
    labelled = sol.labelled(g)
    return SelectionResult(labelled, sol.cost, sol.optimal, t1 - t0, t2 - t1)


def network_cost(spec: CNNSpec, assignment: Dict[int, str],
                 provider: Optional[CostProvider] = None, *,
                 graph: Optional[pbqp.PBQPGraph] = None) -> float:
    """Total network runtime under ``assignment`` with ``provider``'s costs —
    used to score a model-derived assignment against ground truth (Fig 7).

    Fig-7-style loops evaluate many assignments against one ground-truth
    provider; pass ``graph=build_pbqp(spec, provider)`` to amortise the
    O(build) cost across evaluations instead of rebuilding per call."""
    if graph is None:
        if provider is None:
            raise TypeError("network_cost needs a provider or a prebuilt graph")
        graph = build_pbqp(spec, provider)
    idx_assignment = {n: graph.labels[n].index(assignment[n])
                      for n in graph.labels}
    return pbqp.evaluate(graph, idx_assignment)
