"""FlashAttention Pallas kernel (causal, GQA-ready via pre-repeated heads).

Grid: (batch*heads, Q blocks, KV blocks), KV innermost. Online softmax
carries (m, l, acc) in f32 VMEM scratch across KV steps. Causal masking is
applied per element inside the block; fully-masked KV blocks (kv_start >
q_end) are skipped with ``pl.when`` so the causal lower triangle costs ~half
the FLOPs. Block sizes tile VMEM: (bq x d) + (bkv x d) x 2 + (bq x bkv)
working set.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, n_kv: int, bq: int, bkv: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        run = qi * bq + bq - 1 >= ki * bkv     # any unmasked element in block
    else:
        run = jnp.asarray(True)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                       # (bq, bkv)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ v
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: float | None = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q, k, v: (BH, S, d) with heads pre-folded into the batch dim
    (GQA callers repeat KV heads first). Returns (BH, S, d)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bkv == 0, "pad sequence to block multiples"
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    grid = (bh, sq // bq, sk // bkv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, n_kv=grid[2],
                          bq=bq, bkv=bkv, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
