"""Jitted wrapper + block-config variants for the flash attention kernel."""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention

VARIANTS: Dict[str, Tuple[int, int]] = {
    "fa-128x128": (128, 128),
    "fa-128x256": (128, 256),
    "fa-256x128": (256, 128),
    "fa-256x256": (256, 256),
    "fa-512x256": (512, 256),
}


@partial(jax.jit, static_argnames=("causal", "variant", "interpret"))
def flash_attention_op(q, k, v, causal: bool = True,
                       variant: str = "fa-128x128",
                       interpret: bool | None = None):
    """q/k/v: (B, S, H, hd) GQA layout; KV heads are repeated to full heads
    and folded into the batch dim for the kernel."""
    B, Sq, Hq, d = q.shape
    Hkv = k.shape[2]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, -1, d)
    bq, bkv = VARIANTS[variant]
    bq = min(bq, Sq)
    bkv = min(bkv, kf.shape[1])
    interp = default_interpret() if interpret is None else interpret
    out = flash_attention(qf, kf, vf, causal=causal, bq=bq, bkv=bkv,
                          interpret=interp)
    return out.reshape(B, Hq, Sq, d).transpose(0, 2, 1, 3)
