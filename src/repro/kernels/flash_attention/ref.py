"""Pure-jnp oracle for the flash attention kernel."""
import math

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    """(BH, Sq, d) x (BH, Sk, d) -> (BH, Sq, d), plain softmax attention."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
