"""Fused im2col + GEMM convolution Pallas kernel.

The paper's dominant primitive family (im2col) materialises the (c*f*f, P)
patch matrix in HBM. On TPU the lowering belongs in VMEM: this kernel
builds each output row's patch block on-chip and feeds the MXU directly —
the HBM-level patch matrix never exists (the TPU adaptation of the family,
DESIGN.md §2.3).

Overlapping strided input windows are not expressible as a single BlockSpec,
so the input is passed ``f`` times with per-kernel-row index maps: ref ``a``
delivers input row ``i*stride + a`` for output row ``i`` — plain
block indexing, valid on real TPU hardware (no ANY-memory-space tricks).

Grid: (K blocks, output rows). Weights arrive pre-flattened (K, C*f*f) in
(c, a, b) order — identical to the reference im2col lowering.

``conv_im2col_batch`` adds the request batch as an explicit leading grid
dimension — grid (N, K blocks, output rows), each program building one
image's row patch block — so a compiled serving plan feeds whole batches
through one kernel launch.

Epilogues (DESIGN.md §13): optional bias (per output channel), residual
(output-shaped) and ReLU finish the output tile in VMEM before its single
HBM writeback. In interpret mode the epilogue runs once at the wrapper
level (identical numerics, no per-grid-step interpreter overhead);
``fuse_store`` forces the in-kernel path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _finish(y, bias, res, relu: bool, channel_axis: int):
    if bias is not None:
        shape = [1] * y.ndim
        shape[channel_axis] = bias.shape[0]
        y = y + bias.astype(y.dtype).reshape(shape)
    if res is not None:
        y = y + res.astype(y.dtype)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def _conv_kernel(*refs, stride: int, f: int, ow: int, has_bias: bool,
                 has_res: bool, relu: bool):
    x_rows = refs[:f]            # each (C, 1, W)
    it = iter(refs[f:])
    w_ref = next(it)             # (bk, C*f*f)
    b_ref = next(it) if has_bias else None
    r_ref = next(it) if has_res else None
    o_ref = next(it)             # (1, bk, ow)
    C = x_rows[0].shape[0]
    cols = []
    for a in range(f):
        row = x_rows[a][:, 0, :]                          # (C, W)
        for b in range(f):
            end = b + (ow - 1) * stride + 1
            cols.append(jax.lax.slice(row, (0, b), (C, end), (1, stride)))
    pat = jnp.stack(cols, axis=1).reshape(C * f * f, ow)  # VMEM-resident
    acc = jnp.dot(w_ref[...], pat, preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + b_ref[0].astype(jnp.float32)[:, None]
    if has_res:
        acc = acc + r_ref[0].astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[0] = acc.astype(o_ref.dtype)


def conv_im2col(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, *,
                bk: int = 128, bias: jnp.ndarray | None = None,
                residual: jnp.ndarray | None = None, relu: bool = False,
                interpret: bool = False,
                fuse_store: bool | None = None) -> jnp.ndarray:
    """x: (C, H, W); w: (K, C, f, f) -> (K, oh, ow), valid padding.
    ``bias`` is (K,), ``residual`` is (K, oh, ow)."""
    C, H, W = x.shape
    K, _, f, _ = w.shape
    oh = (H - f) // stride + 1
    ow = (W - f) // stride + 1
    wm = w.reshape(K, C * f * f)
    fuse = (not interpret) if fuse_store is None else fuse_store
    bk = min(bk, K)
    Kp = -(-K // bk) * bk
    if Kp != K:                      # partial K tiles are undefined on TPU
        wm = jnp.pad(wm, ((0, Kp - K), (0, 0)))
    grid = (Kp // bk, oh)
    has_bias = fuse and bias is not None
    has_res = fuse and residual is not None

    def row_spec(a):
        return pl.BlockSpec((C, 1, W), lambda kb, i, a=a: (0, i * stride + a, 0))

    ins = [x] * f + [wm]
    in_specs = [row_spec(a) for a in range(f)] \
        + [pl.BlockSpec((bk, C * f * f), lambda kb, i: (kb, 0))]
    if has_bias:
        ins.append(jnp.pad(bias, (0, Kp - K))[None, :] if Kp != K
                   else bias[None, :])
        in_specs.append(pl.BlockSpec((1, bk), lambda kb, i: (0, kb)))
    if has_res:
        r = residual.transpose(1, 0, 2)              # (oh, K, ow)
        if Kp != K:
            r = jnp.pad(r, ((0, 0), (0, Kp - K), (0, 0)))
        ins.append(r)
        in_specs.append(pl.BlockSpec((1, bk, ow), lambda kb, i: (i, kb, 0)))
    out = pl.pallas_call(
        functools.partial(_conv_kernel, stride=stride, f=f, ow=ow,
                          has_bias=has_bias, has_res=has_res,
                          relu=fuse and relu),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bk, ow), lambda kb, i: (i, kb, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, grid[0] * bk, ow), x.dtype),
        interpret=interpret,
    )(*ins)
    out = out.transpose(1, 0, 2)[:K]
    if not fuse:
        out = _finish(out, bias, residual, relu, channel_axis=0)
    return out


def _conv_batch_kernel(*refs, stride: int, f: int, ow: int, has_bias: bool,
                       has_res: bool, relu: bool):
    x_rows = refs[:f]            # each (1, C, 1, W)
    it = iter(refs[f:])
    w_ref = next(it)             # (bk, C*f*f)
    b_ref = next(it) if has_bias else None
    r_ref = next(it) if has_res else None
    o_ref = next(it)             # (1, 1, bk, ow)
    C = x_rows[0].shape[1]
    cols = []
    for a in range(f):
        row = x_rows[a][0, :, 0, :]                       # (C, W)
        for b in range(f):
            end = b + (ow - 1) * stride + 1
            cols.append(jax.lax.slice(row, (0, b), (C, end), (1, stride)))
    pat = jnp.stack(cols, axis=1).reshape(C * f * f, ow)  # VMEM-resident
    acc = jnp.dot(w_ref[...], pat, preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + b_ref[0].astype(jnp.float32)[:, None]
    if has_res:
        acc = acc + r_ref[0, 0].astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[0, 0] = acc.astype(o_ref.dtype)


def conv_im2col_batch(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, *,
                      bk: int = 128, bias: jnp.ndarray | None = None,
                      residual: jnp.ndarray | None = None, relu: bool = False,
                      interpret: bool = False,
                      fuse_store: bool | None = None) -> jnp.ndarray:
    """x: (N, C, H, W); w: (K, C, f, f) -> (N, K, oh, ow), valid padding.
    Batch is the leading grid dimension: grid (N, K blocks, output rows).
    ``bias`` is (K,), ``residual`` is (N, K, oh, ow)."""
    N, C, H, W = x.shape
    K, _, f, _ = w.shape
    oh = (H - f) // stride + 1
    ow = (W - f) // stride + 1
    wm = w.reshape(K, C * f * f)
    fuse = (not interpret) if fuse_store is None else fuse_store
    bk = min(bk, K)
    Kp = -(-K // bk) * bk
    if Kp != K:                      # partial K tiles are undefined on TPU
        wm = jnp.pad(wm, ((0, Kp - K), (0, 0)))
    grid = (N, Kp // bk, oh)
    has_bias = fuse and bias is not None
    has_res = fuse and residual is not None

    def row_spec(a):
        return pl.BlockSpec((1, C, 1, W),
                            lambda n, kb, i, a=a: (n, 0, i * stride + a, 0))

    ins = [x] * f + [wm]
    in_specs = [row_spec(a) for a in range(f)] \
        + [pl.BlockSpec((bk, C * f * f), lambda n, kb, i: (kb, 0))]
    if has_bias:
        ins.append(jnp.pad(bias, (0, Kp - K))[None, :] if Kp != K
                   else bias[None, :])
        in_specs.append(pl.BlockSpec((1, bk), lambda n, kb, i: (0, kb)))
    if has_res:
        r = residual.transpose(0, 2, 1, 3)           # (N, oh, K, ow)
        if Kp != K:
            r = jnp.pad(r, ((0, 0), (0, 0), (0, Kp - K), (0, 0)))
        ins.append(r)
        in_specs.append(pl.BlockSpec((1, 1, bk, ow),
                                     lambda n, kb, i: (n, i, kb, 0)))
    out = pl.pallas_call(
        functools.partial(_conv_batch_kernel, stride=stride, f=f, ow=ow,
                          has_bias=has_bias, has_res=has_res,
                          relu=fuse and relu),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bk, ow), lambda n, kb, i: (n, i, kb, 0)),
        out_shape=jax.ShapeDtypeStruct((N, oh, grid[1] * bk, ow), x.dtype),
        interpret=interpret,
    )(*ins)
    out = out.transpose(0, 2, 1, 3)[:, :K]
    if not fuse:
        out = _finish(out, bias, residual, relu, channel_axis=1)
    return out
