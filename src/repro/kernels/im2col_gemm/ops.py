"""Jitted wrapper for the fused im2col+GEMM conv kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.common import default_interpret
from repro.kernels.im2col_gemm.im2col_gemm import conv_im2col, conv_im2col_batch

VARIANTS = {"conv-bk64": 64, "conv-bk128": 128, "conv-bk256": 256}


@partial(jax.jit, static_argnames=("stride", "variant", "interpret", "relu",
                                   "fuse_store"))
def conv_im2col_op(x, w, stride: int = 1, variant: str = "conv-bk128",
                   interpret: bool | None = None, bias=None, residual=None,
                   relu: bool = False, fuse_store: bool | None = None):
    interp = default_interpret() if interpret is None else interpret
    return conv_im2col(x, w, stride, bk=VARIANTS[variant], bias=bias,
                       residual=residual, relu=relu, interpret=interp,
                       fuse_store=fuse_store)


@partial(jax.jit, static_argnames=("stride", "variant", "interpret", "relu",
                                   "fuse_store"))
def conv_im2col_batch_op(x, w, stride: int = 1, variant: str = "conv-bk128",
                         interpret: bool | None = None, bias=None,
                         residual=None, relu: bool = False,
                         fuse_store: bool | None = None):
    """(N, C, H, W) batch through the fused conv — batch grid dimension."""
    interp = default_interpret() if interpret is None else interpret
    return conv_im2col_batch(x, w, stride, bk=VARIANTS[variant], bias=bias,
                             residual=residual, relu=relu, interpret=interp,
                             fuse_store=fuse_store)
