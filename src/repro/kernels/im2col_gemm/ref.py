"""Oracle for the fused conv kernel: the pure-jnp im2col primitive."""
import jax.numpy as jnp

from repro.primitives.conv import reference_conv


def conv_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    return reference_conv(x, w, stride)
