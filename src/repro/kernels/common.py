"""Shared kernel utilities."""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas kernels execute natively on TPU; everywhere else (this CPU
    container included) they run in interpret mode, which executes the kernel
    body in Python — bit-accurate for correctness validation."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
