"""Full Winograd conv: jnp transforms around the Pallas point-GEMM (the
compute-bound stage), generic over F(mxm, 3x3) via the shared transform
sets in ``primitives.conv``.

Epilogues (DESIGN.md §13): bias / residual / ReLU are applied right after
the inverse transform, inside the same jitted function — they cannot move
into the point-GEMM kernel (the transform is linear, ReLU is not; the
kernel's output lives in the transform domain), but fusing them here still
removes the separate elementwise pass over the activation at the plan
level.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.winograd.winograd import (winograd_point_gemm,
                                             winograd_point_gemm_batch)
from repro.primitives.conv import _WINO_SETS

VARIANTS = {"wino-128x128": (128, 128), "wino-256x128": (256, 128),
            "wino-128x256": (128, 256)}


def _epilogue(y, bias, residual, relu: bool, channel_axis: int):
    if bias is not None:
        shape = [1] * y.ndim
        shape[channel_axis] = bias.shape[0]
        y = y + bias.astype(y.dtype).reshape(shape)
    if residual is not None:
        y = y + residual.astype(y.dtype)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


@partial(jax.jit, static_argnames=("m", "bk", "bt", "bc", "relu", "interpret"))
def winograd_conv(x: jnp.ndarray, w: jnp.ndarray, *, m: int = 2,
                  bk: int = 128, bt: int = 128, bc: int = 128,
                  bias=None, residual=None, relu: bool = False,
                  interpret: bool | None = None) -> jnp.ndarray:
    """x: (C, H, W); w: (K, C, 3, 3) -> (K, H-2, W-2). Stride 1, F(mxm,3x3)."""
    AT, G, BT = (jnp.asarray(a, jnp.float32) for a in _WINO_SETS[(m, 3)])
    C, H, W = x.shape
    K = w.shape[0]
    n = m + 2
    oh, ow = H - 2, W - 2
    th, tw = -(-oh // m), -(-ow // m)
    ph, pw = (th - 1) * m + n, (tw - 1) * m + n
    xp = jnp.pad(x, ((0, 0), (0, ph - H), (0, pw - W)))
    rows = []
    for a in range(n):
        cols = [xp[:, a:a + (th - 1) * m + 1:m, b:b + (tw - 1) * m + 1:m]
                for b in range(n)]
        rows.append(jnp.stack(cols, -1))
    tiles = jnp.stack(rows, -2)                               # (C, th, tw, n, n)
    V = jnp.einsum("ap,cijpq,qb->abcij", BT, tiles.astype(jnp.float32), BT.T)
    V = V.reshape(n * n, C, th * tw)                          # (n², C, T)
    U = jnp.einsum("ar,kcrs,sb->abkc", G, w.astype(jnp.float32), G.T)
    U = U.reshape(n * n, K, C)

    interp = default_interpret() if interpret is None else interpret
    M = winograd_point_gemm(U, V.astype(U.dtype), bk=bk, bt=bt, bc=bc,
                            interpret=interp)                 # (n², K, T)
    M = M.reshape(n, n, K, th, tw)
    Y = jnp.einsum("ap,pqkij,qm->kiajm", AT, M, AT.T)         # (K, th, m, tw, m)
    y = Y.reshape(K, th * m, tw * m)[:, :oh, :ow]
    y = _epilogue(y, bias, residual, relu, channel_axis=0)
    return y.astype(x.dtype)


@partial(jax.jit, static_argnames=("m", "bk", "bt", "bc", "relu", "interpret"))
def winograd_conv_batch(x: jnp.ndarray, w: jnp.ndarray, *, m: int = 2,
                        bk: int = 128, bt: int = 128, bc: int = 128,
                        bias=None, residual=None, relu: bool = False,
                        interpret: bool | None = None) -> jnp.ndarray:
    """x: (N, C, H, W); w: (K, C, 3, 3) -> (N, K, H-2, W-2). Stride 1.
    Batched transforms around the batch-grid Pallas point-GEMM: U is
    transformed once and shared, only V carries the batch."""
    AT, G, BT = (jnp.asarray(a, jnp.float32) for a in _WINO_SETS[(m, 3)])
    N, C, H, W = x.shape
    K = w.shape[0]
    n = m + 2
    oh, ow = H - 2, W - 2
    th, tw = -(-oh // m), -(-ow // m)
    ph, pw = (th - 1) * m + n, (tw - 1) * m + n
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, ph - H), (0, pw - W)))
    rows = []
    for a in range(n):
        cols = [xp[:, :, a:a + (th - 1) * m + 1:m, b:b + (tw - 1) * m + 1:m]
                for b in range(n)]
        rows.append(jnp.stack(cols, -1))
    tiles = jnp.stack(rows, -2)                               # (N, C, th, tw, n, n)
    V = jnp.einsum("ap,ncijpq,qb->nabcij", BT, tiles.astype(jnp.float32), BT.T)
    V = V.reshape(N, n * n, C, th * tw)                       # (N, n², C, T)
    U = jnp.einsum("ar,kcrs,sb->abkc", G, w.astype(jnp.float32), G.T)
    U = U.reshape(n * n, K, C)

    interp = default_interpret() if interpret is None else interpret
    M = winograd_point_gemm_batch(U, V.astype(U.dtype), bk=bk, bt=bt, bc=bc,
                                  interpret=interp)           # (N, n², K, T)
    M = M.reshape(N, n, n, K, th, tw)
    Y = jnp.einsum("ap,npqkij,qm->nkiajm", AT, M, AT.T)       # (N, K, th, m, tw, m)
    y = Y.reshape(N, K, th * m, tw * m)[:, :, :oh, :ow]
    y = _epilogue(y, bias, residual, relu, channel_axis=1)
    return y.astype(x.dtype)


def winograd_conv_op(x: jnp.ndarray, w: jnp.ndarray,
                     variant: str = "wino-128x128",
                     interpret: bool | None = None) -> jnp.ndarray:
    """x: (C, H, W); w: (K, C, 3, 3) -> (K, H-2, W-2). Stride 1, F(2x2,3x3)."""
    bk, bt = VARIANTS[variant]
    return winograd_conv(x, w, m=2, bk=bk, bt=bt, interpret=interpret)


def winograd_conv_batch_op(x: jnp.ndarray, w: jnp.ndarray,
                           variant: str = "wino-128x128",
                           interpret: bool | None = None) -> jnp.ndarray:
    """x: (N, C, H, W); w: (K, C, 3, 3) -> (N, K, H-2, W-2). Stride 1."""
    bk, bt = VARIANTS[variant]
    return winograd_conv_batch(x, w, m=2, bk=bk, bt=bt, interpret=interpret)
