"""Full Winograd F(2x2,3x3) conv: jnp transforms around the Pallas
point-GEMM (the compute-bound stage)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.winograd.winograd import (winograd_point_gemm,
                                             winograd_point_gemm_batch)
from repro.primitives.conv import _WINO_SETS

VARIANTS = {"wino-128x128": (128, 128), "wino-256x128": (256, 128),
            "wino-128x256": (128, 256)}


@partial(jax.jit, static_argnames=("variant", "interpret"))
def winograd_conv_op(x: jnp.ndarray, w: jnp.ndarray,
                     variant: str = "wino-128x128",
                     interpret: bool | None = None) -> jnp.ndarray:
    """x: (C, H, W); w: (K, C, 3, 3) -> (K, H-2, W-2). Stride 1."""
    AT, G, BT = (jnp.asarray(a, jnp.float32) for a in _WINO_SETS[(2, 3)])
    C, H, W = x.shape
    K = w.shape[0]
    m, n = 2, 4
    oh, ow = H - 2, W - 2
    th, tw = -(-oh // m), -(-ow // m)
    ph, pw = (th - 1) * m + n, (tw - 1) * m + n
    xp = jnp.pad(x, ((0, 0), (0, ph - H), (0, pw - W)))
    rows = []
    for a in range(n):
        cols = [xp[:, a:a + (th - 1) * m + 1:m, b:b + (tw - 1) * m + 1:m]
                for b in range(n)]
        rows.append(jnp.stack(cols, -1))
    tiles = jnp.stack(rows, -2)                               # (C, th, tw, n, n)
    V = jnp.einsum("ap,cijpq,qb->abcij", BT, tiles.astype(jnp.float32), BT.T)
    V = V.reshape(n * n, C, th * tw)                          # (16, C, T)
    U = jnp.einsum("ar,kcrs,sb->abkc", G, w.astype(jnp.float32), G.T)
    U = U.reshape(n * n, K, C)

    bk, bt = VARIANTS[variant]
    interp = default_interpret() if interpret is None else interpret
    M = winograd_point_gemm(U, V.astype(U.dtype), bk=bk, bt=bt,
                            interpret=interp)                 # (16, K, T)
    M = M.reshape(n, n, K, th, tw)
    Y = jnp.einsum("ap,pqkij,qm->kiajm", AT, M, AT.T)         # (K, th, m, tw, m)
    y = Y.reshape(K, th * m, tw * m)
    return y[:, :oh, :ow].astype(x.dtype)


@partial(jax.jit, static_argnames=("variant", "interpret"))
def winograd_conv_batch_op(x: jnp.ndarray, w: jnp.ndarray,
                           variant: str = "wino-128x128",
                           interpret: bool | None = None) -> jnp.ndarray:
    """x: (N, C, H, W); w: (K, C, 3, 3) -> (N, K, H-2, W-2). Stride 1.
    Batched transforms around the batch-grid Pallas point-GEMM: U is
    transformed once and shared, only V carries the batch."""
    AT, G, BT = (jnp.asarray(a, jnp.float32) for a in _WINO_SETS[(2, 3)])
    N, C, H, W = x.shape
    K = w.shape[0]
    m, n = 2, 4
    oh, ow = H - 2, W - 2
    th, tw = -(-oh // m), -(-ow // m)
    ph, pw = (th - 1) * m + n, (tw - 1) * m + n
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, ph - H), (0, pw - W)))
    rows = []
    for a in range(n):
        cols = [xp[:, :, a:a + (th - 1) * m + 1:m, b:b + (tw - 1) * m + 1:m]
                for b in range(n)]
        rows.append(jnp.stack(cols, -1))
    tiles = jnp.stack(rows, -2)                               # (N, C, th, tw, n, n)
    V = jnp.einsum("ap,ncijpq,qb->nabcij", BT, tiles.astype(jnp.float32), BT.T)
    V = V.reshape(N, n * n, C, th * tw)                       # (N, 16, C, T)
    U = jnp.einsum("ar,kcrs,sb->abkc", G, w.astype(jnp.float32), G.T)
    U = U.reshape(n * n, K, C)

    bk, bt = VARIANTS[variant]
    interp = default_interpret() if interpret is None else interpret
    M = winograd_point_gemm_batch(U, V.astype(U.dtype), bk=bk, bt=bt,
                                  interpret=interp)           # (N, 16, K, T)
    M = M.reshape(N, n, n, K, th, tw)
    Y = jnp.einsum("ap,npqkij,qm->nkiajm", AT, M, AT.T)       # (N, K, th, m, tw, m)
    y = Y.reshape(N, K, th * m, tw * m)
    return y[:, :, :oh, :ow].astype(x.dtype)
