"""Oracles for the Winograd kernel: point-GEMM einsum + full conv."""
import jax.numpy as jnp

from repro.primitives.conv import reference_conv


def point_gemm_ref(u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("pkc,pct->pkt", u.astype(jnp.float32),
                      v.astype(jnp.float32)).astype(u.dtype)


def conv3x3_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return reference_conv(x, w, 1)
