"""Winograd F(2x2, 3x3) convolution: Pallas batched point-GEMM.

Winograd's hot spot is the batched per-tile-point GEMM
``M[p] = U[p] @ V[p]`` for the 16 transform points p — on TPU this is 16
MXU GEMMs of shape (K, C) x (C, T). The input/output transforms are cheap
bandwidth-bound 4x4 stencils handled by XLA (ops.py); the kernel owns the
compute-bound stage, tiling (K, T) per point with the C reduction innermost.

``winograd_point_gemm_batch`` adds the request batch as an explicit leading
grid dimension over a shared transformed-weight tensor U — the compiled
serving plan's shape, where only V (the input transform) carries the batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _point_gemm_kernel(u_ref, v_ref, o_ref, acc_ref, *, n_c: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(u_ref[0], v_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_c - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def winograd_point_gemm(u: jnp.ndarray, v: jnp.ndarray, *, bk: int = 128,
                        bt: int = 128, bc: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """u: (P, K, C); v: (P, C, T) -> (P, K, T) — P parallel GEMMs
    (P = (m+r-1)^2 = 16 for F(2x2,3x3))."""
    P, K, C = u.shape
    T = v.shape[2]
    bk, bt, bc = min(bk, K), min(bt, T), min(bc, C)
    # pad to block multiples (partial tiles are undefined on TPU)
    Kp, Tp, Cp = -(-K // bk) * bk, -(-T // bt) * bt, -(-C // bc) * bc
    if (Kp, Cp) != (K, C):
        u = jnp.pad(u, ((0, 0), (0, Kp - K), (0, Cp - C)))
    if (Cp, Tp) != (C, T):
        v = jnp.pad(v, ((0, 0), (0, Cp - C), (0, Tp - T)))
    grid = (P, Kp // bk, Tp // bt, Cp // bc)
    out = pl.pallas_call(
        functools.partial(_point_gemm_kernel, n_c=grid[3]),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bk, bc), lambda p, i, j, c: (p, i, c)),
                  pl.BlockSpec((1, bc, bt), lambda p, i, j, c: (p, c, j))],
        out_specs=pl.BlockSpec((1, bk, bt), lambda p, i, j, c: (p, i, j)),
        out_shape=jax.ShapeDtypeStruct((P, Kp, Tp), u.dtype),
        scratch_shapes=[pltpu.VMEM((bk, bt), jnp.float32)],
        interpret=interpret,
    )(u, v)
    return out[:, :K, :T]


def _point_gemm_batch_kernel(u_ref, v_ref, o_ref, acc_ref, *, n_c: int):
    @pl.when(pl.program_id(4) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(u_ref[0], v_ref[0, 0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(4) == n_c - 1)
    def _store():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


def winograd_point_gemm_batch(u: jnp.ndarray, v: jnp.ndarray, *, bk: int = 128,
                              bt: int = 128, bc: int = 128,
                              interpret: bool = False) -> jnp.ndarray:
    """u: (P, K, C) shared weights; v: (N, P, C, T) batched input transform
    -> (N, P, K, T). Grid (N, P, K tiles, T tiles, C tiles) — the batch is
    an explicit grid dimension, U blocks are revisited per image."""
    P, K, C = u.shape
    N, P2, C2, T = v.shape
    assert (P, C) == (P2, C2), (u.shape, v.shape)
    bk, bt, bc = min(bk, K), min(bt, T), min(bc, C)
    Kp, Tp, Cp = -(-K // bk) * bk, -(-T // bt) * bt, -(-C // bc) * bc
    if (Kp, Cp) != (K, C):
        u = jnp.pad(u, ((0, 0), (0, Kp - K), (0, Cp - C)))
    if (Cp, Tp) != (C, T):
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Cp - C), (0, Tp - T)))
    grid = (N, P, Kp // bk, Tp // bt, Cp // bc)
    out = pl.pallas_call(
        functools.partial(_point_gemm_batch_kernel, n_c=grid[4]),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bk, bc), lambda n, p, i, j, c: (p, i, c)),
                  pl.BlockSpec((1, 1, bc, bt), lambda n, p, i, j, c: (n, p, c, j))],
        out_specs=pl.BlockSpec((1, 1, bk, bt), lambda n, p, i, j, c: (n, p, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, P, Kp, Tp), u.dtype),
        scratch_shapes=[pltpu.VMEM((bk, bt), jnp.float32)],
        interpret=interpret,
    )(u, v)
    return out[:, :, :K, :T]
