"""Tiled MXU matmul Pallas kernel with configurable block shapes.

The (bm, bk, bn) block configuration is the TPU analogue of the paper's
"primitive variants" (DESIGN.md §2.2): each config is a selectable
implementation whose cost the performance model predicts, and the autotune
pipeline PBQP-selects per matmul site. Blocks tile VMEM; the inner jnp.dot
maps onto the 128x128 MXU, so hardware-aligned configs keep bm/bk/bn at
multiples of 128.

Grid is (M/bm, N/bn, K/bk) with the K dimension innermost (sequential on
TPU), accumulating into an f32 VMEM scratch tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _matmul_batch_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], y_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def matmul_batch(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128,
                 bk: int = 128, bn: int = 128, out_dtype=None,
                 interpret: bool = False) -> jnp.ndarray:
    """Batched GEMM x: (B, M, K) @ y: (B, K, N) -> (B, M, N) with the batch
    as an explicit leading grid dimension (one (M, N, K) tile walk per image;
    the plan executor's whole-batch GEMM shape). Same edge-tile padding rules
    as ``matmul``."""
    B, m, k = x.shape
    B2, k2, n = y.shape
    assert (B, k) == (B2, k2), (x.shape, y.shape)
    out_dtype = out_dtype or x.dtype
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    mp, kp, np_ = -(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, 0), (0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        y = jnp.pad(y, ((0, 0), (0, kp - k), (0, np_ - n)))
    grid = (B, mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_batch_kernel, n_k=grid[3]),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bm, bk), lambda b, i, j, kk: (b, i, kk)),
                  pl.BlockSpec((1, bk, bn), lambda b, i, j, kk: (b, kk, j))],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
    return out[:, :m, :n]


def matmul(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128, bk: int = 128,
           bn: int = 128, out_dtype=None, interpret: bool = False) -> jnp.ndarray:
    """x: (M, K) @ y: (K, N) -> (M, N). Shapes need not divide blocks
    (Pallas masks edge tiles; zero-fill is exact for the K reduction)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    out_dtype = out_dtype or x.dtype
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    # pad to block multiples: partial edge tiles are undefined on TPU (and
    # NaN-poisoned in interpret mode); zero padding is exact for the K
    # reduction and sliced away on M/N.
    mp, kp, np_ = -(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        y = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
    return out[:m, :n]
