"""Tiled MXU matmul Pallas kernel with configurable block shapes.

The (bm, bk, bn) block configuration is the TPU analogue of the paper's
"primitive variants" (DESIGN.md §2.2): each config is a selectable
implementation whose cost the performance model predicts, and the autotune
pipeline PBQP-selects per matmul site. Blocks tile VMEM; the inner jnp.dot
maps onto the 128x128 MXU, so hardware-aligned configs keep bm/bk/bn at
multiples of 128.

Grid is (M/bm, N/bn, K/bk) with the K dimension innermost (sequential on
TPU), accumulating into an f32 VMEM scratch tile.

Epilogues (DESIGN.md §13): an optional bias (per output row), residual
(same shape as the output) and ReLU can be fused into the kernel's store
step — the output tile is finished in VMEM before the single HBM writeback,
so the unfused read-modify-write round trip over the activation never
happens. In interpret mode the epilogue is applied once at the wrapper
level instead (same jit, identical numerics): the interpreter executes the
kernel body per grid step, so per-tile epilogue ops would multiply
interpreter overhead while saving no memory traffic. ``fuse_store`` forces
the in-kernel path (tests exercise it under interpret).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _finish(acc, bias_blk, res_blk, relu: bool):
    """Shared epilogue: bias -> residual -> ReLU on an f32 (bm, bn) tile."""
    if bias_blk is not None:
        acc = acc + bias_blk.astype(jnp.float32)[:, None]
    if res_blk is not None:
        acc = acc + res_blk.astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc


def _matmul_kernel(*refs, n_k: int, has_bias: bool, has_res: bool, relu: bool):
    it = iter(refs)
    x_ref, y_ref = next(it), next(it)
    b_ref = next(it) if has_bias else None
    r_ref = next(it) if has_res else None
    o_ref, acc_ref = next(it), next(it)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        acc = _finish(acc_ref[...], b_ref[0] if has_bias else None,
                      r_ref[...] if has_res else None, relu)
        o_ref[...] = acc.astype(o_ref.dtype)


def _matmul_batch_kernel(*refs, n_k: int, has_bias: bool, has_res: bool,
                         relu: bool):
    it = iter(refs)
    x_ref, y_ref = next(it), next(it)
    b_ref = next(it) if has_bias else None
    r_ref = next(it) if has_res else None
    o_ref, acc_ref = next(it), next(it)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], y_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _store():
        acc = _finish(acc_ref[...], b_ref[0] if has_bias else None,
                      r_ref[0] if has_res else None, relu)
        o_ref[0] = acc.astype(o_ref.dtype)


def matmul_batch(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128,
                 bk: int = 128, bn: int = 128, out_dtype=None,
                 bias: jnp.ndarray | None = None,
                 residual: jnp.ndarray | None = None, relu: bool = False,
                 interpret: bool = False,
                 fuse_store: bool | None = None) -> jnp.ndarray:
    """Batched GEMM x: (B, M, K) @ y: (B, K, N) -> (B, M, N) with the batch
    as an explicit leading grid dimension (one (M, N, K) tile walk per image;
    the plan executor's whole-batch GEMM shape). Same edge-tile padding rules
    as ``matmul``. ``bias`` is (M,), ``residual`` is (B, M, N)."""
    B, m, k = x.shape
    B2, k2, n = y.shape
    assert (B, k) == (B2, k2), (x.shape, y.shape)
    out_dtype = out_dtype or x.dtype
    fuse = (not interpret) if fuse_store is None else fuse_store
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    mp, kp, np_ = -(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, 0), (0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        y = jnp.pad(y, ((0, 0), (0, kp - k), (0, np_ - n)))
    grid = (B, mp // bm, np_ // bn, kp // bk)
    has_bias = fuse and bias is not None
    has_res = fuse and residual is not None
    ins = [x, y]
    in_specs = [pl.BlockSpec((1, bm, bk), lambda b, i, j, kk: (b, i, kk)),
                pl.BlockSpec((1, bk, bn), lambda b, i, j, kk: (b, kk, j))]
    if has_bias:
        ins.append(jnp.pad(bias, (0, mp - m))[None, :] if mp != m
                   else bias[None, :])
        in_specs.append(pl.BlockSpec((1, bm), lambda b, i, j, kk: (0, i)))
    if has_res:
        r = residual
        if (mp, np_) != (m, n):
            r = jnp.pad(r, ((0, 0), (0, mp - m), (0, np_ - n)))
        ins.append(r)
        in_specs.append(pl.BlockSpec((1, bm, bn), lambda b, i, j, kk: (b, i, j)))
    out = pl.pallas_call(
        functools.partial(_matmul_batch_kernel, n_k=grid[3], has_bias=has_bias,
                          has_res=has_res, relu=fuse and relu),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*ins)
    out = out[:, :m, :n]
    if not fuse:
        out = _finish(out, bias, residual, relu).astype(out_dtype)
    return out


def matmul(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128, bk: int = 128,
           bn: int = 128, out_dtype=None, bias: jnp.ndarray | None = None,
           residual: jnp.ndarray | None = None, relu: bool = False,
           interpret: bool = False,
           fuse_store: bool | None = None) -> jnp.ndarray:
    """x: (M, K) @ y: (K, N) -> (M, N). Shapes need not divide blocks
    (Pallas masks edge tiles; zero-fill is exact for the K reduction).
    ``bias`` is (M,), ``residual`` is (M, N)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    out_dtype = out_dtype or x.dtype
    fuse = (not interpret) if fuse_store is None else fuse_store
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    # pad to block multiples: partial edge tiles are undefined on TPU (and
    # NaN-poisoned in interpret mode); zero padding is exact for the K
    # reduction and sliced away on M/N.
    mp, kp, np_ = -(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        y = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    has_bias = fuse and bias is not None
    has_res = fuse and residual is not None
    ins = [x, y]
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))]
    if has_bias:
        ins.append(jnp.pad(bias, (0, mp - m))[None, :] if mp != m
                   else bias[None, :])
        in_specs.append(pl.BlockSpec((1, bm), lambda i, j, kk: (0, i)))
    if has_res:
        r = residual
        if (mp, np_) != (m, n):
            r = jnp.pad(r, ((0, mp - m), (0, np_ - n)))
        ins.append(r)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2], has_bias=has_bias,
                          has_res=has_res, relu=fuse and relu),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*ins)
    out = out[:m, :n]
    if not fuse:
        out = _finish(out, bias, residual, relu).astype(out_dtype)
    return out
