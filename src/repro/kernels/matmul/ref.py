"""Pure-jnp oracle for the matmul kernel."""
import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, y: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(out_dtype)
