"""Jitted wrapper + variant registry for the tiled matmul kernel.

``VARIANTS`` is the kernel-config pool the autotune feature (repro.core.
autotune) selects from — the TPU analogue of the paper's primitive table.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax

from repro.kernels.common import default_interpret
from repro.kernels.matmul.matmul import matmul, matmul_batch

# (bm, bk, bn) pool: MXU-aligned tilings trading VMEM footprint for reuse.
VARIANTS: Dict[str, Tuple[int, int, int]] = {
    "mm-128x128x128": (128, 128, 128),
    "mm-256x128x128": (256, 128, 128),
    "mm-128x128x256": (128, 128, 256),
    "mm-256x128x256": (256, 128, 256),
    "mm-512x128x128": (512, 128, 128),
    "mm-128x256x128": (128, 256, 128),
    "mm-256x256x256": (256, 256, 256),
    "mm-512x256x256": (512, 256, 256),
}


@partial(jax.jit, static_argnames=("variant", "interpret", "relu", "fuse_store"))
def matmul_op(x, y, variant: str = "mm-128x128x128", interpret: bool | None = None,
              bias=None, residual=None, relu: bool = False,
              fuse_store: bool | None = None):
    bm, bk, bn = VARIANTS[variant]
    interp = default_interpret() if interpret is None else interpret
    return matmul(x, y, bm=bm, bk=bk, bn=bn, bias=bias, residual=residual,
                  relu=relu, interpret=interp, fuse_store=fuse_store)


@partial(jax.jit, static_argnames=("variant", "interpret", "relu", "fuse_store"))
def matmul_batch_op(x, y, variant: str = "mm-128x128x128",
                    interpret: bool | None = None,
                    bias=None, residual=None, relu: bool = False,
                    fuse_store: bool | None = None):
    """(B, M, K) @ (B, K, N) with the batch as an explicit grid dimension."""
    bm, bk, bn = VARIANTS[variant]
    interp = default_interpret() if interpret is None else interpret
    return matmul_batch(x, y, bm=bm, bk=bk, bn=bn, bias=bias, residual=residual,
                        relu=relu, interpret=interp, fuse_store=fuse_store)


def vmem_bytes(variant: str, dtype_bytes: int = 2) -> int:
    """Working-set estimate per grid step — used as an autotune feature."""
    bm, bk, bn = VARIANTS[variant]
    return dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn
