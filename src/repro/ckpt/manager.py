"""Fault-tolerant checkpointing.

Design goals (DESIGN.md §5):
  * **Atomicity** — write to ``<dir>/tmp.<step>.<pid>`` then ``os.replace``
    into place, so a killed writer never leaves a readable-but-corrupt
    checkpoint. A ``manifest.json`` with a content checksum is written last;
    a checkpoint without a valid manifest is ignored on restore.
  * **Keep-k GC** — old steps are garbage-collected after a successful save.
  * **Resume-latest** — ``latest_step()``/``restore_latest()`` let a
    restarted launcher (node failure, preemption) continue from the last
    complete checkpoint.
  * **Elastic re-shard** — arrays are saved host-replicated (fully gathered,
    numpy). On restore the caller supplies target shardings; arrays are
    ``jax.device_put`` to them, so the mesh shape may differ between save and
    restore (elastic scaling). For 1000+-node runs one would write per-shard
    files (OCDBT-style); the manifest format has a ``layout`` field reserved
    for that extension.

Pytrees are flattened with ``jax.tree_util.tree_flatten_with_path`` so the
on-disk format is stable, named, and partially restorable.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None) -> str:
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names = [_path_str(p) for p, _ in leaves]
        arrays = {}
        for name, (_, leaf) in zip(names, leaves):
            arrays[name] = np.asarray(jax.device_get(leaf))

        tmp = os.path.join(self.directory, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(self.directory, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **{k.replace("/", "|"): v for k, v in arrays.items()})
        checksum = _file_sha256(npz_path)
        manifest = {
            "step": step,
            "time": time.time(),
            "names": names,
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "checksum": checksum,
            "layout": "replicated-npz-v1",
            "extra": extra or {},
        }
        # manifest written LAST: its presence marks the checkpoint complete.
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    # -- restore --------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            m = _STEP_RE.match(d)
            if m and self._valid(os.path.join(self.directory, d)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding
        (or None) — enables elastic re-shard onto a new mesh."""
        d = os.path.join(self.directory, f"step_{step}")
        if not self._valid(d):
            raise FileNotFoundError(f"no valid checkpoint at step {step}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            data = {k.replace("|", "/"): z[k] for k in z.files}
        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        out = []
        for (path, leaf), shd in zip(leaves, shard_leaves):
            name = _path_str(path)
            if name not in data:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            arr = data[name]
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.directory, f"step_{step}", "manifest.json")) as f:
            return json.load(f)

    # -- internals ------------------------------------------------------------
    def _valid(self, d: str) -> bool:
        man = os.path.join(d, "manifest.json")
        npz = os.path.join(d, "arrays.npz")
        if not (os.path.exists(man) and os.path.exists(npz)):
            return False
        try:
            with open(man) as f:
                m = json.load(f)
            return m.get("checksum") == _file_sha256(npz)
        except (json.JSONDecodeError, OSError):
            return False

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)
        # remove stale tmp dirs from crashed writers
        for d in os.listdir(self.directory):
            if d.startswith("tmp."):
                full = os.path.join(self.directory, d)
                if time.time() - os.path.getmtime(full) > 3600:
                    shutil.rmtree(full, ignore_errors=True)


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
