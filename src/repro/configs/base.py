"""Architecture configuration schema + registry.

One module per assigned architecture lives in ``repro.configs.<id>`` and
exposes ``CONFIG``; they register themselves here. ``ArchConfig.reduced()``
returns a tiny same-family config for CPU smoke tests (the full configs are
exercised only via the dry-run's ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, List, Optional

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig
from repro.models.components import MLADims


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    act: str = "silu"
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # fraction of head_dim rotated (chatglm3: 0.5)
    qkv_bias: bool = False
    attn_kind: str = "gqa"           # gqa | mla | none
    mla: Optional[MLADims] = None
    window: Optional[int] = None     # sliding-window size (mixtral / gemma2 local)
    layer_pattern: str = "global"    # "global" | "alt_local_global"
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    post_norms: bool = False         # gemma2 post-attn/post-mlp norms
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: Optional[int] = None   # zamba2 shared block period
    kind: str = "decoder"            # decoder | encdec
    n_enc_layers: int = 0
    prefix_tokens: int = 0           # vlm/audio stub frontend tokens
    tie_embeddings: bool = True
    norm: str = "rmsnorm"            # rmsnorm | rmsnorm1p (gemma) | layernorm
    pos: str = "rope"                # rope | learned | none
    max_position: int = 524288       # learned-pos table size
    norm_eps: float = 1e-6
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunks: int = 8
    supports_long_decode: bool = False
    source: str = ""                 # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // max(self.n_heads, 1)

    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attn_kind == "gqa":
            per_layer += d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
                         + self.n_heads * self.hd * d
        elif self.attn_kind == "mla":
            m = self.mla
            qk = m.qk_nope + m.qk_rope
            per_layer += d * m.q_lora + m.q_lora * self.n_heads * qk \
                         + d * m.kv_lora + d * m.qk_rope \
                         + m.kv_lora * self.n_heads * (m.qk_nope + m.v_head) \
                         + self.n_heads * m.v_head * d
        if self.moe is not None:
            per_layer += d * self.moe.n_experts * self.moe.d_ff * 3 + d * self.moe.n_experts
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff
        if self.ssm is not None:
            din = self.ssm.d_inner(d)
            gn = self.ssm.n_groups * self.ssm.d_state
            H = self.ssm.n_heads(d)
            ssm_l = d * (2 * din + 2 * gn + H) + din * d + self.ssm.d_conv * (din + 2 * gn)
            if self.hybrid_attn_every:
                n_ssm = L
                shared = d * self.n_heads * self.hd * 2 + 2 * d * self.n_kv_heads * self.hd \
                         + 3 * d * self.d_ff
                return emb + n_ssm * ssm_l + shared
            return emb + L * ssm_l
        total = emb + L * per_layer
        if self.kind == "encdec":
            # encoder layers + decoder cross-attention
            enc = self.n_enc_layers * (4 * d * d + 2 * d * self.d_ff)
            cross = L * 4 * d * d
            total += enc + cross
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = L * (d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd
                    + self.n_heads * self.hd * d)
        moe = L * (d * self.moe.top_k * self.moe.d_ff * 3 + d * self.moe.n_experts)
        return emb + attn + moe

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke", n_layers=min(self.n_layers, 4) if not self.hybrid_attn_every else 4,
            d_model=64, n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) or 2,
            d_ff=128 if self.d_ff else 0, vocab=256, head_dim=16,
            loss_chunks=2, remat=False, param_dtype=jnp.float32,
        )
        if self.moe is not None:
            # dropless at smoke scale so incremental decode matches the
            # batched forward exactly (capacity drops are batch-dependent)
            kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_ff=32,
                                  capacity_factor=8.0)
            kw["d_ff"] = 0
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, headdim=16, expand=2, chunk=8,
                                  n_groups=1, d_conv=self.ssm.d_conv)
            kw["d_ff"] = self.d_ff and 128
        if self.mla is not None:
            kw["mla"] = MLADims(q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8, v_head=16)
        if self.hybrid_attn_every:
            kw["hybrid_attn_every"] = 2
        if self.kind == "encdec":
            kw["n_enc_layers"] = 2
        if self.window is not None:
            kw["window"] = 16
        return dataclasses.replace(self, **kw)


ASSIGNED_ARCHS = (
    "internvl2_1b", "zamba2_2_7b", "whisper_medium", "minicpm3_4b",
    "llama3_405b", "gemma2_27b", "chatglm3_6b", "qwen3_moe_30b_a3b",
    "mixtral_8x7b", "mamba2_2_7b",
)

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        importlib.import_module(f"repro.configs.{key}")
    return _REGISTRY[key]


def all_assigned() -> List[ArchConfig]:
    return [get(n) for n in ASSIGNED_ARCHS]
