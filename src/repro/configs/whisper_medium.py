"""Whisper-medium — encoder-decoder; conv frame frontend stubbed
(input_specs() provides precomputed frame embeddings). [arXiv:2212.04356].
LayerNorm + learned positions per the original; full attention, so the
long_500k shape is skipped (DESIGN.md §4)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper_medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, head_dim=64, kind="encdec", n_enc_layers=24,
    act="gelu", norm="layernorm", pos="learned", rope_theta=0.0,
    tie_embeddings=True, max_position=65536,
    source="arXiv:2212.04356 (openai/whisper-medium)",
))
