"""ChatGLM3-6B — GQA kv=2, partial (half-dim '2d') RoPE, qkv bias.
[arXiv:2406.12793; hf:THUDM/chatglm3-6b]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chatglm3_6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=65024, head_dim=128, rope_fraction=0.5, qkv_bias=True,
    tie_embeddings=False,
    source="arXiv:2406.12793 / hf:THUDM/chatglm3-6b",
))
