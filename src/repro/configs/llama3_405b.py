"""Llama-3.1-405B — dense GQA, 128k vocab. [arXiv:2407.21783].
Full attention: long_500k skipped. Training cell defaults to Adafactor +
ZeRO-3 so optimizer state fits v5e HBM (DESIGN.md §4, EXPERIMENTS §Dry-run)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3_405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab=128256, head_dim=128, rope_theta=500000.0, tie_embeddings=False,
    source="arXiv:2407.21783",
))
