"""Mamba2-2.7B — attention-free SSD (state-space duality).
[arXiv:2405.21060]. O(1)-state decode: long_500k RUNS."""
from repro.configs.base import ArchConfig, register
from repro.models.ssm import SSMConfig

CONFIG = register(ArchConfig(
    name="mamba2_2_7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, attn_kind="none",
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, chunk=256),
    supports_long_decode=True,
    source="arXiv:2405.21060",
))
