"""InternVL2-1B — InternViT frontend (stubbed) + Qwen2-0.5B LM backbone.
[arXiv:2404.16821; hf]. Frontend supplies 256 patch embeddings via
input_specs(); the backbone is the assigned 24L/896/14H(kv2)/4864/151655."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2_1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151655, head_dim=64, qkv_bias=True, rope_theta=1e6,
    prefix_tokens=256, tie_embeddings=True,
    source="arXiv:2404.16821 / hf:OpenGVLab/InternVL2-1B (Qwen2-0.5B backbone)",
))
