"""Zamba2-2.7B — Mamba2 backbone with a shared attention(+MLP) block applied
every 6 SSM layers (weights shared across applications; per-invocation LoRA
omitted, DESIGN.md §10). [arXiv:2411.15242; hf]. Shared attention uses a
4096-token sliding window so the 500k-decode shape is serveable (§10)."""
from repro.configs.base import ArchConfig, register
from repro.models.ssm import SSMConfig

CONFIG = register(ArchConfig(
    name="zamba2_2_7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=80,
    ssm=SSMConfig(d_state=64, headdim=64, expand=2, chunk=256),
    hybrid_attn_every=6, window=4096, supports_long_decode=True,
    source="arXiv:2411.15242 / hf:Zyphra/Zamba2-2.7B",
))
