"""Mixtral-8x7B — 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]. SWA bounds the decode KV working set, so the long_500k
cell RUNS for this arch (DESIGN.md §4)."""
from repro.configs.base import ArchConfig, register
from repro.models.moe import MoEConfig

CONFIG = register(ArchConfig(
    name="mixtral_8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=0,
    vocab=32000, head_dim=128, rope_theta=1e6, window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=14336),
    supports_long_decode=True,
    source="arXiv:2401.04088",
))
