"""Qwen3-30B-A3B — MoE, 128 experts top-8, per-expert ffn 768, GQA kv=4,
head_dim 128. [hf:Qwen/Qwen3-30B-A3B]. Expert axis shards over 'model'
(expert parallelism); q/k-norm omitted (noted in DESIGN.md §10)."""
from repro.configs.base import ArchConfig, register
from repro.models.moe import MoEConfig

CONFIG = register(ArchConfig(
    name="qwen3_moe_30b_a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=0,
    vocab=151936, head_dim=128, rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768),
    source="hf:Qwen/Qwen3-30B-A3B",
))
