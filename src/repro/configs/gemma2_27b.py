"""Gemma2-27B — alternating local(4096)/global attention, logit softcaps
(attn 50, final 30), gemma-style (1+scale) RMSNorm with post-norms,
head_dim 128 (attention width 4096 != d_model 4608). [arXiv:2408.00118; hf].
Global layers are full attention: long_500k skipped."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma2_27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab=256000, head_dim=128, act="gelu", norm="rmsnorm1p",
    layer_pattern="alt_local_global", window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    source="arXiv:2408.00118 / hf:google/gemma-2-27b",
))
