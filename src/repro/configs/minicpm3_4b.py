"""MiniCPM3-4B — dense with Multi-head Latent Attention.
[hf:openbmb/MiniCPM3-4B]. MLA dims from the reference config:
q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32, v_head 64; decode caches
the compressed latent (288 floats/token)."""
from repro.configs.base import ArchConfig, register
from repro.models.components import MLADims

CONFIG = register(ArchConfig(
    name="minicpm3_4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, attn_kind="mla",
    mla=MLADims(q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64),
    source="hf:openbmb/MiniCPM3-4B",
))
