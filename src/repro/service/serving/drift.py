"""Calibration-drift detection and serving telemetry (DESIGN.md §8.3, §8.5).

The perf model predicts per-image runtime on the platform it was calibrated
for; the server observes per-image runtime on the machine actually executing
plans. Those live on different absolute scales (a simulated-arm model serves
on a real CPU), so raw observed/predicted ratios mean nothing — what carries
signal is the ratio *moving*. Per (network, generation) the monitor:

  1. learns a **reference** log-ratio from the first ``calib_obs``
     observations (the platform-to-host scale at calibration time),
  2. tracks an **EWMA** of the log-ratio afterwards,
  3. flags an **excursion** when ``|ewma - reference| > log(threshold)``.

``observe`` returns True exactly once per excursion — the trigger for one
background recalibration (``platform.calibrate`` on fresh measurements +
re-select + ``hot_swap``). The excursion latch clears only when the ratio
returns inside threshold/2 (hysteresis) or the generation changes (the swap
resets the stats, because the new model has a new prediction scale).

Per-observation log-ratios are clamped to ±``clamp`` so a single pathological
dispatch (GC pause, page fault storm) cannot fake a sustained drift.

Beyond detection, the monitor is the serving-telemetry sink:

* **Observation buffer** (``record`` via ``observe(batch=...)``): every
  cleanly-timed dispatch (jit-compile dispatches excluded by the server) is
  one free measurement of the drifted platform. A bounded per-network deque
  keeps ``(batch bucket, clamped log-ratio, timestamp)``; ``attributed()``
  turns it into per-layer-config runtimes (see below) so drift-triggered
  recalibration can calibrate from served traffic instead of paying
  ``measure_sample`` profiling.
* **Window caps** (``observe_wait``): per-batch queueing waits feed a p99
  estimate; when it exceeds the latency budget the monitor halves the
  network's batch-window cap (``window_scale``), and doubles it back once
  p99 drops under half the budget — load-adaptive deadline batching.

Attribution: a dispatch times the *whole* compiled plan, not one layer. The
model's per-layer predictions give the split: a dispatch observed at drift
``exp(δ)`` relative to the calibration reference contributes
``predicted_j * exp(δ)`` for every assigned layer config j. δ is estimated
per batch bucket with an exponentially-weighted mean of the buffered
log-ratios minus the reference, so (a) fresh post-drift entries dominate a
buffer that still holds pre-drift history, and (b) the sample stays in the
*model's* prediction scale — mixing cleanly with freshly profiled top-up
rows instead of smuggling in the serving host's absolute clock.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

# window-cap adaptation: adjust at most every WAIT_EVERY recorded waits once
# WAIT_MIN_OBS samples exist; the cap never shrinks below MIN_WINDOW_SCALE
WAIT_MIN_OBS = 16
WAIT_EVERY = 32
MIN_WINDOW_SCALE = 1.0 / 16.0


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """The served network's assigned layer configs and their model-predicted
    per-image runtimes — the attribution key for turning whole-plan dispatch
    timings into per-layer observations."""

    feats: np.ndarray              # (L, 5) conv-layer (k, c, im, s, f) rows
    columns: Tuple[str, ...]       # (L,) assigned primitive per layer
    predicted: np.ndarray          # (L,) model-predicted per-image seconds

    def __post_init__(self):
        if not (len(self.feats) == len(self.columns) == len(self.predicted)):
            raise ValueError("feats/columns/predicted lengths differ")


@dataclasses.dataclass(frozen=True)
class ServedObservation:
    """One cleanly-timed dispatch: its pow2 batch bucket, the clamped
    log(observed/predicted) per-image ratio, and when it was recorded."""

    batch: int
    log_r: float
    t: float


@dataclasses.dataclass
class DriftStats:
    """EWMA state for one (network, generation)."""
    generation: int
    n: int = 0                         # observations consumed
    ref_log: float = 0.0               # reference log-ratio (after calib)
    ewma_log: float = 0.0
    in_excursion: bool = False
    triggers: int = 0                  # excursions flagged
    layers: Optional[LayerProfile] = None
    buffer: Deque[ServedObservation] = dataclasses.field(
        default_factory=lambda: deque(maxlen=256))
    # queueing-wait telemetry driving the batch-window cap
    waits: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=512))
    window_scale: float = 1.0
    waits_since_adjust: int = 0
    # probe-dispatch telemetry (DESIGN.md §14.4): per (config, column) the
    # EW mean clamped log(observed/predicted), observation count, and the
    # model's predicted per-image seconds. Kept OUTSIDE the dispatch buffer
    # so probes never feed excursion detection or BucketScaleHead fitting.
    probes: Dict[Tuple[Tuple[float, ...], str], Tuple[float, int, float]] = \
        dataclasses.field(default_factory=dict)

    def ratio(self) -> float:
        """Current drift ratio: 1.0 = serving exactly as calibrated."""
        if self.n == 0:
            return 1.0
        return math.exp(self.ewma_log - self.ref_log)


class DriftMonitor:
    """Thread-safe served-vs-predicted latency tracker for many networks."""

    def __init__(self, *, threshold: float = 1.5, alpha: float = 0.25,
                 calib_obs: int = 3, clamp: float = math.log(8.0),
                 obs_cap: int = 256, obs_alpha: float = 0.5,
                 clock: Optional[Callable[[], float]] = None):
        if threshold <= 1.0:
            raise ValueError(f"threshold must be > 1, got {threshold}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if obs_cap < 1:
            raise ValueError(f"obs_cap must be >= 1, got {obs_cap}")
        if not 0.0 < obs_alpha <= 1.0:
            raise ValueError(f"obs_alpha must be in (0, 1], got {obs_alpha}")
        self.threshold = threshold
        self.alpha = alpha
        self.calib_obs = max(int(calib_obs), 1)
        self.clamp = clamp
        self.obs_cap = int(obs_cap)
        self.obs_alpha = obs_alpha
        self.clock = clock if clock is not None else time.monotonic
        self._stats: Dict[str, DriftStats] = {}
        # failure ledger (DESIGN.md §11.1): net -> generation -> kind ->
        # count. Kept OUTSIDE _stats on purpose: a hot_swap resets drift
        # stats (new prediction scale) but must not erase the record of why
        # previous generations failed — the ledger is the post-incident
        # audit trail, keyed by the generation that misbehaved.
        self._failures: Dict[str, Dict[int, Dict[str, int]]] = {}
        self._lock = threading.Lock()

    def reset(self, net: str, generation: int,
              layers: Optional[LayerProfile] = None) -> DriftStats:
        """Start fresh stats for ``net`` at ``generation`` (register /
        hot_swap: the model — and so the prediction scale — changed).
        ``layers`` is the new assignment's attribution profile; without it
        dispatches are still drift-tracked but not buffered as samples."""
        with self._lock:
            s = DriftStats(generation=generation, layers=layers,
                           buffer=deque(maxlen=self.obs_cap))
            self._stats[net] = s
            return s

    def stats(self, net: str) -> Optional[DriftStats]:
        with self._lock:
            return self._stats.get(net)

    def observe(self, net: str, generation: int, observed_s: float,
                predicted_s: float, batch: Optional[int] = None) -> bool:
        """Feed one dispatch's per-image (observed, predicted) runtimes.
        Returns True exactly when a new excursion starts — i.e. at most once
        between resets, the moment recalibration should be scheduled.

        ``batch`` (the dispatch's pow2 bucket) additionally records the
        observation into the served-sample buffer; the server passes it only
        for cleanly-timed dispatches (jit-compile dispatches excluded)."""
        if (not math.isfinite(observed_s) or observed_s <= 0.0
                or not math.isfinite(predicted_s) or predicted_s <= 0.0):
            return False
        with self._lock:
            s = self._stats.get(net)
            if s is None or s.generation != generation:
                return False           # stale: a swap raced this dispatch
            log_r = math.log(observed_s / predicted_s)
            s.n += 1
            if s.n <= self.calib_obs:  # learning the reference scale
                if s.n > 1:            # clamp here too: one pathological
                    # dispatch must not poison the reference either
                    log_r = min(max(log_r, s.ref_log - self.clamp),
                                s.ref_log + self.clamp)
                s.ref_log += (log_r - s.ref_log) / s.n
                s.ewma_log = s.ref_log
                self._record_locked(s, batch, log_r)
                return False
            log_r = min(max(log_r, s.ref_log - self.clamp),
                        s.ref_log + self.clamp)
            self._record_locked(s, batch, log_r)
            s.ewma_log += self.alpha * (log_r - s.ewma_log)
            excess = abs(s.ewma_log - s.ref_log)
            if s.in_excursion:
                if excess < math.log(self.threshold) / 2:
                    s.in_excursion = False      # recovered without recal
                return False
            if excess > math.log(self.threshold):
                s.in_excursion = True
                s.triggers += 1
                return True
            return False

    def _record_locked(self, s: DriftStats, batch: Optional[int],
                       log_r: float) -> None:
        if batch is None or s.layers is None:
            return
        s.buffer.append(ServedObservation(batch=int(batch), log_r=log_r,
                                          t=self.clock()))

    # -- served-sample telemetry -------------------------------------------
    def observations(self, net: str) -> List[ServedObservation]:
        """Snapshot of the buffered (non-compile) dispatch observations."""
        with self._lock:
            s = self._stats.get(net)
            return list(s.buffer) if s is not None else []

    def _ew_by_bucket(self, entries: Sequence[ServedObservation]
                      ) -> Tuple[Dict[int, float], Dict[int, int]]:
        """Exponentially-weighted mean log-ratio and count per pow2 bucket,
        oldest → newest (the EW mean converges onto the most recent
        observations) — shared by ``attributed`` and ``bucket_head``."""
        by_bucket: Dict[int, float] = {}
        counts: Dict[int, int] = {}
        for e in entries:
            if e.batch in by_bucket:
                by_bucket[e.batch] += self.obs_alpha * (e.log_r
                                                        - by_bucket[e.batch])
            else:
                by_bucket[e.batch] = e.log_r
            counts[e.batch] = counts.get(e.batch, 0) + 1
        return by_bucket, counts

    def bucket_head(self, net: str, *, min_obs: int = 1):
        """Fit a :class:`~repro.core.perfmodel.BucketScaleHead` from the
        buffered served observations — the batch-shape correction the server
        threads through batch caps, deadline windows, router scores, and the
        canary gate (DESIGN.md §12.3). None when nothing is buffered."""
        from repro.core.perfmodel import BucketScaleHead
        with self._lock:
            s = self._stats.get(net)
            entries = list(s.buffer) if s is not None else []
        return BucketScaleHead.fit(((e.batch, e.log_r) for e in entries),
                                   alpha=self.obs_alpha, min_obs=min_obs)

    def coverage(self, net: str) -> int:
        """Distinct layer configs the buffer covers — every buffered dispatch
        timed the whole plan, so one clean dispatch covers every assigned
        config; zero only when nothing (attributable) was served."""
        with self._lock:
            s = self._stats.get(net)
            if s is None or s.layers is None or not s.buffer:
                return 0
            return len({tuple(map(float, row)) for row in s.layers.feats})

    def attributed(self, net: str, *, min_obs: int = 1
                   ) -> Optional[Tuple[np.ndarray,
                                       Tuple[str, ...],
                                       List[Tuple[int, np.ndarray]],
                                       Dict]]:
        """Attribute the buffered whole-plan timings to per-layer configs.

        Returns ``(feats, columns, [(bucket, times), ...], info)`` — for each
        batch bucket seen, the (L,) attributed per-image runtimes
        ``predicted * exp(δ_bucket)`` where δ is the exponentially-weighted
        mean of the bucket's buffered log-ratios minus the calibration
        reference (newest observations dominate, so a buffer holding
        pre-drift history still yields a post-drift sample). Buckets with
        fewer than ``min_obs`` buffered dispatches are dropped from the
        sample rows (a lone noisy dispatch should not mint calibration
        rows) but still counted in ``info``. None when the buffer is empty,
        the network has no attribution profile, or no bucket clears
        ``min_obs``.
        """
        with self._lock:
            s = self._stats.get(net)
            if s is None or s.layers is None or not s.buffer:
                return None
            entries = list(s.buffer)
            layers, ref = s.layers, s.ref_log
        by_bucket, counts = self._ew_by_bucket(entries)
        kept = sorted(b for b in by_bucket
                      if counts[b] >= max(int(min_obs), 1))
        if not kept:
            return None
        rows = [(b, layers.predicted * math.exp(by_bucket[b] - ref))
                for b in kept]
        info = {"dispatches": len(entries),
                "buckets": {int(b): int(counts[b]) for b in sorted(counts)},
                "images": int(sum(e.batch for e in entries)),
                "drift": {int(b): math.exp(by_bucket[b] - ref)
                          for b in sorted(by_bucket)}}
        return layers.feats, layers.columns, rows, info

    # -- probe-dispatch telemetry (DESIGN.md §14.4) ------------------------
    def layer_profile(self, net: str) -> Optional[LayerProfile]:
        """The current generation's attribution profile — the server's probe
        scheduler draws (config, column) targets from it."""
        with self._lock:
            s = self._stats.get(net)
            return s.layers if s is not None else None

    def record_probe(self, net: str, generation: int, config, column: str,
                     observed_s: float, predicted_s: float) -> bool:
        """Feed one single-layer probe dispatch's (observed, predicted)
        per-image runtimes for ``(config, column)``.

        Probes live in their own per-key EW store, deliberately outside the
        dispatch buffer: they must never feed excursion detection, the
        served-latency accounting, or ``BucketScaleHead`` fitting — their
        sole consumer is ``probe_attributed``, which turns them into
        calibration rows that correct *relative* primitive costs. Clamped
        against the calibration reference like any observation. Returns
        False for stale generations or non-finite timings."""
        if (not math.isfinite(observed_s) or observed_s <= 0.0
                or not math.isfinite(predicted_s) or predicted_s <= 0.0):
            return False
        with self._lock:
            s = self._stats.get(net)
            if s is None or s.generation != generation:
                return False
            log_r = math.log(observed_s / predicted_s)
            log_r = min(max(log_r, s.ref_log - self.clamp),
                        s.ref_log + self.clamp)
            key = (tuple(float(v) for v in np.asarray(config).reshape(-1)),
                   column)
            prev = s.probes.get(key)
            if prev is None:
                s.probes[key] = (log_r, 1, float(predicted_s))
            else:
                ew, n, _ = prev
                s.probes[key] = (ew + self.obs_alpha * (log_r - ew), n + 1,
                                 float(predicted_s))
            return True

    def probe_attributed(self, net: str
                         ) -> Optional[Tuple[List[Tuple[np.ndarray, str,
                                                        float]], Dict]]:
        """Per-(config, column) probe measurements in the model's prediction
        scale: ``predicted * exp(ew - ref)`` — direct single-column rows for
        ``observations_to_dataset(probes=...)``. Deterministically ordered
        by (config, column). None when no probes were recorded."""
        with self._lock:
            s = self._stats.get(net)
            if s is None or not s.probes:
                return None
            ref = s.ref_log
            snap = dict(s.probes)
        rows = [(np.asarray(cfg, np.float64), col,
                 pred * math.exp(ew - ref))
                for (cfg, col), (ew, n, pred) in sorted(snap.items())]
        info = {"probes": int(sum(n for _, n, _ in snap.values())),
                "probe_keys": len(snap)}
        return rows, info

    # -- deadline telemetry: queueing p99 vs budget ------------------------
    def observe_wait(self, net: str, generation: int, wait_s: float,
                     budget_s: Optional[float]) -> Optional[float]:
        """Feed one dispatch's oldest-ticket queueing wait. Returns a new
        ``window_scale`` when the cap should change (p99 wait above the
        latency budget halves it; p99 under budget/2 doubles it back towards
        1.0), else None. Without a finite budget, waits are only recorded.
        Generation-checked like ``observe``: a claim racing a hot_swap's
        stats reset must not graft a stale scale onto the fresh queue (the
        monitor's fresh stats would sit at 1.0 and never emit the restore)."""
        if not math.isfinite(wait_s) or wait_s < 0.0:
            return None
        with self._lock:
            s = self._stats.get(net)
            if s is None or s.generation != generation:
                return None
            s.waits.append(wait_s)
            if (budget_s is None or not math.isfinite(budget_s)
                    or budget_s <= 0.0):
                return None
            s.waits_since_adjust += 1
            if (len(s.waits) < WAIT_MIN_OBS
                    or s.waits_since_adjust < WAIT_EVERY):
                return None
            p99 = float(np.percentile(np.asarray(s.waits, np.float64), 99))
            new = s.window_scale
            if p99 > budget_s:
                new = max(s.window_scale / 2.0, MIN_WINDOW_SCALE)
            elif p99 < budget_s / 2.0 and s.window_scale < 1.0:
                new = min(s.window_scale * 2.0, 1.0)
            if new == s.window_scale:
                s.waits_since_adjust = 0
                return None
            s.window_scale = new
            s.waits_since_adjust = 0
            s.waits.clear()            # judge the new cap on fresh samples
            return new

    # -- failure ledger (DESIGN.md §11.1) ----------------------------------
    def record_failure(self, net: str, generation: int, kind: str) -> None:
        """Count one serving failure for ``(net, generation)``. ``kind`` is
        the taxonomy bucket: "error" (plan raised), "fault" (injected),
        "corrupt" (output validation), "deadline" (supervisor abandoned a
        hung dispatch), "died" (worker thread died mid-dispatch), "canary"
        (candidate rejected by the swap gate), "rollback" (auto-rollback
        fired), "probe" (a single-layer probe dispatch failed)."""
        with self._lock:
            gens = self._failures.setdefault(net, {})
            kinds = gens.setdefault(int(generation), {})
            kinds[kind] = kinds.get(kind, 0) + 1

    def failures(self, net: str,
                 generation: Optional[int] = None) -> Dict[str, int]:
        """Ledger kind→count for ``net`` — one generation, or all merged."""
        with self._lock:
            gens = self._failures.get(net, {})
            if generation is not None:
                return dict(gens.get(int(generation), {}))
            out: Dict[str, int] = {}
            for kinds in gens.values():
                for k, n in kinds.items():
                    out[k] = out.get(k, 0) + n
            return out

    def failure_ledger(self, net: str) -> Dict[int, Dict[str, int]]:
        """Full per-generation ledger snapshot for ``net``."""
        with self._lock:
            return {g: dict(k) for g, k in
                    self._failures.get(net, {}).items()}

    def window_scale(self, net: str) -> float:
        s = self.stats(net)
        return s.window_scale if s is not None else 1.0

    def ratio(self, net: str) -> float:
        s = self.stats(net)
        return s.ratio() if s is not None else 1.0
