"""Calibration-drift detection from served latencies (DESIGN.md §8.3).

The perf model predicts per-image runtime on the platform it was calibrated
for; the server observes per-image runtime on the machine actually executing
plans. Those live on different absolute scales (a simulated-arm model serves
on a real CPU), so raw observed/predicted ratios mean nothing — what carries
signal is the ratio *moving*. Per (network, generation) the monitor:

  1. learns a **reference** log-ratio from the first ``calib_obs``
     observations (the platform-to-host scale at calibration time),
  2. tracks an **EWMA** of the log-ratio afterwards,
  3. flags an **excursion** when ``|ewma - reference| > log(threshold)``.

``observe`` returns True exactly once per excursion — the trigger for one
background recalibration (``platform.calibrate`` on fresh measurements +
re-select + ``hot_swap``). The excursion latch clears only when the ratio
returns inside threshold/2 (hysteresis) or the generation changes (the swap
resets the stats, because the new model has a new prediction scale).

Per-observation log-ratios are clamped to ±``clamp`` so a single pathological
dispatch (GC pause, page fault storm) cannot fake a sustained drift.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class DriftStats:
    """EWMA state for one (network, generation)."""
    generation: int
    n: int = 0                         # observations consumed
    ref_log: float = 0.0               # reference log-ratio (after calib)
    ewma_log: float = 0.0
    in_excursion: bool = False
    triggers: int = 0                  # excursions flagged

    def ratio(self) -> float:
        """Current drift ratio: 1.0 = serving exactly as calibrated."""
        if self.n == 0:
            return 1.0
        return math.exp(self.ewma_log - self.ref_log)


class DriftMonitor:
    """Thread-safe served-vs-predicted latency tracker for many networks."""

    def __init__(self, *, threshold: float = 1.5, alpha: float = 0.25,
                 calib_obs: int = 3, clamp: float = math.log(8.0)):
        if threshold <= 1.0:
            raise ValueError(f"threshold must be > 1, got {threshold}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.threshold = threshold
        self.alpha = alpha
        self.calib_obs = max(int(calib_obs), 1)
        self.clamp = clamp
        self._stats: Dict[str, DriftStats] = {}
        self._lock = threading.Lock()

    def reset(self, net: str, generation: int) -> DriftStats:
        """Start fresh stats for ``net`` at ``generation`` (register /
        hot_swap: the model — and so the prediction scale — changed)."""
        with self._lock:
            s = DriftStats(generation=generation)
            self._stats[net] = s
            return s

    def stats(self, net: str) -> Optional[DriftStats]:
        with self._lock:
            return self._stats.get(net)

    def observe(self, net: str, generation: int, observed_s: float,
                predicted_s: float) -> bool:
        """Feed one dispatch's per-image (observed, predicted) runtimes.
        Returns True exactly when a new excursion starts — i.e. at most once
        between resets, the moment recalibration should be scheduled."""
        if (not math.isfinite(observed_s) or observed_s <= 0.0
                or not math.isfinite(predicted_s) or predicted_s <= 0.0):
            return False
        with self._lock:
            s = self._stats.get(net)
            if s is None or s.generation != generation:
                return False           # stale: a swap raced this dispatch
            log_r = math.log(observed_s / predicted_s)
            s.n += 1
            if s.n <= self.calib_obs:  # learning the reference scale
                if s.n > 1:            # clamp here too: one pathological
                    # dispatch must not poison the reference either
                    log_r = min(max(log_r, s.ref_log - self.clamp),
                                s.ref_log + self.clamp)
                s.ref_log += (log_r - s.ref_log) / s.n
                s.ewma_log = s.ref_log
                return False
            log_r = min(max(log_r, s.ref_log - self.clamp),
                        s.ref_log + self.clamp)
            s.ewma_log += self.alpha * (log_r - s.ewma_log)
            excess = abs(s.ewma_log - s.ref_log)
            if s.in_excursion:
                if excess < math.log(self.threshold) / 2:
                    s.in_excursion = False      # recovered without recal
                return False
            if excess > math.log(self.threshold):
                s.in_excursion = True
                s.triggers += 1
                return True
            return False

    def ratio(self, net: str) -> float:
        s = self.stats(net)
        return s.ratio() if s is not None else 1.0
