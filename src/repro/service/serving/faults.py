"""Deterministic fault injection for the serving layer (DESIGN.md §11).

A fault-tolerant serving core is only trustworthy if its failure paths are
*exercised*, and failure paths exercised by real flakiness are untestable.
This module makes faults first-class, injectable, and deterministic: a
``FaultPlan`` is a list of :class:`Fault` rules, each matching dispatches by
state key, generation, and per-key dispatch index; a :class:`FaultInjector`
counts dispatches and applies the matching rules around the real execution.

Injection points:

  * ``OptimisedServer(faults=injector)`` — every compiled-plan execution
    (including canary batches, which run under the *candidate* generation,
    so a fault plan can poison exactly the generation a recalibration would
    swap in) runs through :meth:`FaultInjector.run`.
  * ``SimulatedPlatform(faults=injector)`` — profiling measurements run
    through :meth:`FaultInjector.profile` under the key ``"profile:<name>"``,
    so a *recalibration source* can be poisoned (a broken measurement rig
    producing garbage times) independently of plan execution.

Fault kinds:

  * ``"raise"``     — the dispatch raises :class:`FaultError` before running.
  * ``"hang"``      — execution stalls for ``seconds`` on the injector's
                      clock before running (a stuck device/kernel; under a
                      fake clock the stall lasts until a test advances it —
                      exactly what the worker-deadline supervisor is for).
  * ``"slowdown"``  — execution runs, then stalls for ``seconds`` (a
                      pathologically slow plan: the canary gate's prey).
  * ``"corrupt"``   — execution runs, then the output's first row is
                      overwritten with NaN (silent data corruption; the
                      server's output validation turns it into a failure).
                      On the profile hook, measurements are scaled by
                      ``factor`` instead (poisoned profiling).

Determinism: matching depends only on (key, generation, per-key dispatch
index) — no randomness, no wall clock. Every injected fault is appended to
``injector.injected`` so tests can assert the exact schedule that ran.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


class FaultError(RuntimeError):
    """An injected execution failure."""


def wait_until(clock: Callable[[], float], t_end: float,
               poll_s: float = 0.0005) -> None:
    """Stall until ``clock() >= t_end``. With the real clock this is a plain
    sleep; with an injected fake clock it polls (tiny real sleeps) until a
    test advances the clock — so hang/slowdown faults are drivable from a
    deterministic harness."""
    while clock() < t_end:
        time.sleep(poll_s)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injection rule. A dispatch matches when every given selector
    does: ``net`` (state key, e.g. ``"edge_cnn#a"``, or ``"profile:arm"``
    for the platform hook; None = any), ``generation`` (None = any), and the
    per-key dispatch index ``first <= i < last`` with ``i % every == 0``
    relative to ``first``."""

    kind: str                          # raise | hang | slowdown | corrupt
    net: Optional[str] = None
    generation: Optional[int] = None
    first: int = 0
    last: Optional[int] = None         # None = open-ended
    every: int = 1
    seconds: float = 0.0               # hang/slowdown stall duration
    factor: float = 1e6                # profile-corrupt measurement scale

    KINDS = ("raise", "hang", "slowdown", "corrupt")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {self.KINDS}")
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")

    def matches(self, net: str, generation: Optional[int], index: int) -> bool:
        if self.net is not None and self.net != net:
            return False
        if (self.generation is not None and generation is not None
                and self.generation != generation):
            return False
        if index < self.first:
            return False
        if self.last is not None and index >= self.last:
            return False
        return (index - self.first) % self.every == 0


class FaultInjector:
    """Applies a ``FaultPlan`` around executions, counting dispatches per
    state key. Thread-safe: the counter and the injected-event log are
    locked; the stall itself runs unlocked (a hang must not block other
    backends' dispatches)."""

    def __init__(self, faults: List[Fault],
                 clock: Optional[Callable[[], float]] = None):
        self.faults = list(faults)
        self.clock = clock if clock is not None else time.monotonic
        self.injected: List[Tuple[str, Optional[int], int, str]] = []
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()

    def count(self, net: str) -> int:
        """Dispatches seen so far for ``net``'s state key."""
        with self._lock:
            return self._counts.get(net, 0)

    def _next(self, net: str, generation: Optional[int]) -> List[Fault]:
        with self._lock:
            i = self._counts.get(net, 0)
            self._counts[net] = i + 1
            hits = [f for f in self.faults if f.matches(net, generation, i)]
            for f in hits:
                self.injected.append((net, generation, i, f.kind))
            return hits

    # -- plan-execution hook ------------------------------------------------
    def run(self, net: str, generation: Optional[int],
            thunk: Callable[[], np.ndarray]) -> np.ndarray:
        """Execute ``thunk`` under this dispatch's matching faults."""
        hits = self._next(net, generation)
        for f in hits:
            if f.kind == "raise":
                raise FaultError(f"injected fault: {net} dispatch raises")
            if f.kind == "hang":
                wait_until(self.clock, self.clock() + f.seconds)
        out = thunk()
        for f in hits:
            if f.kind == "slowdown":
                wait_until(self.clock, self.clock() + f.seconds)
            elif f.kind == "corrupt":
                out = np.asarray(out, np.float32).copy()
                out[:1] = np.nan
        return out

    # -- profiling hook (SimulatedPlatform) ---------------------------------
    def profile(self, platform_name: str, times: np.ndarray) -> np.ndarray:
        """Apply matching faults to one profiling call's measurements, under
        the key ``"profile:<platform>"``. ``raise`` fails the measurement rig;
        ``corrupt`` scales every time by ``factor`` (pathological readings a
        calibration would faithfully learn)."""
        key = f"profile:{platform_name}"
        hits = self._next(key, None)
        for f in hits:
            if f.kind == "raise":
                raise FaultError(f"injected fault: {key} measurement failed")
            if f.kind == "hang" and f.seconds:
                wait_until(self.clock, self.clock() + f.seconds)
            if f.kind == "corrupt":
                times = np.asarray(times, np.float64) * f.factor
        return times


def classify(exc: BaseException) -> str:
    """Ledger kind for one execution failure (DESIGN.md §11.1)."""
    from repro.service.serving.health import CorruptOutput
    if isinstance(exc, CorruptOutput):
        return "corrupt"
    if isinstance(exc, FaultError):
        return "fault"
    return "error"


def validate_output(out, batch: int) -> np.ndarray:
    """Reject a plan output that would silently corrupt results: wrong
    leading batch dimension or non-finite values. Raises
    :class:`~repro.service.serving.health.CorruptOutput`."""
    from repro.service.serving.health import CorruptOutput
    arr = np.asarray(out)
    if arr.ndim < 1 or arr.shape[0] != batch:
        raise CorruptOutput(f"plan returned shape {arr.shape} for a "
                            f"batch of {batch}")
    # A finite sum proves every element finite without materialising the
    # full isfinite mask (per-dispatch hot path, DESIGN.md §13.3); a
    # non-finite sum can also be mere overflow of large finite values, so
    # only then pay for the exact elementwise check.
    if not np.isfinite(arr.sum(dtype=np.float64)):
        bad = int(np.size(arr) - np.isfinite(arr).sum())
        if bad:
            raise CorruptOutput(f"plan output contains {bad} "
                                f"non-finite values")
    return arr

