"""Backend health: per-backend circuit breakers (DESIGN.md §11.2).

A backend that starts failing should stop receiving traffic *before* its
queue fills with doomed tickets — the router's cost×backlog score cannot see
failures, only slowness. :class:`CircuitBreaker` is the classic three-state
machine:

  * **closed** — traffic flows; failures are recorded into a sliding window.
    Too many consecutive failures, or too high an error rate over the
    window, trips the breaker open.
  * **open** — ``allow()`` refuses admission; the router spills submissions
    to healthy backends. After ``cooldown_s`` the breaker transitions to
    half-open on the next ``allow()`` call.
  * **half-open** — up to ``probes`` in-flight dispatches are admitted as
    probes. A probe success closes the breaker (window cleared); a probe
    failure re-opens it and restarts the cooldown.

Locking: like ``NetQueue``, the breaker is NOT self-locking — every method
is called with the owning server's ``_cond`` held. This keeps the breaker
decision atomic with the routing decision that consumes it.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, Optional


class CorruptOutput(RuntimeError):
    """A plan produced output that failed validation (non-finite values or
    a wrong batch dimension). Treated as an execution failure: it triggers
    retry/fallback and feeds the failure ledger under kind ``"corrupt"``."""


class CircuitBreaker:
    """Three-state (closed / open / half-open) breaker over one backend.

    Parameters
    ----------
    failures : consecutive failures that trip the breaker open.
    window : sliding window of recent outcomes for the error-rate trip.
    rate : error-rate over a full window that trips the breaker open.
    cooldown_s : seconds to hold open before probing.
    probes : concurrent probe dispatches admitted while half-open.
    """

    def __init__(self, *, failures: int = 3, window: int = 16,
                 rate: float = 0.5, cooldown_s: float = 1.0,
                 probes: int = 1):
        if failures < 1:
            raise ValueError("failures must be >= 1")
        if probes < 1:
            raise ValueError("probes must be >= 1")
        self.failures = int(failures)
        self.window = int(window)
        self.rate = float(rate)
        self.cooldown_s = float(cooldown_s)
        self.probes = int(probes)

        self.state = "closed"
        self.consecutive = 0
        self.recent: deque = deque(maxlen=self.window)
        self.opened_s: Optional[float] = None
        self.inflight_probes = 0
        self.opens = 0          # lifetime trips (telemetry)
        self.closes = 0         # lifetime recoveries (telemetry)

    # -- admission ----------------------------------------------------------
    def allow(self, now: float) -> bool:
        """May a new dispatch be admitted to this backend at ``now``?
        Transitions open→half-open when the cooldown has elapsed; while
        half-open, admits at most ``probes`` concurrent probe dispatches
        (callers that are refused must try another backend or queue the
        refusal — they do NOT hold a probe slot)."""
        if self.state == "open":
            if self.opened_s is not None and \
                    now - self.opened_s >= self.cooldown_s:
                self.state = "half_open"
                self.inflight_probes = 0
            else:
                return False
        if self.state == "half_open":
            if self.inflight_probes >= self.probes:
                return False
            self.inflight_probes += 1
            return True
        return True

    def cancel_probe(self) -> None:
        """Release a probe slot granted by ``allow()`` when the admitted
        dispatch never actually started (e.g. the queue refused the push
        and the ticket spilled elsewhere)."""
        if self.state == "half_open" and self.inflight_probes > 0:
            self.inflight_probes -= 1

    # -- outcomes -----------------------------------------------------------
    def record(self, ok: bool, now: float) -> None:
        """Record a finished dispatch's outcome. In half-open state this is
        a probe verdict: success closes, failure re-opens."""
        if self.state == "half_open":
            if self.inflight_probes > 0:
                self.inflight_probes -= 1
            if ok:
                self.state = "closed"
                self.closes += 1
                self.consecutive = 0
                self.recent.clear()
                self.opened_s = None
                self.inflight_probes = 0
            else:
                self._trip(now)
            return
        self.recent.append(bool(ok))
        if ok:
            self.consecutive = 0
            return
        self.consecutive += 1
        if self.state == "closed" and self._should_trip():
            self._trip(now)

    def _should_trip(self) -> bool:
        if self.consecutive >= self.failures:
            return True
        if len(self.recent) >= self.window:
            errs = sum(1 for ok in self.recent if not ok)
            if errs / len(self.recent) >= self.rate:
                return True
        return False

    def _trip(self, now: float) -> None:
        self.state = "open"
        self.opened_s = now
        self.opens += 1
        self.inflight_probes = 0

    # -- telemetry ----------------------------------------------------------
    def snapshot(self, now: float) -> Dict[str, object]:
        cooldown_left = 0.0
        if self.state == "open" and self.opened_s is not None:
            cooldown_left = max(0.0, self.cooldown_s - (now - self.opened_s))
        return {
            "state": self.state,
            "consecutive_failures": self.consecutive,
            "window_errors": sum(1 for ok in self.recent if not ok),
            "window_size": len(self.recent),
            "opens": self.opens,
            "closes": self.closes,
            "cooldown_left_s": cooldown_left,
        }


def merge_failures(into: Dict[str, int], more: Dict[str, int]) -> Dict[str, int]:
    """Merge two failure-ledger kind→count maps (stats aggregation)."""
    for kind, n in more.items():
        into[kind] = into.get(kind, 0) + int(n)
    return into


Clock = Callable[[], float]
