"""Tickets and per-network request queues with deadline-aware batch windows
(DESIGN.md §8.1, §8.5).

A ``Ticket`` is one queued inference request. It carries a ``threading.Event``
so a submitting thread can block on exactly its own result while worker
threads dispatch batches concurrently.

A ``NetQueue`` is a bounded FIFO for one network. It does NOT lock itself:
the serving core serialises all queue mutation under one lock (queues are
tiny; a single lock keeps claim/dispatch ordering trivially correct). What it
*does* own is the batching policy:

  * dispatch when ``len(queue) >= batch_cap``            (the batch is full)
  * or when ``oldest ticket age >= effective max_wait``  (the window expired)

so a lone request is dispatched within the window instead of starving while
the server waits for peers, and a burst still fills perf-model-sized batches.

The *effective* window is deadline-aware: given a per-request latency budget
and the model-predicted execution time of the pending batch (its pow2 bucket
× predicted per-image cost), the window is capped at
``budget − predicted execution`` — waiting any longer would blow the budget
even if the batch ran exactly as predicted. The static ``max_wait`` cap is
further scaled by ``window_scale`` (the drift monitor shrinks it when
observed p99 queueing latency exceeds the budget, and restores it when the
queue drains — DESIGN.md §8.5).

``push`` refuses tickets beyond ``depth`` — the backpressure signal: the
caller marks the ticket rejected rather than queueing unbounded work the
budgeted throughput can't drain.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

import numpy as np


def monotonic() -> float:
    """One clock for every queue/window decision (perf_counter: monotonic,
    high resolution). Tests inject their own clock through the server so
    window semantics are checked without wall-clock sleeps."""
    return time.perf_counter()


def pow2_floor(n: int) -> int:
    return 1 << (max(n, 1).bit_length() - 1)


def pow2_ceil(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


@dataclasses.dataclass
class Ticket:
    """One queued inference request. ``result``/``error`` are set by the
    dispatching worker; ``wait()`` blocks until then. A failed or rejected
    dispatch marks its tickets instead of losing them."""

    net: str
    x: np.ndarray                      # (c, im, im) — for slab-backed
    # tickets this is a zero-copy row view into a shared-memory slab
    result: Optional[np.ndarray] = None
    slab: Optional[object] = None      # SlabHandle provenance (frontend.py)
    row: int = -1                      # row index inside the slab, -1 = none
    done: bool = False
    error: Optional[str] = None
    rejected: bool = False             # refused at submit (backpressure)
    degraded: bool = False             # served by the safe fallback plan
    submitted_s: float = 0.0           # clock timestamps
    dispatched_s: float = 0.0
    completed_s: float = 0.0
    clock: Optional[Callable[[], float]] = dataclasses.field(
        default=None, repr=False, compare=False)
    _done_event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    _finish_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until this ticket is finished (True) or ``timeout`` expires
        (False). Finished covers success, failure, and rejection."""
        return self._done_event.wait(timeout)

    @property
    def queue_wait_s(self) -> float:
        """Time spent queued before a worker claimed the ticket."""
        return max(self.dispatched_s - self.submitted_s, 0.0)

    def finish(self, *, result: Optional[np.ndarray] = None,
               error: Optional[str] = None, rejected: bool = False,
               degraded: bool = False) -> bool:
        """Settle the ticket. First finish wins: a supervisor abandoning a
        hung dispatch and the dispatch eventually completing must not both
        deliver — whichever settles first is the result the waiter saw, and
        the loser's call is a no-op (returns False). This is what makes
        "zero duplicated tickets" a structural property rather than a timing
        accident."""
        with self._finish_lock:
            if self.done:
                return False
            self.result = result
            self.error = error
            self.rejected = rejected
            self.degraded = degraded
            self.completed_s = (self.clock or monotonic)()
            self.done = True
        self._done_event.set()
        return True


@dataclasses.dataclass
class BatchGroup:
    """A pre-assembled dispatch from the process front end (DESIGN.md §12):
    tickets whose payload rows already live contiguously — and pow2-padded —
    in one shared-memory slab. ``xs`` is the zero-copy padded batch view the
    worker executes directly (no ``np.stack``, no pad concat in the serving
    process); ``on_done(tickets, out)`` fires exactly once when the dispatch
    settles (delivered, degraded, failed, or rejected) so the front end can
    ship results back and recycle the slab."""

    tickets: List[Ticket]
    xs: np.ndarray                     # (pow2 bucket, c, im, im) padded view
    on_done: Optional[Callable[[List[Ticket],
                                Optional[np.ndarray]], None]] = None


class NetQueue:
    """Bounded FIFO + deadline-aware batch window for one network. All
    methods must be called under the serving core's lock."""

    def __init__(self, *, depth: int, batch_cap: int, max_wait_s: float,
                 budget_s: Optional[float] = None,
                 predicted_s: float = 0.0,
                 bucket_scale: Optional[Callable[[int], float]] = None):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        self.depth = depth
        self.batch_cap = batch_cap
        self.max_wait_s = max_wait_s
        # deadline inputs: per-request latency budget and the model-predicted
        # per-image execution cost (both optional: without them the window is
        # the static max_wait, scaled by window_scale)
        self.budget_s = budget_s
        self.predicted_s = predicted_s
        # batch-shape correction (BucketScaleHead.scale): per-image cost as
        # a function of the pending batch's pow2 bucket. None = linear.
        self.bucket_scale = bucket_scale
        self.window_scale = 1.0        # shrunk/restored by the drift monitor
        self._q: Deque[Ticket] = deque()
        self._groups: Deque[BatchGroup] = deque()

    def __len__(self) -> int:
        return len(self._q) + sum(len(g.tickets) for g in self._groups)

    def effective_wait_s(self) -> float:
        """Current batch window: ``max_wait`` capped by the latency budget
        minus the predicted execution time of the pending batch's pow2
        bucket, all scaled by ``window_scale``. The scale applies to the
        *capped* window — when observed waits blow the budget anyway
        (optimistic predictions, claim contention), the monitor's shrink
        must bite below the deadline cap too, not just below ``max_wait``.
        The predicted execution is batch-shape-aware when a ``bucket_scale``
        head is fitted: per-image cost is scaled for the pending bucket
        instead of assumed batch-size-invariant. Never negative — a pending
        batch whose predicted execution alone exceeds the budget dispatches
        immediately (waiting cannot help it)."""
        w = self.max_wait_s
        if (self.budget_s is not None and math.isfinite(self.budget_s)
                and self.predicted_s > 0.0
                and math.isfinite(self.predicted_s)):
            b = pow2_ceil(len(self._q)) if self._q else 1
            per = self.predicted_s
            if self.bucket_scale is not None:
                per *= float(self.bucket_scale(b))
            w = min(w, self.budget_s - per * b)
        return max(w, 0.0) * self.window_scale

    def backlog_images(self, inflight: int = 0) -> int:
        """Queued images plus an in-flight allowance (``inflight`` batches
        at ``batch_cap`` each) — the cross-backend router's load proxy
        (DESIGN.md §9: predicted per-image cost × backlog)."""
        return len(self) + inflight * self.batch_cap

    def push(self, t: Ticket) -> bool:
        """Enqueue; False when the queue is at depth (backpressure)."""
        if len(self) >= self.depth:
            return False
        self._q.append(t)
        return True

    def push_group(self, g: BatchGroup) -> bool:
        """Enqueue a pre-assembled slab batch; False when the group would
        push the queue past depth (backpressure, same bound as ``push``)."""
        if len(self) + len(g.tickets) > self.depth:
            return False
        self._groups.append(g)
        return True

    def group_ready(self) -> bool:
        return bool(self._groups)

    def take_group(self) -> BatchGroup:
        """Pop the oldest pre-assembled batch (caller checked group_ready)."""
        return self._groups.popleft()

    def drain(self) -> Tuple[List[Ticket], List[BatchGroup]]:
        """Empty the queue entirely: loose tickets and pre-assembled groups
        (re-register / unregister — nothing may be stranded queued)."""
        tickets, groups = list(self._q), list(self._groups)
        self._q.clear()
        self._groups.clear()
        return tickets, groups

    def ready(self, now: float, *, drain: bool = False) -> bool:
        """Should a batch dispatch now? A pre-assembled group (its window
        already ran in the intake process), full batch, expired window, or
        an explicit drain (synchronous pump / shutdown)."""
        if self._groups:
            return True
        if not self._q:
            return False
        if drain or len(self._q) >= self.batch_cap:
            return True
        return now - self._q[0].submitted_s >= self.effective_wait_s()

    def next_deadline(self) -> Optional[float]:
        """Clock time at which the oldest ticket's window expires (the
        worker-pool wait bound); None when empty. A pending group is ready
        immediately."""
        if self._groups:
            return self._groups[0].tickets[0].submitted_s
        if not self._q:
            return None
        return self._q[0].submitted_s + self.effective_wait_s()

    def take(self, n: int) -> List[Ticket]:
        """Pop up to ``n`` loose tickets in FIFO order (groups dispatch
        whole, via ``take_group``)."""
        out = []
        while self._q and len(out) < n:
            out.append(self._q.popleft())
        return out
