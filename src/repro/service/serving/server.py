"""Concurrent serving core (DESIGN.md §8).

``OptimisedServer`` serves any number of registered optimised networks
through the whole-graph compiled plan cache (``repro.primitives.plan``),
closing the paper's loop end to end:

    profile → model → select → serve → observe → recalibrate → hot_swap

Five mechanisms make it a serving system rather than a loop:

  * **Perf-model-predicted batching** (§7.3, kept): each network's batch cap
    is ``latency_budget / predicted_per_image`` rounded down to a power of
    two; partial batches pad up to the next pow2 bucket so the plan cache
    stays small, pad rows are sliced off before delivery.
  * **Deadline-aware batch windows** (``queues.NetQueue``): a batch
    dispatches when it is full, OR when the oldest ticket has waited the
    *effective* window — ``max_wait`` capped by the latency budget minus the
    model-predicted execution time of the pending batch, so a request never
    idles in the queue past the point where its budget could still be met.
    The drift monitor shrinks the window cap when observed p99 queueing
    latency exceeds the budget (and restores it as the queue drains).
  * **Worker pool + backpressure** (``workers.WorkerPool``): ``workers`` > 0
    overlaps plan execution across networks (JAX releases the GIL inside
    compiled plans) under per-network in-flight limits; queues are bounded,
    and ``submit`` returns a *rejected* ticket instead of queueing past
    ``queue_depth``. ``workers=0`` keeps the synchronous ``pump()`` mode.
  * **Drift-triggered recalibration** (``drift.DriftMonitor``): served
    per-image latency is tracked against the model's prediction (EWMA of the
    log ratio vs a per-generation reference); when it drifts past
    ``drift_threshold`` the server runs ``recalibrate`` on a background
    thread and ``hot_swap``s the result in — exactly once per excursion,
    without touching in-flight tickets.
  * **Served-sample reuse** (§8.5): every cleanly-timed dispatch is a free
    measurement; the drift monitor buffers them, and recalibration
    calibrates from the attributed per-layer observations, paying
    ``measure_sample`` profiling only for configs the buffer misses — at
    full coverage a recalibration costs zero extra profiling.
  * **Predicted-cost cross-backend routing** (§9): ``register(opt,
    backend="tpu")`` adds one backend of a logical network; each backend
    keeps its own queue, in-flight limit, and drift state, and ``submit``
    sends every request to the backend whose predicted marginal cost
    (observed-or-predicted per-image cost × backlog) is lowest, spilling to
    the next-cheapest on backpressure. ``unregister_backend`` removes one
    cleanly; routing continues on the rest.

Timing is injectable: ``clock=`` replaces the monotonic clock everywhere a
window/queueing decision reads time, so tests drive batch-window semantics
deterministically instead of sleeping.

CLI — the documented CNN serving command (the LM decode demo lives at
``repro.launch.lm_decode``):

    python -m repro.service.server --net edge_cnn --platform arm \
        --workers 2 --max-wait-ms 5 --latency-budget-ms 50 --drift-threshold 1.5
"""
from __future__ import annotations

import argparse
import dataclasses
import inspect
import itertools
import math
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.service.pipeline import OptimisedNetwork, optimise, reoptimise
from repro.service.serving.drift import DriftMonitor, LayerProfile
from repro.service.serving.faults import (FaultInjector, classify,
                                          validate_output)
from repro.service.serving.health import (CircuitBreaker, merge_failures)
from repro.service.serving.queues import (BatchGroup, NetQueue, Ticket,
                                          monotonic, pow2_ceil, pow2_floor)
from repro.service.serving.workers import WorkerPool

# batch-shape cost model (DESIGN.md §12.3): fit the per-bucket scale head
# once this many clean observations are buffered, refit every this many more
BUCKET_MIN_OBS = 8
BUCKET_REFRESH_EVERY = 8


class ProbeUnsupported(Exception):
    """The probe target's column cannot execute on this host (simulated-only
    primitive) — the probe is skipped, not counted as a failure."""


def layer_profile(opt: OptimisedNetwork) -> Optional[LayerProfile]:
    """The attribution profile for served-sample telemetry: the network's
    assigned conv-layer configs, their assigned primitive columns, and the
    model-predicted per-image runtimes (DESIGN.md §8.5). None when the
    network carries no models (``from_assignment``) or nothing attributable —
    such networks are still drift-monitored, just not sample-buffered."""
    from repro.models.cnn_zoo import ConvLayer
    if opt.models is None:
        return None
    model = opt.models.prim
    rows, cols = [], []
    for i, node in enumerate(opt.spec.nodes):
        if not isinstance(node, ConvLayer):
            continue
        prim = opt.assignment.get(i)
        if prim is None or prim not in model.columns:
            continue
        rows.append(node.config)
        cols.append(prim)
    if not rows:
        return None
    feats = np.asarray(rows, np.float64)
    pred = model.predict(feats)
    idx = [model.columns.index(c) for c in cols]
    predicted = pred[np.arange(len(rows)), idx]
    if not (np.isfinite(predicted).all() and (predicted > 0).all()
            and np.isfinite(predicted.sum())):
        return None
    return LayerProfile(feats=feats, columns=tuple(cols), predicted=predicted)


@dataclasses.dataclass
class _Batch:
    """One claimed dispatch: tickets already popped from the queue, the
    network's in-flight slot already taken. Snapshots opt/weights at claim
    time so an already-claimed batch finishes on the plan it was claimed
    under even if a hot_swap lands before execution, and carries the
    _NetState so accounting survives a re-register replacing the state.

    ``claimed_s`` is the claim timestamp the worker supervisor ages against
    the execution deadline; ``settled`` guards the release of the in-flight
    slot — the executing worker, its ``finally``, a late zombie, and the
    supervisor's ``abandon`` may all race to settle, and exactly one wins
    (DESIGN.md §11.3)."""
    net: str
    tickets: List[Ticket]
    generation: int
    state: "_NetState"
    opt: OptimisedNetwork
    weights: Dict
    claimed_s: float = 0.0
    settled: bool = False              # mutated only under the server lock
    # pre-assembled slab dispatch (DESIGN.md §12): the pow2-padded zero-copy
    # batch view (skips np.stack/pad) and the front end's settle callback
    xs: Optional[np.ndarray] = None
    on_done: Optional[Callable] = None


@dataclasses.dataclass
class _NetState:
    opt: OptimisedNetwork
    weights: Dict
    queue: NetQueue
    max_inflight: int
    latency_budget_ms: Optional[float]
    logical: str = ""                  # the network name requests route under
    backend: Optional[str] = None      # None = plain single-backend entry
    generation: int = 0                # bumped by hot_swap
    inflight: int = 0
    dispatches: int = 0
    images: int = 0
    padded: int = 0
    rejected: int = 0
    recalibrations: int = 0
    last_recal_error: Optional[str] = None
    last_recal_sample: Optional[Dict] = None   # served/fresh mix (§8.5)
    busy_s: float = 0.0
    # fault tolerance (DESIGN.md §11)
    breaker: Optional[CircuitBreaker] = None   # set by register()
    history: Deque = dataclasses.field(        # rollback ring: (gen, opt)
        default_factory=deque)
    fallback_asg: Optional[Dict[int, str]] = None   # lazily-built safe plan
    retries: int = 0                   # primary attempts retried
    failed_dispatches: int = 0         # dispatches whose primary path failed
    failed_tickets: int = 0            # tickets finished with error=
    fallback_dispatches: int = 0       # failed dispatches rescued (≥1 ticket)
    fallback_images: int = 0           # tickets served degraded
    canary_rejected: int = 0           # hot_swap candidates the canary vetoed
    last_canary: Optional[str] = None  # last canary rejection reason
    rollbacks: int = 0                 # generations reverted (manual + auto)
    # consecutive primary failures since this generation went live; -1 once
    # it has ANY success (a proven generation is never auto-rolled-back)
    gen_bad_streak: int = 0
    # batch-shape cost model (DESIGN.md §12.3): per-bucket scale head fitted
    # from this backend's served-traffic buffer, refit every
    # BUCKET_REFRESH_EVERY clean observations
    bucket_head: Optional[object] = None
    bucket_obs_at_fit: int = 0
    # (generation, batch_bucket) -> completion time of the FIRST execution:
    # any dispatch that STARTED before that instant may have paid (or waited
    # on) jit compile and must not feed the drift EWMA — this also covers
    # max_inflight > 1, where two first executions of a bucket overlap
    bucket_ready: Dict[Tuple[int, int], float] = dataclasses.field(
        default_factory=dict)
    waits: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=4096))
    # dispatch fast path (DESIGN.md §13.3): preallocated pow2-bucket batch
    # buffers, reused across dispatches when max_inflight == 1 (a single
    # in-flight batch per state means the buffer is never concurrently
    # written). Keyed by bucket size.
    pad_scratch: Dict[int, np.ndarray] = dataclasses.field(
        default_factory=dict)
    # probe dispatches (DESIGN.md §14.4): rate-limited single-layer
    # measurements correcting relative primitive costs in the pooled sample
    probes: int = 0                    # probes measured successfully
    probe_failures: int = 0            # probes that raised / were faulted
    last_probe_s: float = -math.inf    # rate-limit clock, server lock held
    probe_rr: int = 0                  # round-robin layer cursor
    # drift-pool manifests already acted on by poll_pool (per-state so a
    # re-register naturally re-pulls the fleet's evidence)
    pool_seen: set = dataclasses.field(default_factory=set)

    @property
    def batch_cap(self) -> int:
        return self.queue.batch_cap


class OptimisedServer:
    """Multi-network serving front end. ``workers=0`` (default) is the
    synchronous mode: ``submit`` then ``pump()`` drains inline on the calling
    thread. ``workers>0`` starts a thread pool at first ``register`` and
    ``serve``/``Ticket.wait`` block on completion events instead."""

    def __init__(self, *, max_batch: int = 32,
                 latency_budget_ms: float = 50.0,
                 workers: int = 0,
                 max_wait_ms: float = 5.0,
                 queue_depth: int = 256,
                 max_inflight: int = 1,
                 recalibrate: Optional[Callable] = None,
                 drift_threshold: float = 1.5,
                 drift_alpha: float = 0.25,
                 drift_calib_obs: int = 3,
                 obs_cap: int = 256,
                 exec_deadline_ms: Optional[float] = None,
                 fallback: bool = True,
                 canary: bool = False,
                 canary_batch: int = 2,
                 canary_slowdown: float = 8.0,
                 auto_rollback: int = 3,
                 rollback_history: int = 4,
                 breaker_failures: int = 3,
                 breaker_window: int = 16,
                 breaker_rate: float = 0.5,
                 breaker_cooldown_ms: float = 250.0,
                 breaker_probes: int = 1,
                 faults: Optional[FaultInjector] = None,
                 bucket_cost_model: bool = True,
                 frontend_procs: int = 0,
                 frontend_slots: int = 16,
                 probe_rate: float = 0.0,
                 clock: Optional[Callable[[], float]] = None):
        """Fault-tolerance knobs (DESIGN.md §11): ``exec_deadline_ms`` is the
        per-dispatch execution deadline the worker supervisor enforces (None
        disables hung-dispatch detection); ``fallback`` degrades a failed
        dispatch to the per-net safe plan instead of failing its tickets;
        ``canary``/``canary_batch``/``canary_slowdown`` gate ``hot_swap``
        candidates behind a canary batch; ``auto_rollback`` consecutive
        never-succeeded primary failures of a freshly swapped generation
        revert it (0 disables); ``rollback_history`` bounds the per-net undo
        ring; ``breaker_*`` configure the per-backend circuit breakers the
        multi-backend router consults; ``faults`` injects a deterministic
        fault plan into every plan execution (tests/chaos drills).

        ``bucket_cost_model`` (DESIGN.md §12.3) fits a per-pow2-bucket scale
        head from each backend's served-traffic buffer and threads it
        through batch caps, deadline windows, router scores, and the canary
        gate — predicted per-image cost becomes a function of batch shape
        instead of assumed linear. ``frontend_procs`` > 0 enables the
        process front end (``frontend()``): intake processes assemble
        request batches in shared-memory slabs and hand them to the worker
        pool by reference (requires ``workers`` >= 1).

        ``probe_rate`` (DESIGN.md §14.4) > 0 enables rate-limited
        single-layer probe dispatches: at most ``probe_rate`` probes per
        second (per state), piggybacked after clean dispatches, measuring
        one assigned (config, primitive) directly so pooled calibration
        data corrects *relative* primitive costs rather than just the
        common scale. Probes ride the fault-injection contract but never
        enter the queue — they are excluded from served-latency accounting
        and from the bucket-scale head by construction."""
        self.max_batch = max_batch
        self.latency_budget_ms = latency_budget_ms
        self.max_wait_ms = max_wait_ms
        self.queue_depth = queue_depth
        self.max_inflight = max_inflight
        self.exec_deadline_s = (exec_deadline_ms * 1e-3
                                if exec_deadline_ms else None)
        self.fallback = fallback
        self.canary_default = canary
        self.canary_batch = max(int(canary_batch), 1)
        self.canary_slowdown = canary_slowdown
        self.auto_rollback = int(auto_rollback)
        self.rollback_history = max(int(rollback_history), 0)
        self._breaker_kw = dict(failures=breaker_failures,
                                window=breaker_window, rate=breaker_rate,
                                cooldown_s=breaker_cooldown_ms * 1e-3,
                                probes=breaker_probes)
        self._faults = faults
        self._clock = clock if clock is not None else monotonic
        self._nets: Dict[str, _NetState] = {}
        # logical net -> state keys (DESIGN.md §9). A plain register keeps
        # key == net; register(backend=...) keys the state "net#backend" and
        # submit() routes each request to the predicted-cheapest member
        self._routes: Dict[str, List[str]] = {}
        self._order: List[str] = []            # round-robin claim fairness
        self._rr = 0
        self._cond = threading.Condition()
        self._drift = DriftMonitor(threshold=drift_threshold,
                                   alpha=drift_alpha,
                                   calib_obs=drift_calib_obs,
                                   obs_cap=obs_cap,
                                   clock=self._clock)
        self._recalibrate = recalibrate
        self._recal_served = _accepts_served(recalibrate)
        self._recal_threads: List[threading.Thread] = []
        self._pool = WorkerPool(self, workers) if workers > 0 else None
        self.bucket_cost_model = bool(bucket_cost_model)
        if frontend_procs > 0 and workers < 1:
            raise ValueError(
                "frontend_procs requires workers >= 1: intake processes "
                "feed pre-assembled batches to the worker pool; pump mode "
                "has no concurrent consumer")
        self.frontend_procs = int(frontend_procs)
        self.frontend_slots = int(frontend_slots)
        if probe_rate < 0:
            raise ValueError(f"probe_rate must be >= 0, got {probe_rate}")
        self.probe_rate = float(probe_rate)
        self._frontend = None
        # dispatch fast path (DESIGN.md §13.3): per-generation precompiled
        # plan handles, (id(opt), id(weights)) -> (opt, weights,
        # {input shape: bound jitted fn}). Each handle closes over the
        # weights (constants for the generation's lifetime) so steady-state
        # dispatch is one single-array jit call — no per-dispatch weights
        # pytree flatten, no plan-cache key rebuild. opt/weights are pinned
        # in the value so a live key can never alias recycled ids; entries
        # drop when the generation retires (hot_swap / re-register /
        # rollback / unregister)
        self._plan_handles: Dict[Tuple[int, int],
                                 Tuple[OptimisedNetwork, Dict, Dict]] = {}

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "OptimisedServer":
        if self._pool is not None:
            self._pool.start()
        return self

    def frontend(self, procs: Optional[int] = None, *,
                 slots: Optional[int] = None):
        """The process front end (DESIGN.md §12), created and started on
        first use — intake processes assembling request batches in
        shared-memory slabs. Register every network first: the front end
        sizes its slab pools from the registered image shapes and batch
        caps."""
        if self._frontend is None:
            from repro.service.serving.frontend import ProcessFrontend
            n = procs if procs is not None else self.frontend_procs
            if n < 1:
                raise ValueError("frontend requires procs >= 1 (pass procs= "
                                 "or construct with frontend_procs=)")
            if self._pool is None:
                raise ValueError(
                    "the process front end requires workers >= 1: intake "
                    "processes feed pre-assembled batches to the worker "
                    "pool; pump mode has no concurrent consumer")
            self._frontend = ProcessFrontend(
                self, n,
                slots=slots if slots is not None else self.frontend_slots)
            self._frontend.start()
        return self._frontend

    def stop(self, timeout: float = 10.0) -> None:
        """Drain queued tickets, stop workers, join pending recalibrations."""
        if self._frontend is not None:
            self._frontend.stop(timeout)
            self._frontend = None
        if self._pool is not None:
            self._pool.stop(timeout)
        with self._cond:
            pending = list(self._recal_threads)
        for t in pending:
            t.join(timeout)
        with self._cond:
            self._recal_threads = [t for t in self._recal_threads
                                   if t.is_alive()]

    def wake_all(self) -> None:
        """Wake every thread blocked in ``claim_blocking`` (WorkerPool stop)."""
        with self._cond:
            self._cond.notify_all()

    def __enter__(self) -> "OptimisedServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- registration ------------------------------------------------------
    def _budget_s(self, budget_ms: Optional[float]) -> float:
        return (budget_ms if budget_ms is not None
                else self.latency_budget_ms) * 1e-3

    def _batch_cap(self, predicted_cost_s: float,
                   budget_ms: Optional[float]) -> int:
        budget_s = self._budget_s(budget_ms)
        if not np.isfinite(predicted_cost_s) or predicted_cost_s <= 0:
            return pow2_floor(self.max_batch)
        cap = int(np.clip(budget_s / predicted_cost_s, 1, self.max_batch))
        return pow2_floor(cap)

    def _bucket_batch_cap_locked(self, state: "_NetState") -> int:
        """Batch-shape-aware batch cap (DESIGN.md §12.3): the largest pow2
        bucket whose *bucket-scaled* predicted execution fits the backend's
        latency budget — ``pred × scale(b) × b <= budget``. Falls back to
        the linear ``_batch_cap`` until a head is fitted."""
        pred = state.queue.predicted_s
        head = state.bucket_head if self.bucket_cost_model else None
        if head is None or not (np.isfinite(pred) and pred > 0):
            return self._batch_cap(pred if pred > 0
                                   else state.opt.predicted_cost_s,
                                   state.latency_budget_ms)
        budget_s = self._budget_s(state.latency_budget_ms)
        cap, b = 1, 1
        top = pow2_floor(self.max_batch)
        while b <= top:
            if pred * head.scale(b) * b <= budget_s:
                cap = b
            b *= 2
        return cap

    def _per_image_locked(self, state: "_NetState",
                          bucket: Optional[int] = None, *,
                          observed_first: bool = False) -> float:
        """Predicted per-image cost of this backend, optionally conditioned
        on the pow2 ``bucket`` through the fitted scale head. The head is
        mean-normalised over served buckets, so it composes with either base
        (observed mean or model prediction) as a pure shape correction.
        0.0 when no usable base exists (modelless entry, nothing served)."""
        per = 0.0
        if observed_first and state.images:
            per = state.busy_s / state.images
        if not (np.isfinite(per) and per > 0):
            per = state.queue.predicted_s
        if not (np.isfinite(per) and per > 0) and state.images:
            per = state.busy_s / state.images
        if not (np.isfinite(per) and per > 0):
            return 0.0
        head = state.bucket_head if self.bucket_cost_model else None
        if head is not None and bucket is not None:
            per *= head.scale(bucket)
        return per

    def predict_per_image(self, net: str,
                          bucket: Optional[int] = None) -> float:
        """Model-predicted per-image cost for ``net`` (a state key or an
        unambiguous logical name), batch-shape-conditioned when ``bucket``
        is given and a scale head has been fitted from served traffic."""
        with self._cond:
            key = self._resolve_key_locked(net)
            return self._per_image_locked(self._nets[key], bucket)

    def register(self, opt: OptimisedNetwork, *, backend: Optional[str] = None,
                 weights: Optional[Dict] = None,
                 latency_budget_ms: Optional[float] = None,
                 max_wait_ms: Optional[float] = None,
                 queue_depth: Optional[int] = None,
                 max_inflight: Optional[int] = None) -> _NetState:
        """Register an optimised network for serving. ``weights`` defaults to
        fresh ``make_weights(spec)`` (serving demo weights). Per-network
        overrides fall back to the server-wide knobs.

        ``backend`` names this registration as one backend of the logical
        network ``opt.net`` (DESIGN.md §9): the state is keyed
        ``"net#backend"``, gets its own queue and in-flight limit, and
        ``submit(net, ...)`` routes each request to the predicted-cheapest
        registered backend. Every backend of one logical network must serve
        the same topology (requests are interchangeable between them)."""
        from repro.primitives.executor import make_weights
        key = opt.net if backend is None else f"{opt.net}#{backend}"
        pred = opt.predicted_cost_s
        queue = NetQueue(
            depth=queue_depth if queue_depth is not None else self.queue_depth,
            batch_cap=self._batch_cap(pred, latency_budget_ms),
            max_wait_s=(max_wait_ms if max_wait_ms is not None
                        else self.max_wait_ms) * 1e-3,
            budget_s=self._budget_s(latency_budget_ms),
            predicted_s=pred if np.isfinite(pred) and pred > 0 else 0.0)
        state = _NetState(
            opt=opt,
            weights=weights if weights is not None else make_weights(opt.spec),
            queue=queue,
            max_inflight=(max_inflight if max_inflight is not None
                          else self.max_inflight),
            latency_budget_ms=latency_budget_ms,
            logical=opt.net, backend=backend,
            breaker=CircuitBreaker(**self._breaker_kw),
            history=deque(maxlen=self.rollback_history))
        with self._cond:
            route = self._routes.setdefault(opt.net, [])
            for k in route:
                if k != key and self._nets[k].opt.spec.name != opt.spec.name:
                    raise ValueError(
                        f"backend {backend!r} of {opt.net!r} serves topology "
                        f"{opt.spec.name!r}, but the route already serves "
                        f"{self._nets[k].opt.spec.name!r}")
            old = self._nets.get(key)
            if old is None:
                self._order.append(key)
                route.append(key)
            else:
                # replacing a live registration must not strand its queued
                # tickets (in-flight batches keep their own _NetState ref),
                # and must not reuse its generation numbers — stale drift
                # observations and pending recalibration hot_swaps carry the
                # old generation and would otherwise pass the CAS checks
                stranded, sgroups = old.queue.drain()
                state.generation = old.generation + 1
            self._nets[key] = state
            if old is not None:
                self._evict_retired_locked(old.opt)
        if old is not None:
            err = f"rejected: {key!r} was re-registered"
            for t in stranded:
                t.finish(error=err, rejected=True)
            for g in sgroups:
                for t in g.tickets:
                    t.finish(error=err, rejected=True)
                self._notify_done(g, None)
        self._precompile_plans(opt, state.weights)
        self._drift.reset(key, state.generation,
                          layers=layer_profile(opt))
        self.start()
        return state

    def unregister_backend(self, net: str, backend: str) -> bool:
        """Remove one backend of ``net`` from the route. Its queued tickets
        are rejected (the submitter retries or routes elsewhere); an
        in-flight batch keeps its own state reference and completes
        normally. Returns False when no such backend is registered — the
        router treats a missing backend as simply not a candidate, so
        serving continues on the remaining ones."""
        key = f"{net}#{backend}"
        with self._cond:
            state = self._nets.pop(key, None)
            if state is None:
                return False
            if key in self._order:
                self._order.remove(key)
                self._rr = 0
            route = self._routes.get(net)
            if route and key in route:
                route.remove(key)
            stranded, sgroups = state.queue.drain()
            self._evict_retired_locked(state.opt)
            self._cond.notify_all()
        err = (f"rejected: backend {backend!r} of {net!r} "
               f"was unregistered")
        for t in stranded:
            t.finish(error=err, rejected=True)
        for g in sgroups:
            for t in g.tickets:
                t.finish(error=err, rejected=True)
            self._notify_done(g, None)
        return True

    def hot_swap(self, net: str, opt: OptimisedNetwork, *,
                 latency_budget_ms: Optional[float] = None,
                 expect_generation: Optional[int] = None,
                 canary: Optional[bool] = None) -> bool:
        """Atomically replace ``net``'s assignment (platform recalibrated).
        Weights are kept; already-claimed batches finish on the old plan; the
        next dispatch compiles (or cache-hits) the new one. Drift stats —
        including the observation buffer and the adaptive window scale —
        reset: the new model predicts on a new scale. ``expect_generation``
        makes the swap conditional (a background recalibration must not
        clobber a newer manual swap); returns False when the expectation
        fails. ``net`` may be a state key (``"net#backend"``) to swap one
        backend of a routed network.

        ``canary`` (None = the server-wide default) gates the swap behind a
        canary batch (DESIGN.md §11.4): the candidate serves a deterministic
        synthetic batch *before* commit, and is rejected — previous
        generation keeps serving, rejection recorded under ``canary_rejected``
        / the failure ledger — if it raises, corrupts output, or runs slower
        than ``canary_slowdown`` × the live generation's observed (else
        predicted) per-image cost. The committed swap pushes the outgoing
        generation onto a bounded rollback ring (``rollback(net)`` /
        auto-rollback revert to it)."""
        if canary is None:
            canary = self.canary_default
        with self._cond:
            net = self._resolve_key_locked(net)
            state = self._nets[net]
            if opt.spec.name != state.opt.spec.name:
                raise ValueError(f"hot_swap topology mismatch: {opt.spec.name!r} "
                                 f"vs {state.opt.spec.name!r}")
            if (expect_generation is not None
                    and state.generation != expect_generation):
                return False
            if not canary:
                self._commit_swap_locked(state, opt,
                                         latency_budget_ms=latency_budget_ms)
                generation = state.generation
            else:
                before = state.generation
                # the gate compares per-image cost AT THE CANARY BUCKET:
                # bucket-condition the live baseline the same way the
                # candidate is measured (§12.3) — a net whose small batches
                # are intrinsically pricier per image must not read as a
                # candidate slowdown
                baseline = self._per_image_locked(
                    state, pow2_ceil(self.canary_batch),
                    observed_first=True)
        if not canary:
            self._drift.reset(net, generation, layers=layer_profile(opt))
            self._precompile_plans(opt, state.weights)
            return True
        # canary outside the lock: the live generation keeps serving while
        # the candidate proves itself (it executes under the CANDIDATE
        # generation number, so fault plans can target exactly it)
        if not self._canary_gate(net, state, opt, before + 1, baseline):
            return False
        with self._cond:
            if (self._nets.get(net) is not state
                    or state.generation != before):
                return False       # re-registered or swapped while canarying
            self._commit_swap_locked(state, opt,
                                     latency_budget_ms=latency_budget_ms)
            generation = state.generation
        self._drift.reset(net, generation, layers=layer_profile(opt))
        self._precompile_plans(opt, state.weights)
        return True

    def _commit_swap_locked(self, state: _NetState, opt: OptimisedNetwork, *,
                            latency_budget_ms: Optional[float] = None,
                            remember: bool = True) -> None:
        """The swap itself (caller holds the lock). ``remember`` pushes the
        outgoing (generation, opt) onto the rollback ring — rollbacks pass
        False so the reverted-FROM generation cannot be rolled back INTO."""
        if remember and self.rollback_history > 0:
            state.history.append((state.generation, state.opt))
        if latency_budget_ms is not None:
            state.latency_budget_ms = latency_budget_ms
        outgoing = state.opt
        state.opt = opt
        # retire the outgoing generation's compiled-plan state (§13.3) —
        # in-flight batches hold their own opt/weights refs and fall back to
        # compile_plan, so eviction never breaks an already-claimed dispatch
        self._evict_retired_locked(outgoing)
        state.fallback_asg = None      # rebuild lazily for the new opt
        pred = opt.predicted_cost_s
        state.queue.batch_cap = self._batch_cap(pred,
                                                state.latency_budget_ms)
        state.queue.budget_s = self._budget_s(state.latency_budget_ms)
        state.queue.predicted_s = (pred if np.isfinite(pred) and pred > 0
                                   else 0.0)
        state.queue.window_scale = 1.0     # re-learn under the new model
        # the scale head was fitted against the OLD model's predictions and
        # the drift buffer resets with the swap: refit from fresh traffic
        state.bucket_head = None
        state.bucket_obs_at_fit = 0
        state.queue.bucket_scale = None
        state.generation += 1
        state.gen_bad_streak = 0           # unproven: auto-rollback is armed
        # superseded generations' bucket entries are never read again
        state.bucket_ready = {k: v for k, v in state.bucket_ready.items()
                              if k[0] >= state.generation}
        self._cond.notify_all()

    def _canary_gate(self, key: str, state: _NetState, opt: OptimisedNetwork,
                     generation: int, baseline: float) -> bool:
        """Serve one deterministic canary batch on the candidate, pre-commit
        (DESIGN.md §11.4). Two executions: the first warms (or cache-hits)
        the jit compile, the second is the timed verdict. Rejects on
        exception, corrupt output, or pathological slowdown vs the live
        generation's observed-or-predicted per-image cost."""
        # the canary serves `take` real rows padded to the pow2 bucket `b` —
        # per-image cost divides by the REAL row count: counting pad rows as
        # served images would optimistically shrink per-image cost whenever
        # canary_batch isn't a power of two, waving slow candidates through
        take = self.canary_batch
        b = pow2_ceil(take)
        n0 = opt.spec.nodes[0]
        rng = np.random.default_rng(generation)    # deterministic inputs
        xs = rng.standard_normal((b, n0.c, n0.im, n0.im)).astype(np.float32)
        reason = None
        try:
            self._run_faulted(key, generation, opt, xs, state.weights)
            t0 = self._clock()
            out = self._run_faulted(key, generation, opt, xs, state.weights)
            t1 = self._clock()
            validate_output(out, b)
            per_image = (t1 - t0) / take
            if (np.isfinite(baseline) and baseline > 0
                    and per_image > self.canary_slowdown * baseline):
                reason = (f"canary slowdown: {per_image * 1e3:.3f} ms/img vs "
                          f"baseline {baseline * 1e3:.3f} ms/img "
                          f"(gate {self.canary_slowdown:g}x)")
        except Exception as e:
            reason = f"canary failed: {e}"
        if reason is None:
            return True
        with self._cond:
            state.canary_rejected += 1
            state.last_canary = reason
        self._drift.record_failure(key, generation, "canary")
        return False

    # -- rollback ----------------------------------------------------------
    def rollback(self, net: str) -> bool:
        """Revert ``net`` (a state key for routed networks) to the previous
        generation's assignment from the rollback ring. False when there is
        no history to revert to."""
        return self._rollback(net, expect_generation=None)

    def _rollback(self, net: str,
                  expect_generation: Optional[int]) -> bool:
        with self._cond:
            try:
                key = self._resolve_key_locked(net)
            except KeyError:
                return False
            state = self._nets[key]
            if (expect_generation is not None
                    and state.generation != expect_generation):
                return False       # a newer swap already replaced the bad one
            if not state.history:
                return False
            bad_generation = state.generation
            _old_gen, old_opt = state.history.pop()
            self._commit_swap_locked(state, old_opt, remember=False)
            state.rollbacks += 1
            generation = state.generation
        self._drift.record_failure(key, bad_generation, "rollback")
        self._drift.reset(key, generation, layers=layer_profile(old_opt))
        self._precompile_plans(old_opt, state.weights)
        return True

    # -- request path ------------------------------------------------------
    def _route_keys_locked(self, net: str) -> List[str]:
        """State keys a request for ``net`` may land on: the exact state
        key when it exists (plain registration, or an explicit
        ``"net#backend"`` submit), else the logical net's live route."""
        if net in self._nets:
            return [net]
        keys = [k for k in self._routes.get(net, ()) if k in self._nets]
        if not keys:
            raise KeyError(f"network {net!r} not registered")
        return keys

    def _resolve_key_locked(self, net: str) -> str:
        """One state key for ``net``; routed networks must name the backend
        explicitly (``"net#backend"``) when more than one is registered."""
        keys = self._route_keys_locked(net)
        if len(keys) > 1:
            raise KeyError(f"{net!r} has backends "
                           f"{[self._nets[k].backend for k in keys]}; "
                           f"address one as 'net#backend'")
        return keys[0]

    def _route_score_locked(self, state: _NetState) -> float:
        """Predicted cost of sending ONE MORE image to this backend: its
        per-image cost (observed when it has served, else the perf model's
        prediction) times its backlog. Cheapest predicted backend wins an
        empty route; under load the score grows with the queue, spilling
        traffic to slower-but-idle backends (de Prado et al., 2018).

        The per-image cost is conditioned on the pow2 bucket the NEXT
        dispatch would run at (backlog + this request, capped at the batch
        cap) through the fitted scale head (§12.3) — a backend whose large
        buckets are super-linear stops looking artificially cheap under
        load."""
        backlog = state.queue.backlog_images(state.inflight)
        bucket = pow2_ceil(max(min(backlog + 1,
                                   max(state.queue.batch_cap, 1)), 1))
        per_image = self._per_image_locked(state, bucket,
                                           observed_first=True)
        if not (np.isfinite(per_image) and per_image > 0):
            per_image = 1e-6           # modelless entry: load-balance only
        return per_image * (backlog + 1)

    def submit(self, net: str, x: np.ndarray) -> Ticket:
        """Enqueue one request. The returned ticket is already finished (and
        ``rejected``) when the network's queue is full — backpressure instead
        of unbounded memory.

        Routed networks (``register(backend=...)``): the request goes to the
        backend with the cheapest predicted marginal cost; when that
        backend's queue is full the next-cheapest is tried before the
        request is rejected (DESIGN.md §9). Backends whose circuit breaker
        is open are skipped — the request spills to the healthy ones
        (DESIGN.md §11.2); a half-open breaker admits up to its probe quota.
        When EVERY breaker refuses, the full route is used anyway: degrading
        through a suspect backend beats black-holing the request."""
        x = np.asarray(x, np.float32)
        with self._cond:
            # validate/route against the states the ticket may land in — a
            # concurrent re-register may have changed the topology
            keys = self._route_keys_locked(net)
            n0 = self._nets[keys[0]].opt.spec.nodes[0]
            if x.shape != (n0.c, n0.im, n0.im):
                raise ValueError(f"{net!r} expects one ({n0.c}, {n0.im}, "
                                 f"{n0.im}) image per request, got {x.shape}")
            granted: List[str] = []
            if len(keys) > 1:       # plain registrations skip the gate/scorer
                now = self._clock()
                allowed = []
                for k in keys:
                    if self._nets[k].breaker.allow(now):
                        allowed.append(k)
                        granted.append(k)
                keys = allowed if allowed else keys
                keys.sort(key=lambda k:
                          self._route_score_locked(self._nets[k]))
            t = Ticket(net=keys[0], x=x, submitted_s=self._clock(),
                       clock=self._clock)
            pushed = None
            for k in keys:
                t.net = k
                if self._nets[k].queue.push(t):
                    pushed = k
                    break
            # probe slots granted to backends the ticket did NOT land on are
            # returned — a half-open breaker's quota meters dispatches that
            # actually happen, not routing considerations
            for k in granted:
                if k != pushed:
                    self._nets[k].breaker.cancel_probe()
            if pushed is not None:
                self._cond.notify()
                return t
            self._nets[keys[0]].rejected += 1
            t.finish(error=f"rejected: every backend of {net!r} at queue "
                           f"depth (backpressure)", rejected=True)
        return t

    def _notify_done(self, holder, out: Optional[np.ndarray]) -> None:
        """Fire a group/batch ``on_done`` exactly once (the executing
        worker's ``finally``, the supervisor's ``abandon``, and a drain all
        converge here — the callback swap under the lock picks one winner).
        ``out`` is the primary plan's padded output when every ticket was
        served by it, else None (results travel per-ticket)."""
        with self._cond:
            cb, holder.on_done = holder.on_done, None
        if cb is None:
            return
        try:
            cb(holder.tickets, out)
        except Exception:
            pass                       # front-end delivery is best-effort

    def _submit_group(self, net: str, xs: np.ndarray, rows: int, *,
                      handle=None, on_done: Optional[Callable] = None
                      ) -> BatchGroup:
        """Enqueue one pre-assembled slab batch from the process front end
        (DESIGN.md §12.2): ``xs`` is the pow2-padded batch (a zero-copy
        shared-memory view), ``rows`` of it real. Routing mirrors ``submit``
        — breaker-gated, cheapest-predicted-first, spilling on backpressure,
        whole-group — so the fault-tolerance contracts hold unchanged for
        slab dispatches. When every candidate queue is full the group is
        rejected whole: tickets finish rejected and ``on_done`` fires so the
        front end recycles the slab."""
        now = self._clock()
        tickets = [Ticket(net=net, x=xs[i], slab=handle, row=i,
                          submitted_s=now, clock=self._clock)
                   for i in range(rows)]
        g = BatchGroup(tickets=tickets, xs=xs, on_done=on_done)
        err = None
        with self._cond:
            try:
                keys = self._route_keys_locked(net)
            except KeyError as e:
                keys, err = [], str(e)
            granted: List[str] = []
            if len(keys) > 1:
                allowed = []
                for k in keys:
                    if self._nets[k].breaker.allow(now):
                        allowed.append(k)
                        granted.append(k)
                keys = allowed if allowed else keys
                keys.sort(key=lambda k:
                          self._route_score_locked(self._nets[k]))
            pushed = None
            for k in keys:
                for t in tickets:
                    t.net = k
                if self._nets[k].queue.push_group(g):
                    pushed = k
                    break
            for k in granted:
                if k != pushed:
                    self._nets[k].breaker.cancel_probe()
            if pushed is not None:
                self._cond.notify()
                return g
            if keys:
                self._nets[keys[0]].rejected += len(tickets)
                err = (f"rejected: every backend of {net!r} at queue "
                       f"depth (backpressure)")
        for t in tickets:
            t.finish(error=err, rejected=True)
        self._notify_done(g, None)
        return g

    # -- scheduling --------------------------------------------------------
    def _claim_locked(self, now: float, *, drain: bool = False) -> Optional[_Batch]:
        """Pop the next dispatchable batch (round-robin across networks),
        honouring in-flight limits and batch windows. Caller holds the lock."""
        n = len(self._order)
        for k in range(n):
            name = self._order[(self._rr + k) % n]
            state = self._nets[name]
            if state.inflight >= state.max_inflight:
                continue
            if not state.queue.ready(now, drain=drain):
                continue
            if state.queue.group_ready():
                # pre-assembled slab batch: dispatch whole, payload already
                # padded in shared memory (its window ran in the intake)
                group = state.queue.take_group()
                tickets, gxs, gdone = group.tickets, group.xs, group.on_done
            else:
                tickets = state.queue.take(state.queue.batch_cap)
                gxs = gdone = None
            state.inflight += 1
            t_claim = self._clock()
            for t in tickets:
                t.dispatched_s = t_claim
                state.waits.append(t.queue_wait_s)
            # deadline telemetry: the oldest ticket's wait vs the budget
            # drives the adaptive window cap (drift monitor owns the policy)
            scale = self._drift.observe_wait(name, state.generation,
                                             tickets[0].queue_wait_s,
                                             state.queue.budget_s)
            if scale is not None:
                state.queue.window_scale = scale
            self._rr = (self._rr + k + 1) % n
            return _Batch(net=name, tickets=tickets,
                          generation=state.generation, state=state,
                          opt=state.opt, weights=state.weights,
                          claimed_s=t_claim, xs=gxs, on_done=gdone)
        return None

    def claim_blocking(self, stop_event: threading.Event) -> Optional[_Batch]:
        """Worker-pool entry: block until a batch is dispatchable. During
        shutdown (``stop_event`` set) windows are ignored so queued tickets
        drain; returns None once stopping and every queue is empty."""
        idle = 0
        with self._cond:
            while True:
                stopping = stop_event.is_set()
                batch = self._claim_locked(self._clock(), drain=stopping)
                if batch is not None:
                    return batch
                now = self._clock()
                deadlines = [s.queue.next_deadline()
                             for s in self._nets.values()
                             if len(s.queue) and s.inflight < s.max_inflight]
                deadlines = [d for d in deadlines if d is not None]
                if stopping:
                    if not any(len(s.queue) for s in self._nets.values()):
                        return None
                    timeout = 0.01     # draining: re-check promptly
                elif deadlines:
                    gap = min(deadlines) - now
                    if gap <= 0.0:
                        # window already expired yet the claim was refused
                        # (in-flight cap, a competing pump won the race):
                        # geometric backoff instead of a hot re-poll loop
                        timeout = min(1e-4 * (1 << min(idle, 7)), 0.01)
                        idle += 1
                    else:
                        idle = 0
                        timeout = gap + 1e-4
                else:
                    # empty queues: sleep until submit/execute/stop notify —
                    # an idle server burns no CPU here
                    idle = 0
                    timeout = None
                self._cond.wait(timeout)

    # -- execution ---------------------------------------------------------
    @staticmethod
    def _bind_plan(opt: OptimisedNetwork, weights: Dict,
                   shape: Tuple[int, ...]):
        """One bound dispatch handle: the compiled plan for ``shape`` with
        the generation's weights closed over as jit constants and only the
        served sink returned. The per-call input pytree collapses to a
        single array — the weights dict is flattened once at trace time,
        not on every dispatch."""
        import jax
        from repro.primitives.plan import compile_plan
        plan = compile_plan(opt.spec, opt.assignment, shape)
        src, sink, fn = plan.sources[0], plan.sinks[-1], plan.fn
        return jax.jit(lambda a: fn({src: a}, weights)[sink])

    def _precompile_plans(self, opt: OptimisedNetwork,
                          weights: Dict) -> None:
        """Build AND WARM the per-pow2-bucket bound plan handles for
        ``opt`` (DESIGN.md §13.3). Each handle is traced and XLA-compiled
        here — on the register / recalibration thread, never on a
        dispatch — by running it once on zeros; steady-state ``_run_plan``
        then resolves its handle with two dict lookups and dispatches one
        single-array jit call instead of re-keying the global plan cache
        and re-flattening the weights pytree per batch. Dispatches that
        arrive before a bucket is warm (or for multi-input specs, which
        skip the eager pass) fall back to the content-keyed global plan
        cache, so serving never blocks on handle compilation."""
        import jax
        from repro.primitives.plan import source_nodes

        def publish() -> None:
            # Skip (and drop) if the generation was retired while warming,
            # so a racing hot_swap/unregister cannot leak handles.
            with self._cond:
                if any(st.opt is opt for st in self._nets.values()):
                    self._plan_handles[(id(opt), id(weights))] = (
                        opt, weights, dict(handles))
                else:
                    self._plan_handles.pop((id(opt), id(weights)), None)

        handles: Dict[Tuple[int, ...], object] = {}
        try:
            srcs = source_nodes(opt.spec)
            if len(srcs) == 1:
                n0 = opt.spec.nodes[srcs[0]]
                b, cap = 1, pow2_ceil(max(int(self.max_batch), 1))
                while b <= cap:
                    shape = (b, n0.c, n0.im, n0.im)
                    bound = self._bind_plan(opt, weights, shape)
                    jax.block_until_ready(bound(np.zeros(shape, np.float32)))
                    handles[shape] = bound
                    publish()          # smallest buckets go live first
                    b *= 2
        except Exception:
            publish()

    def _evict_retired_locked(self, old_opt: OptimisedNetwork) -> int:
        """Drop compiled-plan state for a retired generation (DESIGN.md
        §13.3): its precompiled handles, its entries in the global plan
        cache, and executor jit-cache entries for primitive columns no live
        registration serves any more. Skipped (handles aside) when another
        live backend still serves the identical (spec, assignment) pair. A
        later ``rollback`` into a retired generation simply recompiles.
        Caller holds the lock; returns evicted plan-cache entries."""
        from repro.primitives.executor import evict_prim_entries
        from repro.primitives.plan import evict_plans
        for k in [k for k, v in self._plan_handles.items()
                  if v[0] is old_opt]:
            del self._plan_handles[k]
        for st in self._nets.values():
            if (st.opt is not old_opt
                    and st.opt.spec.name == old_opt.spec.name
                    and st.opt.assignment == old_opt.assignment):
                return 0
        n = evict_plans(old_opt.spec, old_opt.assignment)
        live: set = set()
        for st in self._nets.values():
            live.update(st.opt.assignment.values())
        evict_prim_entries(set(old_opt.assignment.values()) - live)
        return n

    def _run_plan(self, opt: OptimisedNetwork, xs: np.ndarray,
                  weights: Dict) -> np.ndarray:
        """Execute one padded batch through the compiled whole-graph plan.
        Isolated so tests/experiments can wrap it (e.g. to emulate a machine
        that got slower). The precompiled bound-handle table is the
        steady-state path (one single-array jit dispatch); cold shapes,
        not-yet-warm buckets, and retired or unknown (opt, weights) pairs
        all fall back to the content-keyed global plan cache — a dispatch
        never compiles a bound handle."""
        ent = self._plan_handles.get((id(opt), id(weights)))
        if ent is not None and ent[0] is opt and ent[1] is weights:
            bound = ent[2].get(xs.shape)
            if bound is not None:
                # np.asarray on the jax output blocks AND copies to host in
                # one step — no separate block_until_ready round
                return np.asarray(bound(xs))
        import jax.numpy as jnp
        from repro.primitives.plan import compile_plan
        plan = compile_plan(opt.spec, opt.assignment, xs.shape)
        out = plan(jnp.asarray(xs), weights)[plan.sinks[-1]]
        return np.asarray(jax.block_until_ready(out))

    def _run_faulted(self, key: str, generation: int, opt: OptimisedNetwork,
                     xs: np.ndarray, weights: Dict) -> np.ndarray:
        """One plan execution, routed through the fault injector when one is
        configured — the single choke point shared by dispatches and canary
        batches, so a fault plan covers both."""
        if self._faults is not None:
            return self._faults.run(key, generation,
                                    lambda: self._run_plan(opt, xs, weights))
        return self._run_plan(opt, xs, weights)

    def _attempt(self, batch: _Batch, xs: np.ndarray, b: int) -> np.ndarray:
        """One primary execution attempt: compiled plan under the fault
        injector, output-validated (a silently corrupt result is a failure,
        not a delivery)."""
        out = self._run_faulted(batch.net, batch.generation, batch.opt, xs,
                                batch.weights)
        return validate_output(out, b)

    def _settle(self, batch: _Batch, *, primary_ok: bool, take: int, b: int,
                t0: float, t1: float) -> Tuple[bool, bool, bool]:
        """Release one claim exactly once: the in-flight slot, serving
        counters, compile bookkeeping, and the per-generation failure
        streak. Idempotent — the executing worker, its ``finally`` guard, a
        late-completing zombie, and the supervisor's ``abandon`` may all
        race here; the first caller wins and owns the outcome. Returns
        ``(settled_now, clean_timing, rollback_due)``."""
        state = batch.state
        clean = False
        roll = False
        with self._cond:
            if batch.settled:
                return False, False, False
            batch.settled = True
            state.inflight -= 1
            if primary_ok:
                state.dispatches += 1
                state.images += take
                state.padded += b - take
                state.busy_s += t1 - t0
                # a dispatch only times cleanly if it STARTED after the
                # bucket's first execution completed (no jit compile paid or
                # waited on — holds for any max_inflight)
                ready_at = state.bucket_ready.get((batch.generation, b))
                if ready_at is None:
                    state.bucket_ready[(batch.generation, b)] = t1
                else:
                    clean = t0 >= ready_at
                if state.generation == batch.generation:
                    state.gen_bad_streak = -1   # proven: never auto-rolled
            else:
                state.failed_dispatches += 1
                if (state.generation == batch.generation
                        and state.gen_bad_streak >= 0):
                    state.gen_bad_streak += 1
                    # == (not >=): concurrent failing batches of the same
                    # generation must trigger ONE rollback, not one each
                    roll = (self.auto_rollback > 0
                            and state.gen_bad_streak == self.auto_rollback
                            and len(state.history) > 0)
            self._cond.notify_all()
        return True, clean, roll

    def _fallback_asg(self, state: _NetState) -> Optional[Dict[int, str]]:
        """The state's safe-plan assignment, built lazily (reference-only
        primitives — see ``pipeline.safe_assignment``). ``{}`` caches an
        unbuildable spec so a broken topology is not re-attempted per
        failure."""
        if state.fallback_asg is None:
            from repro.service.pipeline import safe_assignment
            try:
                asg = safe_assignment(state.opt.spec)
            except Exception:
                asg = {}
            with self._cond:
                state.fallback_asg = asg
        return state.fallback_asg or None

    def _run_fallback(self, batch: _Batch, err: str) -> bool:
        """Degrade a failed dispatch to the safe plan (DESIGN.md §11.1):
        each ticket is served individually through the *interpreted*
        reference path (``executor.execute(compiled=False)``) — maximal
        independence from the compiled machinery that just failed, at
        reference-primitive speed. Per-ticket isolation: one pathological
        input fails its own ticket, not its batch peers. Returns True when
        the batch's tickets were all settled here (served or failed)."""
        state = batch.state
        asg = self._fallback_asg(state)
        if asg is None:
            return False
        import jax.numpy as jnp
        from repro.primitives.executor import execute as execute_reference
        from repro.primitives.plan import sink_nodes
        sink = sink_nodes(batch.opt.spec)[-1]
        served = 0
        for t in batch.tickets:
            if t.done:
                continue               # already settled (late rescue race)
            try:
                rep = execute_reference(batch.opt.spec, asg,
                                        weights=batch.weights,
                                        x=jnp.asarray(t.x), compiled=False)
                out = np.asarray(rep.outputs[sink])
                if t.finish(result=out, degraded=True):
                    served += 1
            except Exception as e:
                t.finish(error=f"{err}; fallback also failed: {e}")
        with self._cond:
            if served:
                state.fallback_dispatches += 1
                state.fallback_images += served
        return True

    def execute(self, batch: _Batch) -> None:
        """Run one claimed batch to completion: assemble and pad to the pow2
        bucket, execute the compiled plan (one retry on failure, then
        degrade to the safe fallback plan), deliver results, feed the
        breaker / failure ledger / drift monitor, release the in-flight
        slot. Never raises, and never leaks: batch assembly runs inside the
        guarded region (a malformed ticket fails its batch, not the worker),
        and the ``finally`` settle guarantees the in-flight slot and every
        ticket are released even if delivery itself blew up."""
        state = batch.state
        tickets = batch.tickets
        take = len(tickets)
        b = batch.xs.shape[0] if batch.xs is not None else pow2_ceil(take)
        err: Optional[str] = None
        kind: Optional[str] = None
        out = None
        abandoned = False
        t0 = t1 = self._clock()
        try:
            try:
                if batch.xs is not None:
                    # slab dispatch: the batch is already assembled, padded,
                    # and pow2-bucketed in shared memory — zero copies here
                    xs = batch.xs
                elif b == 1:
                    # lone unpadded request: a leading-axis view of the
                    # ticket's own array — no assembly copy at all (the plan
                    # copies on device transfer, exactly as a stacked batch
                    # would be)
                    xs = np.asarray(tickets[0].x)[None]
                elif state.max_inflight == 1:
                    # fast path (DESIGN.md §13.3): assemble into the state's
                    # preallocated bucket buffer — one write per row, no
                    # per-dispatch stack/concatenate allocations. Safe only
                    # with a single in-flight batch per state (the buffer is
                    # exclusive until this dispatch settles; the plan copies
                    # it on device transfer before the next claim can write)
                    row = np.asarray(tickets[0].x)
                    xs = state.pad_scratch.get(b)
                    if (xs is None or xs.shape[1:] != row.shape
                            or xs.dtype != row.dtype):
                        xs = np.empty((b,) + row.shape, row.dtype)
                        state.pad_scratch[b] = xs
                    for j, t in enumerate(tickets):
                        xs[j] = t.x
                    if b != take:
                        xs[take:] = xs[take - 1]
                else:
                    xs = np.stack([t.x for t in tickets])
                    if b != take:
                        pad = np.broadcast_to(xs[-1:],
                                              (b - take,) + xs.shape[1:])
                        xs = np.concatenate([xs, pad])
                t0 = self._clock()
                try:
                    out = self._attempt(batch, xs, b)
                except Exception as e:
                    kind = classify(e)
                    with self._cond:
                        state.retries += 1
                    try:   # one retry: a transient fault should cost a
                        out = self._attempt(batch, xs, b)   # retry, not
                    except Exception as e2:                 # degradation
                        err, kind = str(e2), classify(e2)
                t1 = self._clock()
            except Exception as e:     # batch assembly / bookkeeping failed
                err, kind = str(e), "error"
                t1 = self._clock()

            settled, clean_timing, roll = self._settle(
                batch, primary_ok=err is None, take=take, b=b, t0=t0, t1=t1)
            if not settled:
                # abandoned by the supervisor: it owns the outcome — a
                # zombie returning here must not touch the tickets, or it
                # races the supervisor's in-progress fallback rescue and
                # error-finishes tickets the rescue would have served
                abandoned = True
                return
            with self._cond:
                state.breaker.record(err is None, self._clock())
            if err is None:
                for j, t in enumerate(tickets):
                    t.finish(result=out[j])
                # drift: per-image served latency vs model prediction. A
                # cleanly timed dispatch is also one free measurement —
                # ``batch=b`` buffers it for served-sample recalibration
                pred = batch.opt.predicted_cost_s
                if (clean_timing and np.isfinite(pred) and pred > 0
                        and self._drift.observe(batch.net, batch.generation,
                                                (t1 - t0) / b, pred, batch=b)):
                    self._schedule_recalibration(batch.net, batch.generation)
                if clean_timing and self.bucket_cost_model:
                    self._refresh_bucket_head(batch.net, state)
                if clean_timing and self.probe_rate > 0:
                    self._maybe_probe(batch)
                return
            self._drift.record_failure(batch.net, batch.generation,
                                       kind or "error")
            if not (self.fallback and self._run_fallback(batch, err)):
                for t in tickets:
                    t.finish(error=err)
            with self._cond:
                state.failed_tickets += sum(1 for t in tickets
                                            if t.error is not None)
            if roll:
                self._rollback(batch.net,
                               expect_generation=batch.generation)
        finally:
            # leak-proofing: if anything above escaped, the claim still
            # settles and every ticket still finishes (both idempotent)
            self._settle(batch, primary_ok=False, take=take, b=b,
                         t0=t0, t1=t1)
            if not abandoned:
                for t in tickets:
                    t.finish(error=err or "internal serving error")
                # slab dispatches: tell the front end this batch settled
                # (every ticket finished above) so it can recycle the slab
                # and ship results; an abandoned batch's supervisor owns it
                self._notify_done(batch, out if err is None else None)

    def abandon(self, batch: _Batch, reason: str) -> None:
        """Give up on a claim whose worker hung past the execution deadline
        or died (called by the ``WorkerPool`` supervisor — DESIGN.md §11.3).
        Settles the batch (no-op if the dispatch actually finished first),
        trips the breaker/ledger, and rescues the tickets through the
        fallback plan so a hung backend costs latency, not answers. The
        zombie worker's own eventual settle/finish attempts lose the race
        by construction."""
        take = len(batch.tickets)
        b = pow2_ceil(take)
        settled, _clean, roll = self._settle(batch, primary_ok=False,
                                             take=take, b=b, t0=0.0, t1=0.0)
        if not settled:
            return
        kind = "deadline" if reason == "deadline" else "died"
        with self._cond:
            batch.state.breaker.record(False, self._clock())
        self._drift.record_failure(batch.net, batch.generation, kind)
        msg = (f"abandoned: worker {reason} executing {batch.net!r} "
               f"generation {batch.generation}")
        try:
            rescued = self.fallback and self._run_fallback(batch, msg)
        except Exception:
            rescued = False
        if not rescued:
            for t in batch.tickets:
                t.finish(error=msg)
        self._notify_done(batch, None)
        if roll:
            self._rollback(batch.net, expect_generation=batch.generation)

    # -- batch-shape cost model -------------------------------------------
    def _refresh_bucket_head(self, key: str, state: _NetState) -> None:
        """Refit the per-bucket scale head from the served-traffic buffer
        (DESIGN.md §12.3) once enough clean observations accumulated, then
        re-derive everything that consumes batch-shape-aware cost: the
        queue's ``bucket_scale`` (deadline windows) and the backend's batch
        cap. Cheap (a handful of EW means), so it runs on the dispatch path;
        the refit cadence bounds it further."""
        n_obs = len(self._drift.observations(key))
        with self._cond:
            if (n_obs < BUCKET_MIN_OBS
                    or n_obs - state.bucket_obs_at_fit < BUCKET_REFRESH_EVERY):
                return
            state.bucket_obs_at_fit = n_obs
        head = self._drift.bucket_head(key, min_obs=2)
        with self._cond:
            if self._nets.get(key) is not state:
                return                 # re-registered while fitting
            state.bucket_head = head
            state.queue.bucket_scale = (head.scale if head is not None
                                        else None)
            state.queue.batch_cap = self._bucket_batch_cap_locked(state)

    # -- probe dispatches (DESIGN.md §14.4) --------------------------------
    def _maybe_probe(self, batch: _Batch) -> None:
        """Rate-limited single-layer probe, piggybacked after a clean
        dispatch on the same worker thread. At most one probe per
        ``1/probe_rate`` seconds per state; targets round-robin over the
        generation's attribution profile. Probes run under the fault
        injector (the §11 contract covers them) but never enter the queue
        — no ticket, no wait sample, no drift-buffer entry — so served
        latency percentiles and the bucket-scale head cannot see them."""
        state = batch.state
        interval = 1.0 / self.probe_rate
        now = self._clock()
        with self._cond:
            if (self._nets.get(batch.net) is not state
                    or state.generation != batch.generation
                    or now - state.last_probe_s < interval):
                return
            state.last_probe_s = now
            idx = state.probe_rr
            state.probe_rr += 1
        layers = self._drift.layer_profile(batch.net)
        if layers is None or not len(layers.columns):
            return
        i = idx % len(layers.columns)
        cfg = layers.feats[i]
        col = layers.columns[i]
        pred = float(layers.predicted[i])
        try:
            if self._faults is not None:
                obs = self._faults.run(batch.net, batch.generation,
                                       lambda: self._run_probe(batch.opt,
                                                               cfg, col))
            else:
                obs = self._run_probe(batch.opt, cfg, col)
            obs = float(obs)
            if not (np.isfinite(obs) and obs > 0):
                raise ValueError(f"probe measured {obs!r}")
        except ProbeUnsupported:
            return                     # column not runnable here: skip, not
        except Exception:              # a failure — the cursor advanced
            with self._cond:
                state.probe_failures += 1
            self._drift.record_failure(batch.net, batch.generation, "probe")
            return
        if self._drift.record_probe(batch.net, batch.generation, cfg, col,
                                    obs, pred):
            with self._cond:
                state.probes += 1

    def _run_probe(self, opt: OptimisedNetwork, config, column: str) -> float:
        """Measure one (config, primitive) directly: run the column's kernel
        on a synthetic single image, timing a warmed second call. Returns
        per-image seconds. Isolated so tests (and simulated-platform
        harnesses) can substitute their own measurement."""
        from repro.primitives.conv import is_runnable, run_primitive
        if not is_runnable(column):
            raise ProbeUnsupported(column)
        k, c, im, s, f = (int(v) for v in np.asarray(config).reshape(-1))
        rng = np.random.default_rng(0)
        x = rng.standard_normal((c, im, im)).astype(np.float32)
        w = rng.standard_normal((k, c, f, f)).astype(np.float32)
        jax.block_until_ready(run_primitive(column, x, w, s))   # warm/compile
        t0 = self._clock()
        jax.block_until_ready(run_primitive(column, x, w, s))
        return self._clock() - t0

    def poll_pool(self, store, *, host: Optional[str] = None) -> int:
        """Check the shared store for fleet drift evidence this server has
        not yet acted on (DESIGN.md §14.3): for each registered state whose
        platform fingerprint has fresh ``drift_pool`` entries from *other*
        hosts, schedule one background recalibration — the recalibrator
        (built with ``make_recalibrator(pool=True)``) pulls the pooled
        datasets itself. Returns how many recalibrations were scheduled.
        Callers drive this on their own cadence (a timer, the CLI, tests);
        a faulty backend read skips the poll, never the serving path."""
        scheduled = 0
        with self._cond:
            items = list(self._nets.items())
        for key, state in items:
            platform = state.opt.platform
            if platform is None:
                continue
            try:
                entries = store.drift_entries(platform.pool_fingerprint(),
                                              exclude_host=host)
            except OSError:
                continue
            fresh = [m for m in entries
                     if m.get("key") not in state.pool_seen]
            if not fresh:
                continue
            with self._cond:
                if self._nets.get(key) is not state:
                    continue
                state.pool_seen.update(m.get("key") for m in fresh)
                gen = state.generation
            self._schedule_recalibration(key, gen)
            scheduled += 1
        return scheduled

    # -- drift-triggered recalibration ------------------------------------
    def served_sample(self, net: str):
        """The buffered served observations attributed to layer configs, as
        a ``PerfDataset`` ready for ``platform.calibrate(served=...)`` —
        None when nothing attributable was served (§8.5). The dataset
        carries the attribution summary (dispatches, per-bucket counts and
        drift) as ``served_info`` so recalibration reports can surface the
        batch-shape mix the sample was drawn from. Probe-dispatch
        measurements (§14.4), when any were recorded, ride along as their
        own single-column rows."""
        att = self._drift.attributed(net)
        pro = self._drift.probe_attributed(net)
        if att is None and pro is None:
            return None
        if att is not None:
            feats, cols, bucket_rows, info = att
        else:
            layers = self._drift.layer_profile(net)
            width = layers.feats.shape[1] if layers is not None else 5
            feats = np.empty((0, width), np.float64)
            cols, bucket_rows, info = (), [], {}
        probe_rows, probe_info = pro if pro is not None else ([], {})
        info = {**info, **probe_info}
        with self._cond:
            state = self._nets.get(net)
            platform = state.opt.platform if state is not None else None
        from repro.profiler.dataset import observations_to_dataset
        columns = sorted(set(cols) | {c for _, c, _ in probe_rows})
        return observations_to_dataset(
            feats, cols, bucket_rows, columns=columns,
            platform=platform.name if platform is not None else "served",
            info=info, probes=probe_rows or None)

    def _schedule_recalibration(self, net: str, generation: int) -> None:
        if self._recalibrate is None:
            return
        th = threading.Thread(target=self._recalibration_worker,
                              args=(net, generation), daemon=True,
                              name=f"recal-{net}-g{generation}")
        # _recal_threads is touched from worker threads (here) and the
        # caller's thread (recalibrations_idle/stop): mutate under the lock
        with self._cond:
            self._recal_threads = [t for t in self._recal_threads
                                   if t.is_alive()]
            self._recal_threads.append(th)
        th.start()

    def _recalibration_worker(self, net: str, generation: int) -> None:
        state = self._nets.get(net)
        if state is None:
            return                   # backend unregistered while scheduled
        with self._cond:
            if state.generation != generation:
                return               # swapped while we were scheduled
            opt = state.opt
        try:
            if self._recal_served:
                new_opt = self._recalibrate(opt,
                                            served=self.served_sample(net))
            else:
                new_opt = self._recalibrate(opt)
        except Exception as e:       # serving continues on the stale model
            with self._cond:
                state.last_recal_error = str(e)
            return
        if self.hot_swap(net, new_opt, expect_generation=generation):
            with self._cond:
                state.recalibrations += 1
                state.last_recal_sample = getattr(new_opt.models,
                                                  "sample_info", None)

    def recalibrations_idle(self) -> bool:
        """True when no background recalibration is in flight (tests/CLI)."""
        with self._cond:
            self._recal_threads = [t for t in self._recal_threads
                                   if t.is_alive()]
            return not self._recal_threads

    # -- synchronous path --------------------------------------------------
    def pump(self, drain: bool = True, idle_wait_s: float = 0.0) -> int:
        """Serve queued tickets inline on the calling thread, returning the
        dispatch count. ``drain=True`` (the ``workers=0`` serving mode)
        ignores batch windows — pump IS the arrival of serving capacity.
        ``drain=False`` dispatches only batches that are *ready* (full, or
        window expired against the injected clock) — the deterministic poll
        used by window-semantics tests. With a worker pool running, pump
        simply competes for claims and remains safe.

        ``idle_wait_s`` > 0 adds idle backoff for external polling loops:
        when nothing is dispatchable, pump blocks on the server condition —
        woken by ``submit`` or bounded by the earliest window deadline, up
        to ``idle_wait_s`` — instead of returning immediately and letting
        the caller busy-spin a core against an empty queue. The default (0)
        keeps the exact non-blocking contract (window tests drive an
        injected clock and must never sleep)."""
        dispatches = 0
        waited = False
        while True:
            with self._cond:
                batch = self._claim_locked(self._clock(), drain=drain)
                if (batch is None and idle_wait_s > 0.0 and not waited
                        and dispatches == 0):
                    waited = True
                    now = self._clock()
                    deadlines = [d for d in
                                 (s.queue.next_deadline()
                                  for s in self._nets.values()
                                  if len(s.queue))
                                 if d is not None]
                    timeout = idle_wait_s
                    if deadlines:
                        timeout = min(idle_wait_s,
                                      max(min(deadlines) - now, 0.0) + 1e-4)
                    self._cond.wait(timeout)
                    batch = self._claim_locked(self._clock(), drain=drain)
            if batch is None:
                return dispatches
            self.execute(batch)
            dispatches += 1

    def serve(self, net: str, xs: Sequence[np.ndarray], *,
              timeout: float = 120.0) -> List[np.ndarray]:
        """Submit a burst and block until every ticket finishes (sync
        convenience). Raises if any request failed or was rejected. In pump
        mode the caller IS the drain, so a burst larger than ``queue_depth``
        drains mid-submission instead of tripping backpressure."""
        if self._pool is not None and self._pool.running:
            tickets = [self.submit(net, x) for x in xs]
            deadline = self._clock() + timeout
            for t in tickets:
                if not t.wait(max(deadline - self._clock(), 0.0)):
                    raise TimeoutError(f"{net!r}: ticket not served within "
                                       f"{timeout:.1f}s")
        else:
            tickets = []
            for x in xs:
                t = self.submit(net, x)
                if t.rejected:               # queue full: drain, retry once
                    self.pump()
                    t = self.submit(net, x)
                tickets.append(t)
            self.pump()
        failed = [t.error for t in tickets if t.error]
        if failed:
            raise RuntimeError(f"{len(failed)} request(s) failed: {failed[0]}")
        return [t.result for t in tickets]

    # -- introspection -----------------------------------------------------
    def _state_stats_locked(self, key: str) -> Dict:
        s = self._nets[key]
        waits = np.asarray(s.waits, np.float64)
        head = s.bucket_head
        return {"batch_cap": s.queue.batch_cap, "generation": s.generation,
                # per-backend cap derivation (§12.3): the resolved latency
                # budget and the bucket-conditioned per-image cost at the cap
                "latency_budget_ms": self._budget_s(s.latency_budget_ms)
                * 1e3,
                "predicted_per_image_ms": self._per_image_locked(
                    s, s.queue.batch_cap) * 1e3,
                "bucket_scales": ({int(b): head.scale(b)
                                   for b in head.buckets()}
                                  if head is not None else None),
                "dispatches": s.dispatches, "images": s.images,
                "padded": s.padded, "busy_s": s.busy_s,
                "images_per_s": (s.images / s.busy_s if s.busy_s else 0.0),
                "queued": len(s.queue), "inflight": s.inflight,
                "rejected": s.rejected,
                "recalibrations": s.recalibrations,
                "last_recal_error": s.last_recal_error,
                "recal_sample": s.last_recal_sample,
                "window_scale": s.queue.window_scale,
                "effective_wait_ms": s.queue.effective_wait_s() * 1e3,
                "queue_wait_p50_ms": (float(np.percentile(waits, 50)) * 1e3
                                      if waits.size else 0.0),
                "queue_wait_p99_ms": (float(np.percentile(waits, 99)) * 1e3
                                      if waits.size else 0.0),
                # fault tolerance (DESIGN.md §11)
                "breaker": (s.breaker.snapshot(self._clock())
                            if s.breaker is not None else None),
                "retries": s.retries,
                "failed_dispatches": s.failed_dispatches,
                "failed_tickets": s.failed_tickets,
                "fallback_dispatches": s.fallback_dispatches,
                "fallback_images": s.fallback_images,
                "canary_rejected": s.canary_rejected,
                "last_canary": s.last_canary,
                "rollbacks": s.rollbacks,
                # probe dispatches (DESIGN.md §14.4)
                "probes": s.probes,
                "probe_failures": s.probe_failures}

    def stats(self, net: str) -> Dict:
        """Serving stats for ``net`` — a state key or a logical name. A
        routed network aggregates its backends (sums for counters, pooled
        percentiles for queue waits) and adds a ``"backends"`` map: per
        backend, the full per-state stats including dispatch counts and
        p50/p99 queueing latency."""
        with self._cond:
            keys = self._route_keys_locked(net)
            per = {k: self._state_stats_locked(k) for k in keys}
            names = {k: self._nets[k].backend for k in keys}
            pooled = [np.asarray(self._nets[k].waits, np.float64)
                      for k in keys]
        for k in keys:
            per[k]["drift_ratio"] = self._drift.ratio(k)
            per[k]["observed_dispatches"] = len(self._drift.observations(k))
            per[k]["failures"] = self._drift.failures(k)
        if len(keys) == 1 and names[keys[0]] is None:
            return per[keys[0]]                # plain single-backend network
        out: Dict = {"backends": {names[k] or k: per[k] for k in keys}}
        for fld in ("dispatches", "images", "padded", "rejected", "queued",
                    "inflight", "recalibrations", "observed_dispatches",
                    "retries", "failed_dispatches", "failed_tickets",
                    "fallback_dispatches", "fallback_images",
                    "canary_rejected", "rollbacks", "probes",
                    "probe_failures"):
            out[fld] = sum(per[k][fld] for k in keys)
        failures: Dict[str, int] = {}
        for k in keys:
            merge_failures(failures, per[k]["failures"])
        out["failures"] = failures
        out["busy_s"] = sum(per[k]["busy_s"] for k in keys)
        out["images_per_s"] = (out["images"] / out["busy_s"]
                               if out["busy_s"] else 0.0)
        for fld in ("batch_cap", "generation", "window_scale",
                    "effective_wait_ms"):
            out[fld] = max(per[k][fld] for k in keys)
        ratios = [per[k]["drift_ratio"] for k in keys
                  if per[k]["drift_ratio"] is not None]
        out["drift_ratio"] = max(ratios) if ratios else None
        for fld in ("last_recal_error", "recal_sample", "last_canary"):
            out[fld] = next((per[k][fld] for k in keys
                             if per[k][fld] is not None), None)
        waits = (np.concatenate(pooled) if any(w.size for w in pooled)
                 else np.empty(0))
        out["queue_wait_p50_ms"] = (float(np.percentile(waits, 50)) * 1e3
                                    if waits.size else 0.0)
        out["queue_wait_p99_ms"] = (float(np.percentile(waits, 99)) * 1e3
                                    if waits.size else 0.0)
        return out

    def backends(self, net: str) -> List[str]:
        """Registered backend names for ``net`` (empty for a plain
        single-backend registration)."""
        with self._cond:
            return sorted(self._nets[k].backend
                          for k in self._routes.get(net, ())
                          if k in self._nets
                          and self._nets[k].backend is not None)

    @property
    def networks(self) -> List[str]:
        return sorted(self._nets)


def _accepts_served(recalibrate: Optional[Callable]) -> bool:
    """Whether ``recalibrate`` takes the served-sample keyword — legacy
    single-argument recalibrators stay supported (fresh-profiling path)."""
    if recalibrate is None:
        return False
    try:
        params = inspect.signature(recalibrate).parameters
    except (TypeError, ValueError):
        return False
    return ("served" in params
            or any(p.kind is inspect.Parameter.VAR_KEYWORD
                   for p in params.values()))


def make_recalibrator(*, store=None, sample_n: int = 16, mode: str = "factor",
                      budget: Optional[float] = None,
                      max_iters: Optional[int] = None,
                      seed: int = 0,
                      use_served: bool = True,
                      pool: bool = False,
                      host: Optional[str] = None) -> Callable:
    """Default drift-recalibration policy (DESIGN.md §8.3/§8.5). With
    ``use_served`` (default) the server's buffered served observations form
    the calibration sample, freshly measuring only the configs the buffer
    misses; without them (or with ``use_served=False``) it falls back to
    freshly measuring ``sample_n`` configs on the network's platform
    (post-drift truth). Either way: ``calibrate`` the current models onto
    the sample, re-solve the PBQP, return the new ``OptimisedNetwork`` for
    ``hot_swap``. The sample seed advances per call so successive excursions
    draw different configs.

    ``budget`` selects a third policy that overrides served reuse entirely:
    a plain budgeted re-calibration against the platform's (cached) dataset
    — no ``measure_sample``, no served sample. Use it when the platform's
    profiling pool is cheap/trusted and drift triggers should simply re-run
    the §4.4 transfer at that budget.

    ``pool`` (DESIGN.md §14.3, needs ``store``) joins the fleet: every
    recalibration first *publishes* this host's served evidence under the
    platform fingerprint (best-effort — a flaky backend costs the fleet the
    evidence, never the local recalibration), then pulls the other hosts'
    newest pooled datasets and calibrates from local + fleet samples. A
    host with no local observations (woken by ``poll_pool``) recalibrates
    from fleet evidence alone, profiling nothing. ``host`` names this
    machine in the pool (see ``platforms.host_machine_id``)."""
    counter = itertools.count()

    def recalibrate(opt: OptimisedNetwork,
                    served=None) -> OptimisedNetwork:
        k = next(counter)
        pooled = None
        if pool and store is not None and opt.platform is not None:
            fp = opt.platform.pool_fingerprint()
            if served is not None and host is not None:
                try:
                    store.publish_drift(fp, served, host=host, net=opt.net)
                except OSError:
                    pass
            try:
                pooled = store.pooled_drift(fp, exclude_host=host) or None
            except OSError:
                pooled = None
        if (use_served and budget is None
                and (served is not None or pooled)):
            return reoptimise(opt, served=served, pooled=pooled,
                              sample_n=sample_n, mode=mode, store=store,
                              seed=seed + k, max_iters=max_iters)
        sample = (opt.platform.measure_sample(sample_n, seed=seed + k)
                  if budget is None else None)
        return reoptimise(opt, sample=sample,
                          budget=0.05 if budget is None else budget,
                          mode=mode, store=store, seed=seed,
                          max_iters=max_iters)

    return recalibrate


# ---------------------------------------------------------------------------
# CLI: optimise-on-arrival, then serve
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Optimise a CNN for a platform and serve it "
                    "(concurrent worker-pool serving core).")
    ap.add_argument("--net", default="edge_cnn")
    ap.add_argument("--platform", default="arm",
                    help="intel | amd | arm (simulated) | host (real CPU) | "
                         "tpu (autotuned Pallas tile columns)")
    ap.add_argument("--backends", default=None, metavar="P1,P2,...",
                    help="register the net on each of these platforms as a "
                         "routed backend and dispatch every request to the "
                         "predicted-cheapest one (default: the single "
                         "--platform backend, unrouted)")
    ap.add_argument("--transfer-from", default=None, metavar="PLATFORM",
                    help="calibrate from this platform's pretrained model "
                         "(the paper's §4.4 path) instead of native training")
    ap.add_argument("--calib-budget", type=float, default=0.01,
                    help="calibration sample budget (fraction or row count)")
    ap.add_argument("--store", default="artifacts",
                    help="artifact store root ('' disables warm-start)")
    ap.add_argument("--store-backend", choices=("local", "object"),
                    default="local",
                    help="artifact-store backend: 'local' (directory at "
                         "--store) or 'object' (in-process simulated object "
                         "store — the fleet-sharing demo backend; DESIGN.md "
                         "§14.1)")
    ap.add_argument("--pool-drift", action="store_true",
                    help="fleet calibration pooling (DESIGN.md §14.3): "
                         "publish this host's served drift evidence to the "
                         "store under the platform fingerprint and fold the "
                         "fleet's pooled datasets into every drift "
                         "recalibration")
    ap.add_argument("--probe-rate", type=float, default=0.0,
                    help="max single-layer probe dispatches per second "
                         "(0 disables): rate-limited direct measurements of "
                         "assigned (config, primitive) pairs that correct "
                         "relative primitive costs in the pooled sample "
                         "(DESIGN.md §14.4)")
    ap.add_argument("--keep", type=int, default=None,
                    help="artifact GC: keep only the newest K artifacts per "
                         "category after each put (default: keep all)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--latency-budget-ms", "--budget-ms", dest="budget_ms",
                    type=float, default=50.0,
                    help="per-request latency budget: sets the perf-model "
                         "batch cap AND caps each batch window at budget "
                         "minus the predicted execution time (deadline-aware "
                         "batching)")
    ap.add_argument("--workers", type=int, default=0,
                    help="serving worker threads; 0 = synchronous pump mode")
    ap.add_argument("--frontend-procs", type=int, default=0,
                    help="intake processes assembling request batches in "
                         "shared-memory slabs and handing them to the "
                         "worker pool by reference (requires --workers >= "
                         "1); 0 = thread-only front end")
    ap.add_argument("--no-bucket-cost-model", action="store_true",
                    help="disable the batch-shape-aware cost model: batch "
                         "caps, deadline windows, router scores, and the "
                         "canary gate assume per-image cost is "
                         "batch-size-invariant (the pre-§12.3 behaviour)")
    ap.add_argument("--backend-budget-ms", default=None,
                    metavar="P1=MS,P2=MS,...",
                    help="per-backend latency budgets for routed serving "
                         "(--backends): each backend derives its own batch "
                         "cap from its budget and its bucket-aware "
                         "predicted cost (default: --latency-budget-ms for "
                         "every backend)")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="batch window cap: max time a ticket waits for "
                         "batch peers before its partial batch dispatches "
                         "(the deadline-aware effective window never exceeds "
                         "it)")
    ap.add_argument("--queue-depth", type=int, default=256,
                    help="per-network queue bound; submits beyond it are "
                         "rejected (backpressure)")
    ap.add_argument("--drift-threshold", type=float, default=1.5,
                    help="served/predicted latency EWMA ratio that triggers "
                         "background recalibration + hot swap")
    ap.add_argument("--drift-alpha", type=float, default=0.25,
                    help="EWMA smoothing for the drift ratio")
    ap.add_argument("--obs-cap", type=int, default=256,
                    help="served-observation buffer size per network (the "
                         "free recalibration sample)")
    ap.add_argument("--recal-sample-n", type=int, default=16,
                    help="calibration sample size for drift recalibration; "
                         "configs the served buffer covers cost no profiling")
    ap.add_argument("--no-served-reuse", action="store_true",
                    help="disable served-observation reuse: drift "
                         "recalibration always freshly profiles its full "
                         "sample (the pre-§8.5 behaviour)")
    ap.add_argument("--max-triplets", type=int, default=60,
                    help="simulated profiling pool size")
    ap.add_argument("--max-iters", type=int, default=2000)
    ap.add_argument("--hot-swap", action="store_true",
                    help="recalibrate mid-run and hot-swap the assignment")
    ap.add_argument("--exec-deadline-ms", type=float, default=None,
                    help="per-dispatch execution deadline: the worker "
                         "supervisor abandons (and rescues via fallback) "
                         "dispatches exceeding it, replacing the hung "
                         "worker (default: disabled)")
    ap.add_argument("--no-fallback", action="store_true",
                    help="disable graceful degradation: a failed dispatch "
                         "fails its tickets instead of retrying them on "
                         "the safe reference plan")
    ap.add_argument("--canary", action="store_true",
                    help="gate every hot_swap behind a canary batch: a "
                         "candidate that errors, corrupts output, or runs "
                         "pathologically slow is rejected and the previous "
                         "generation keeps serving")
    ap.add_argument("--breaker-failures", type=int, default=3,
                    help="consecutive dispatch failures that open a "
                         "backend's circuit breaker (routed traffic then "
                         "spills to healthy backends)")
    ap.add_argument("--breaker-window", type=int, default=16,
                    help="sliding outcome window for the breaker's "
                         "error-rate trip")
    ap.add_argument("--breaker-rate", type=float, default=0.5,
                    help="error rate over a full window that opens the "
                         "breaker")
    ap.add_argument("--breaker-cooldown-ms", type=float, default=250.0,
                    help="open-state hold before half-open probe dispatches "
                         "test the backend again")
    ap.add_argument("--rollback-history", type=int, default=4,
                    help="hot-swap generations kept per net for "
                         "rollback (0 disables)")
    args = ap.parse_args(argv)

    from repro.service.artifacts import ArtifactStore
    from repro.service.platforms import get_platform, host_machine_id
    from repro.service.store_backends import get_backend

    store = (ArtifactStore(args.store, keep=args.keep,
                           backend=get_backend(args.store_backend,
                                               args.store))
             if args.store else None)
    pool_host = host_machine_id() if args.pool_drift else None
    specs = ([s.strip() for s in args.backends.split(",") if s.strip()]
             if args.backends else [args.platform])
    routed = len(specs) > 1

    base = None
    if args.transfer_from:
        base_plat = get_platform(args.transfer_from,
                                 max_triplets=args.max_triplets)
        base = base_plat.pretrain("nn2", store=store,
                                  max_iters=args.max_iters)
        print(f"[serve] base model: {args.transfer_from} "
              f"({'warm' if base.warm else 'cold'}, {base.seconds:.2f}s)")

    opts = []
    for spec_name in specs:
        # host platforms persist their profiled datasets through the store,
        # so repeat CLI runs skip the expensive real-CPU measurement pass
        plat_kw = {"store": store} if spec_name == "host" else \
            {"max_triplets": args.max_triplets}
        platform = get_platform(spec_name, **plat_kw)
        opt = optimise(args.net, platform, store=store, base=base,
                       budget=args.calib_budget, executable=True,
                       max_iters=args.max_iters)
        print(f"[serve] optimised {opt.net} for {platform.fingerprint()}: "
              f"{'warm' if opt.warm else 'cold'} in {opt.seconds:.2f}s, "
              f"predicted {opt.predicted_cost_s*1e3:.3f} ms/img")
        opts.append((spec_name, opt))
    opt = opts[0][1]

    budgets: Dict[str, float] = {}
    if args.backend_budget_ms:
        for part in args.backend_budget_ms.split(","):
            name, _, ms = part.partition("=")
            if not ms:
                raise SystemExit(f"--backend-budget-ms expects P=MS pairs, "
                                 f"got {part!r}")
            budgets[name.strip()] = float(ms)

    server = OptimisedServer(latency_budget_ms=args.budget_ms,
                             workers=args.workers,
                             max_wait_ms=args.max_wait_ms,
                             queue_depth=args.queue_depth,
                             drift_threshold=args.drift_threshold,
                             drift_alpha=args.drift_alpha,
                             obs_cap=args.obs_cap,
                             exec_deadline_ms=args.exec_deadline_ms,
                             fallback=not args.no_fallback,
                             canary=args.canary,
                             breaker_failures=args.breaker_failures,
                             breaker_window=args.breaker_window,
                             breaker_rate=args.breaker_rate,
                             breaker_cooldown_ms=args.breaker_cooldown_ms,
                             rollback_history=args.rollback_history,
                             bucket_cost_model=not args.no_bucket_cost_model,
                             frontend_procs=args.frontend_procs,
                             probe_rate=args.probe_rate,
                             recalibrate=make_recalibrator(
                                 store=store,
                                 sample_n=args.recal_sample_n,
                                 use_served=not args.no_served_reuse,
                                 pool=args.pool_drift and store is not None,
                                 host=pool_host))
    for spec_name, o in opts:
        # routed backends serve one at a time each; the worker pool overlaps
        # them across backends instead
        server.register(o, backend=spec_name if routed else None,
                        latency_budget_ms=budgets.get(spec_name),
                        max_inflight=1 if routed else None)
    s = server.stats(opt.net)
    print(f"[serve] batch cap {s['batch_cap']} "
          f"(budget {args.budget_ms:.0f} ms), workers={args.workers}, "
          f"window={args.max_wait_ms:.1f} ms "
          f"(effective {s['effective_wait_ms']:.2f} ms)")

    n0 = opt.spec.nodes[0]
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((args.requests, n0.c, n0.im, n0.im)).astype(np.float32)
    server.serve(opt.net, xs[: min(4, args.requests)])   # warm the plan
    t0 = time.perf_counter()
    server.serve(opt.net, xs)
    dt = time.perf_counter() - t0
    s = server.stats(opt.net)
    print(f"[serve] {args.requests} requests in {dt*1e3:.0f} ms "
          f"({args.requests/dt:.1f} img/s, {s['dispatches']} dispatches, "
          f"{s['padded']} padded, queue p50/p99 "
          f"{s['queue_wait_p50_ms']:.2f}/{s['queue_wait_p99_ms']:.2f} ms, "
          f"{s['observed_dispatches']} observations buffered)")
    if routed:
        for b, bs in s["backends"].items():
            print(f"[serve]   backend {b}: {bs['dispatches']} dispatches, "
                  f"{bs['images']} images, queue p50/p99 "
                  f"{bs['queue_wait_p50_ms']:.2f}/"
                  f"{bs['queue_wait_p99_ms']:.2f} ms, "
                  f"breaker {bs['breaker']['state']}")
    if s["failed_dispatches"] or s["fallback_images"]:
        print(f"[serve] faults: {s['failed_dispatches']} failed dispatches "
              f"({s['retries']} retried), {s['fallback_images']} images "
              f"served degraded, ledger {s['failures']}")
    if args.probe_rate > 0:
        print(f"[serve] probes: {s['probes']} measured, "
              f"{s['probe_failures']} failed (rate cap "
              f"{args.probe_rate:g}/s)")

    if args.pool_drift and store is not None:
        served = server.served_sample(opt.net)
        if served is not None:
            plat_fp = opt.platform.pool_fingerprint()
            store.publish_drift(plat_fp, served, host=pool_host, net=opt.net)
            print(f"[serve] published {served.n} drift-evidence rows for "
                  f"{plat_fp} as host {pool_host}")
        polled = server.poll_pool(store, host=pool_host)
        print(f"[serve] fleet pool: {len(store.entries('drift_pool'))} "
              f"entries, {polled} recalibrations scheduled from other "
              f"hosts' evidence")

    if args.hot_swap:
        spec_name, o = opts[0]
        recal = optimise(args.net, o.platform, store=store, base=o.models,
                         budget=max(args.calib_budget * 5, 0.05),
                         mode="finetune", executable=True,
                         max_iters=args.max_iters)
        key = f"{opt.net}#{spec_name}" if routed else opt.net
        server.hot_swap(key, recal)
        server.serve(opt.net, xs[:8])
        print(f"[serve] hot-swapped to recalibrated assignment "
              f"(generation {server.stats(key)['generation']})")

    if args.frontend_procs > 0:
        fe = server.frontend()
        agg = fe.drive(opt.net, args.requests, seed=1)
        print(f"[serve] frontend: {args.frontend_procs} intake procs, "
              f"{agg['requests']} requests -> {agg['served']} served "
              f"({agg['degraded']} degraded, {agg['failed']} failed, "
              f"{agg['rejected']} rejected) at {agg['images_per_s']:.1f} "
              f"img/s, mean latency {agg['latency_mean_ms']:.2f} ms")
    server.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
