"""Worker pool: threads that claim and execute batches (DESIGN.md §8.2).

Plan execution is a jitted XLA computation — JAX releases the GIL while it
runs — so plain ``threading`` genuinely overlaps plan execution across
networks (and overlaps one network's Python-side batch assembly with
another's compute). The pool is deliberately dumb: every scheduling decision
(timed windows, per-state in-flight limits, fairness) lives in the serving
core's ``claim_blocking``; a worker just loops claim → execute.

Multi-backend networks (DESIGN.md §9) need no pool support: each backend
registration is its own claimable state with its own queue and in-flight
limit, so with ``workers >= 2`` and per-backend ``max_inflight=1`` two
backends of one network genuinely execute in parallel.

``stop()`` is graceful by default: workers first drain every queued ticket
(windows ignored — shutdown must not strand requests), then exit.
"""
from __future__ import annotations

import threading
from typing import List, Optional


class WorkerPool:
    """N daemon threads running ``core.claim_blocking`` → ``core.execute``.

    ``core`` duck-type: ``claim_blocking(stop_event) -> Optional[claim]``
    (None means "stopping and nothing left to drain") and ``execute(claim)``.
    """

    def __init__(self, core, workers: int, name: str = "serve"):
        if workers < 1:
            raise ValueError(f"worker count must be >= 1, got {workers}")
        self.core = core
        self.workers = workers
        self.name = name
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "WorkerPool":
        self._threads = [t for t in self._threads if t.is_alive()]
        if self._threads:
            return self
        # a FRESH event per pool incarnation: each worker captures its own,
        # so a zombie from a timed-out stop() keeps seeing its (set) event
        # and can never be revived by a later start()
        self._stop = threading.Event()
        for i in range(self.workers):
            t = threading.Thread(target=self._run, args=(self._stop,),
                                 daemon=True,
                                 name=f"{self.name}-worker-{i}")
            t.start()
            self._threads.append(t)
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Signal shutdown and join. Workers drain queued tickets first so
        no submitted request is stranded undone. Threads that outlive the
        join timeout stay tracked (still winding down), never revivable."""
        self._stop.set()
        self.core.wake_all()
        for t in self._threads:
            t.join(timeout)
        self._threads = [t for t in self._threads if t.is_alive()]

    @property
    def running(self) -> bool:
        return any(t.is_alive() for t in self._threads)

    # -- worker body -------------------------------------------------------
    def _run(self, stop: threading.Event) -> None:
        while True:
            claim = self.core.claim_blocking(stop)
            if claim is None:
                return
            self.core.execute(claim)
