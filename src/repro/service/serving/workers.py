"""Supervised worker pool: threads that claim and execute batches, plus a
supervisor that detects hung or dead workers (DESIGN.md §8.2, §11.3).

Plan execution is a jitted XLA computation — JAX releases the GIL while it
runs — so plain ``threading`` genuinely overlaps plan execution across
networks (and overlaps one network's Python-side batch assembly with
another's compute). The pool stays deliberately dumb about *scheduling*:
every decision (timed windows, per-state in-flight limits, fairness) lives
in the serving core's ``claim_blocking``; a worker loops claim → execute.

What the pool does own is *liveness* (DESIGN.md §11.3). Each worker runs in
a slot that records its in-progress claim; a supervisor thread polls the
slots and intervenes when:

  * the worker thread **died** mid-claim (an exception escaped everything —
    should be unreachable, ``execute`` never raises, but a supervisor that
    assumes that is not a supervisor): the claim is ``abandon``ed (in-flight
    slot released, tickets rescued or failed) and a fresh worker takes the
    slot;
  * the claim **exceeded the execution deadline** (``core.exec_deadline_s``,
    measured on the core's injectable clock from claim time): a hung plan —
    stuck device, runaway kernel — cannot be interrupted from Python, so the
    claim is abandoned the same way and the stuck thread is *replaced*: a
    fresh worker takes the slot and the zombie, still blocked inside the
    plan, discovers on completion that it was replaced and exits. Its
    eventual settle attempt is a no-op: the core's per-batch settle guard
    and the tickets' first-finish-wins make duplicate delivery structurally
    impossible.

Multi-backend networks (DESIGN.md §9) need no pool support: each backend
registration is its own claimable state with its own queue and in-flight
limit, so with ``workers >= 2`` and per-backend ``max_inflight=1`` two
backends of one network genuinely execute in parallel.

The process front end (DESIGN.md §12) needs no pool support either: a
pre-assembled slab batch arrives through ``claim_blocking`` as an ordinary
claim whose ``xs`` is already a padded shared-memory view, so the worker
skips batch assembly entirely and executes straight out of the slab —
supervision, abandonment, and zombie replacement apply to it unchanged
(a zombie's stale slab read is discarded by the first-finish-wins settle).

``stop()`` is graceful by default: workers first drain every queued ticket
(windows ignored — shutdown must not strand requests), then exit.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional

SUPERVISOR_POLL_S = 0.01      # real-time poll; deadlines use the core clock


class _Slot:
    """One worker position: the live thread and its in-progress claim."""

    def __init__(self, index: int):
        self.index = index
        self.thread: Optional[threading.Thread] = None
        self.claim = None            # the _Batch being executed, else None


class WorkerPool:
    """N daemon threads running ``core.claim_blocking`` → ``core.execute``,
    under a supervisor enforcing liveness.

    ``core`` duck-type: ``claim_blocking(stop_event) -> Optional[claim]``
    (None means "stopping and nothing left to drain") and ``execute(claim)``.
    Supervision additionally uses, when present: ``abandon(claim, reason)``
    (rescue/fail a claim whose worker is gone), ``exec_deadline_s`` (per-
    dispatch execution deadline; None disables), and ``_clock`` (the core's
    injectable clock — deadlines must be drivable from tests).
    """

    def __init__(self, core, workers: int, name: str = "serve"):
        if workers < 1:
            raise ValueError(f"worker count must be >= 1, got {workers}")
        self.core = core
        self.workers = workers
        self.name = name
        self.restarts = 0            # workers replaced (hung or died)
        self._slots: List[_Slot] = []
        self._zombies: List[threading.Thread] = []
        self._supervisor: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._spawn_seq = 0

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "WorkerPool":
        with self._lock:
            if any(s.thread is not None and s.thread.is_alive()
                   for s in self._slots):
                return self
            # a FRESH event per pool incarnation: each worker captures its
            # own, so a zombie from a timed-out stop() keeps seeing its (set)
            # event and can never be revived by a later start()
            self._stop = threading.Event()
            self._slots = [_Slot(i) for i in range(self.workers)]
            for s in self._slots:
                self._spawn_locked(s, self._stop)
            self._supervisor = threading.Thread(
                target=self._supervise, args=(self._stop,), daemon=True,
                name=f"{self.name}-supervisor")
            self._supervisor.start()
        return self

    def _spawn_locked(self, slot: _Slot, stop: threading.Event) -> None:
        self._spawn_seq += 1
        t = threading.Thread(target=self._run, args=(slot, stop),
                             daemon=True,
                             name=f"{self.name}-worker-{slot.index}"
                                  f".{self._spawn_seq}")
        slot.thread = t
        t.start()

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Signal shutdown and join. Workers drain queued tickets first so
        no submitted request is stranded undone. Threads that outlive the
        join timeout (zombies stuck in a hung plan included) are left to
        die with the process — daemonised, never revivable."""
        self._stop.set()
        self.core.wake_all()
        with self._lock:
            threads = [s.thread for s in self._slots if s.thread is not None]
            threads += self._zombies
            sup = self._supervisor
        for t in threads:
            t.join(timeout)
        if sup is not None:
            sup.join(timeout)
        with self._lock:
            self._zombies = [t for t in self._zombies if t.is_alive()]
            for s in self._slots:
                if s.thread is not None and not s.thread.is_alive():
                    s.thread = None
            self._slots = [s for s in self._slots if s.thread is not None]
            self._supervisor = None

    @property
    def running(self) -> bool:
        with self._lock:
            return any(s.thread is not None and s.thread.is_alive()
                       for s in self._slots)

    # -- worker body -------------------------------------------------------
    def _run(self, slot: _Slot, stop: threading.Event) -> None:
        me = threading.current_thread()
        while True:
            with self._lock:
                if slot.thread is not me:
                    return           # replaced while executing: zombie exits
            claim = self.core.claim_blocking(stop)
            if claim is None:
                return
            with self._lock:
                if slot.thread is me:
                    slot.claim = claim
            try:
                self.core.execute(claim)
            except BaseException:    # execute() never raises by contract;
                # if it somehow does, the claim must not leak its in-flight
                # slot or strand its tickets — rescue, then keep serving
                abandon = getattr(self.core, "abandon", None)
                if abandon is not None:
                    abandon(claim, "died")
            finally:
                with self._lock:
                    if slot.claim is claim:
                        slot.claim = None

    # -- supervisor --------------------------------------------------------
    def _supervise(self, stop: threading.Event) -> None:
        clock = getattr(self.core, "_clock", time.monotonic)
        abandon = getattr(self.core, "abandon", None)
        while not stop.is_set():
            time.sleep(SUPERVISOR_POLL_S)
            deadline = getattr(self.core, "exec_deadline_s", None)
            with self._lock:
                self._zombies = [t for t in self._zombies if t.is_alive()]
                now = clock()
                for slot in self._slots:
                    t, claim = slot.thread, slot.claim
                    if t is None or stop.is_set():
                        continue
                    dead = not t.is_alive()
                    # a settled claim is a finished dispatch whose worker has
                    # not yet cleared its slot field — slow, not hung
                    hung = (not dead and claim is not None
                            and deadline is not None
                            and not getattr(claim, "settled", False)
                            and now - getattr(claim, "claimed_s", now)
                            > deadline)
                    if not dead and not hung:
                        continue
                    if claim is not None and abandon is not None:
                        reason = "died" if dead else "deadline"
                        # release the pool lock around abandon: it takes the
                        # core lock and may execute a fallback plan
                        slot.claim = None
                        self._lock.release()
                        try:
                            abandon(claim, reason)
                        finally:
                            self._lock.acquire()
                    if not dead:
                        self._zombies.append(t)   # stuck in the plan: shed it
                    self.restarts += 1
                    self._spawn_locked(slot, stop)

    @property
    def zombies(self) -> int:
        """Hung worker threads shed by the supervisor and still running."""
        with self._lock:
            return sum(1 for t in self._zombies if t.is_alive())
