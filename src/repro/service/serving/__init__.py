"""Concurrent serving core (DESIGN.md §8): per-network queues with timed
batch windows, a worker pool overlapping plan execution across networks, and
drift-triggered recalibration closing the profile → model → select → serve →
observe → recalibrate loop.

    from repro.service.serving import OptimisedServer, make_recalibrator

    server = OptimisedServer(workers=2, max_wait_ms=5.0,
                             recalibrate=make_recalibrator(store=store))
    server.register(opt)
    ticket = server.submit(opt.net, image)
    ticket.wait()
"""
from repro.service.serving.drift import (DriftMonitor, DriftStats,
                                         LayerProfile, ServedObservation)
from repro.service.serving.faults import Fault, FaultError, FaultInjector
from repro.service.serving.frontend import (ProcessFrontend, SlabHandle,
                                            SlabPool)
from repro.service.serving.health import CircuitBreaker, CorruptOutput
from repro.service.serving.queues import BatchGroup, NetQueue, Ticket
from repro.service.serving.server import (OptimisedServer, layer_profile,
                                          main, make_recalibrator)
from repro.service.serving.workers import WorkerPool

__all__ = [
    "BatchGroup", "CircuitBreaker", "CorruptOutput", "DriftMonitor",
    "DriftStats", "Fault", "FaultError", "FaultInjector", "LayerProfile",
    "NetQueue", "OptimisedServer", "ProcessFrontend", "ServedObservation",
    "SlabHandle", "SlabPool", "Ticket", "WorkerPool",
    "layer_profile", "main", "make_recalibrator",
]
