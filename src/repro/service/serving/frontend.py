"""Process-level serving front end (DESIGN.md §12).

The worker pool overlaps *plan execution* across threads because XLA
releases the GIL — but Python-side batch assembly (ticket intake, payload
copies, pow2 padding, result slicing) does not, so a thread-only front end
plateaus regardless of worker count. This module moves batch assembly into
**intake processes**:

  * ``SlabPool`` — a shared-memory tensor pool: preallocated pow2-bucket
    slabs (``multiprocessing.shared_memory``) recycled through a free-list
    ring. An intake process writes each request payload ONCE into a slab
    row; everything downstream passes the ``SlabHandle`` by reference.
  * ``_intake_main`` — the intake process body: receives requests (or
    synthesizes load in ``drive`` mode), assembles pow2-padded batches
    directly inside a slab, and emits compact batch descriptors.
  * ``ProcessFrontend`` — the parent-side manager: a dispatcher thread
    turns descriptors into pre-assembled ``BatchGroup``s (zero-copy slab
    views) that the serving core's workers execute directly; results ship
    back to the owning intake in one bulk message per batch, where per-row
    slicing happens off the serving process's GIL.

Slab lifecycle: intake ``alloc`` → intake writes rows + pad → dispatcher
``view`` (zero-copy) → workers execute the view → the dispatch settles →
``on_done`` frees the slab and ships results. The free happens only after
every ticket of the batch settled (the core's ``finally`` guarantees it), so
a recycled slot can never be overwritten under a live dispatch; a zombie
worker still reading a recycled slab sees garbage whose output is discarded
by the first-finish-wins settle — stale reads are harmless by construction.
Handles carry a per-slot generation: ``free``/``view`` with a stale handle
raise instead of silently aliasing a newer allocation.

Fault tolerance is unchanged: groups route through the same breaker-gated
scorer as loose tickets, execute under the fault injector, degrade to the
fallback plan per ticket, and settle idempotently — the shm path changes
where bytes live, not the delivery contract.

Intake processes use the ``spawn`` start method and import only numpy +
this module's light dependencies — never JAX — so a JAX-initialised parent
is safe.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue as pyqueue
import threading
import time
import traceback
from typing import Callable, Dict, List, Optional, Tuple

import multiprocessing as mp
from multiprocessing import shared_memory

import numpy as np

from repro.service.serving.queues import (BatchGroup, Ticket, monotonic,
                                          pow2_ceil)

_CTX = mp.get_context("spawn")

# intake assembly: how long an alloc retries when the pool is exhausted
# (server-side frees are what replenish it) before the batch is rejected
ALLOC_WAIT_S = 5.0
ALLOC_POLL_S = 0.001
# parent dispatcher/reply loops: bounded poll so stop() is prompt without
# busy-spinning (queue.get blocks in C, releasing the GIL)
PARENT_POLL_S = 0.1


@dataclasses.dataclass(frozen=True)
class SlabHandle:
    """A by-reference claim on one slab: pow2 ``bucket`` rows in ``slot`` of
    that bucket's segment. ``generation`` is the slot's allocation epoch —
    a freed handle goes stale and any further ``view``/``free`` raises."""

    bucket: int
    slot: int
    generation: int


class SlabPool:
    """Preallocated pow2-bucket shared-memory slabs + a free-list ring.

    One data segment per bucket (``slots`` slabs of ``bucket`` images each)
    plus one int64 control segment holding, per bucket: ring head, free
    count, the ring of free slot ids, and a per-slot generation counter.
    All mutation happens under one cross-process lock; ``view`` re-checks
    the generation unlocked as a best-effort stale-handle guard.

    The creating process owns the segments (``close(unlink=True)``);
    intake processes ``attach`` by name and only ever ``close()``.
    """

    def __init__(self, image_shape: Tuple[int, ...], *, max_batch: int = 32,
                 slots: int = 16, dtype=np.float32):
        self.image_shape = tuple(int(d) for d in image_shape)
        self.dtype = np.dtype(dtype)
        self.slots = int(slots)
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.buckets: List[int] = []
        b, top = 1, pow2_ceil(max_batch)
        while b <= top:
            self.buckets.append(b)
            b *= 2
        self.lock = _CTX.Lock()
        self._owner = True
        item = int(np.prod(self.image_shape)) * self.dtype.itemsize
        self._item = item
        self._data = {b: shared_memory.SharedMemory(
            create=True, size=max(b * item * self.slots, 1))
            for b in self.buckets}
        per = 2 + 2 * self.slots
        self._ctrl = shared_memory.SharedMemory(
            create=True, size=8 * per * len(self.buckets))
        self._c = np.ndarray((len(self.buckets), per), dtype=np.int64,
                             buffer=self._ctrl.buf)
        for bi in range(len(self.buckets)):
            row = self._c[bi]
            row[0] = 0                       # ring head
            row[1] = self.slots              # free count
            row[2:2 + self.slots] = np.arange(self.slots)   # the ring
            row[2 + self.slots:] = 0         # per-slot generation

    # -- cross-process handoff --------------------------------------------
    def spec(self) -> Dict:
        """Picklable attach recipe (segment names + geometry). The lock is
        NOT in here — multiprocessing primitives must travel through
        ``Process`` args, so pass ``(spec, lock)`` pairs."""
        return {"image_shape": self.image_shape, "dtype": self.dtype.str,
                "slots": self.slots, "buckets": list(self.buckets),
                "data": {b: self._data[b].name for b in self.buckets},
                "ctrl": self._ctrl.name}

    @classmethod
    def attach(cls, spec: Dict, lock) -> "SlabPool":
        """Map an existing pool by name. The attaching process never
        unlinks. Attachers must be processes sharing the owner's resource
        tracker (spawn children, or the owner's own process): attaching
        re-registers each segment with that one shared tracker, which is
        set-idempotent — unregistering here instead (the workaround for
        *unrelated* attaching processes, which run their own tracker) would
        strip the owner's registration and unbalance the tracker at
        unlink."""
        self = cls.__new__(cls)
        self.image_shape = tuple(spec["image_shape"])
        self.dtype = np.dtype(spec["dtype"])
        self.slots = int(spec["slots"])
        self.buckets = [int(b) for b in spec["buckets"]]
        self.lock = lock
        self._owner = False
        self._item = int(np.prod(self.image_shape)) * self.dtype.itemsize
        self._data = {}
        segs = []
        try:
            for b in self.buckets:
                self._data[b] = shared_memory.SharedMemory(
                    name=spec["data"][b])
                segs.append(self._data[b])
            self._ctrl = shared_memory.SharedMemory(name=spec["ctrl"])
        except BaseException:
            for s in segs:
                try:
                    s.close()
                except Exception:
                    pass
            raise
        per = 2 + 2 * self.slots
        self._c = np.ndarray((len(self.buckets), per), dtype=np.int64,
                             buffer=self._ctrl.buf)
        return self

    # -- alloc / free / view ----------------------------------------------
    def _index(self, bucket: int) -> int:
        b = pow2_ceil(bucket)
        try:
            return self.buckets.index(b)
        except ValueError:
            raise ValueError(f"bucket {bucket} outside pool ladder "
                             f"{self.buckets}") from None

    def alloc(self, bucket: int) -> Optional[SlabHandle]:
        """Claim one free slab of (at least) ``bucket`` rows; None when that
        bucket's ring is empty (backpressure — the server replenishes the
        ring as dispatches settle)."""
        bi = self._index(bucket)
        b = self.buckets[bi]
        with self.lock:
            row = self._c[bi]
            if row[1] == 0:
                return None
            head = int(row[0])
            slot = int(row[2 + head])
            row[0] = (head + 1) % self.slots
            row[1] -= 1
            gen = int(row[2 + self.slots + slot])
        return SlabHandle(bucket=b, slot=slot, generation=gen)

    def free(self, h: SlabHandle) -> None:
        """Return a slab to its ring. Bumps the slot generation, so the
        handle (and any copy of it) is dead afterwards — double frees and
        use-after-free raise instead of aliasing the next allocation."""
        bi = self._index(h.bucket)
        with self.lock:
            row = self._c[bi]
            if int(row[2 + self.slots + h.slot]) != h.generation:
                raise ValueError(f"stale slab handle {h}: slot already "
                                 f"recycled (double free?)")
            row[2 + self.slots + h.slot] += 1
            tail = (int(row[0]) + int(row[1])) % self.slots
            row[2 + tail] = h.slot
            row[1] += 1

    def view(self, h: SlabHandle, rows: Optional[int] = None) -> np.ndarray:
        """Zero-copy ndarray over the slab: ``(bucket, *image_shape)``, or
        the first ``rows`` of it. Raises on a stale handle."""
        bi = self._index(h.bucket)
        if int(self._c[bi, 2 + self.slots + h.slot]) != h.generation:
            raise ValueError(f"stale slab handle {h}")
        off = h.slot * h.bucket * self._item
        arr = np.ndarray((h.bucket,) + self.image_shape, dtype=self.dtype,
                         buffer=self._data[h.bucket].buf, offset=off)
        return arr if rows is None else arr[:rows]

    def available(self, bucket: int) -> int:
        bi = self._index(bucket)
        with self.lock:
            return int(self._c[bi, 1])

    # -- lifecycle ---------------------------------------------------------
    def close(self, unlink: Optional[bool] = None) -> None:
        """Unmap the segments; the owner also unlinks the names. Lingering
        zero-copy views (a ticket someone still holds) keep their mapping
        alive — the close is best-effort, the unlink unconditional."""
        if unlink is None:
            unlink = self._owner
        self._c = None
        for shm in list(self._data.values()) + [self._ctrl]:
            try:
                shm.close()
            except BufferError:
                pass               # a live view pins the mapping; fine
            if unlink and self._owner:
                try:
                    shm.unlink()
                except FileNotFoundError:
                    pass



# ---------------------------------------------------------------------------
# Intake process
# ---------------------------------------------------------------------------

class _Pending:
    """One in-assembly batch inside an intake process: the claimed slab,
    rows written so far, per-row request ids (None rows = drive mode), and
    the window start."""

    def __init__(self, handle: SlabHandle, buf: np.ndarray, t0: float):
        self.handle = handle
        self.buf = buf                 # (bucket, *image_shape) slab view
        self.rows = 0
        self.req_ids: List[Optional[int]] = []
        self.t0 = t0


def _flush(pool: SlabPool, outbox, idx: int, seq, inflight: Dict,
           net: str, p: _Pending) -> None:
    """Pad the pending rows to their pow2 bucket inside the slab (replicate
    the last real row) and emit the batch descriptor."""
    b = pow2_ceil(p.rows)
    if b > p.rows:
        p.buf[p.rows:b] = p.buf[p.rows - 1]
    bid = next(seq)
    inflight[bid] = (net, p.req_ids, time.perf_counter())
    outbox.put(("batch", idx, bid, net, p.handle, p.rows))


def _alloc_blocking(pool: SlabPool, bucket: int) -> Optional[SlabHandle]:
    """Alloc with bounded retry: the ring refills as the server settles
    dispatches, so exhaustion is transient backpressure, not an error —
    until ``ALLOC_WAIT_S``, after which the caller rejects the batch."""
    deadline = time.perf_counter() + ALLOC_WAIT_S
    while True:
        h = pool.alloc(bucket)
        if h is not None or time.perf_counter() > deadline:
            return h
        time.sleep(ALLOC_POLL_S)


def _intake_main(idx: int, pools_arg: Dict, inbox, outbox, reply_q) -> None:
    """Intake process body. Messages on ``inbox``:

    ``("cfg", net, cfg)``            per-net assembly policy (cap, wait_s)
    ``("req", req_id, net, payload)`` one externally-submitted request
    ``("drive", net, n, seed)``      synthesize ``n`` request payloads
    ``("done", bid, payload)``       results of one emitted batch
    ``("stop",)``                    drain nothing, exit now

    No busy-spin: with nothing pending the loop blocks on ``inbox.get``;
    with an open assembly window it blocks until that window's deadline.
    """
    pools = {net: SlabPool.attach(spec, lock)
             for net, (spec, lock) in pools_arg.items()}
    cfg: Dict[str, Dict] = {}
    pending: Dict[str, _Pending] = {}
    inflight: Dict[int, Tuple[str, List[Optional[int]], float]] = {}
    seq = itertools.count()
    drives: Dict[str, Dict] = {}       # net -> accounting for a drive job
    templates: Dict[str, np.ndarray] = {}
    stop = False

    def window_deadline() -> Optional[float]:
        if not pending:
            return None
        return min(p.t0 + cfg[n]["wait_s"] for n, p in pending.items())

    def start_pending(net: str) -> Optional[_Pending]:
        c = cfg[net]
        h = _alloc_blocking(pools[net], c["cap"])
        if h is None:
            return None
        return _Pending(h, pools[net].view(h), time.perf_counter())

    def add_row(net: str, payload: Optional[np.ndarray],
                req_id: Optional[int]) -> None:
        p = pending.get(net)
        if p is None:
            p = start_pending(net)
            if p is None:              # pool exhausted beyond patience
                if req_id is not None:
                    reply_q.put(("reply", idx, [req_id], [None],
                                 ["rejected: slab pool exhausted"], [False]))
                elif net in drives:
                    drives[net]["rejected"] += 1
                    drives[net]["resolved"] += 1
                return
            pending[net] = p
        if payload is None:            # drive mode: template row, one write
            p.buf[p.rows] = templates[net]
        else:
            p.buf[p.rows] = payload
        p.req_ids.append(req_id)
        p.rows += 1
        if p.rows >= cfg[net]["cap"]:
            _flush(pools[net], outbox, idx, seq, inflight, net,
                   pending.pop(net))

    def pump_drive() -> bool:
        """Generate at most one batch worth of drive rows; True when any
        drive job still has rows to generate."""
        for net, job in drives.items():
            if job["to_generate"] <= 0:
                continue
            n = min(job["to_generate"], cfg[net]["cap"])
            for _ in range(n):
                add_row(net, None, None)
                job["to_generate"] -= 1
            if net in pending:         # partial tail: let the window run
                if job["to_generate"] <= 0 and pending[net].rows:
                    _flush(pools[net], outbox, idx, seq, inflight, net,
                           pending.pop(net))
            return True
        return any(j["to_generate"] > 0 for j in drives.values())

    def handle_done(bid: int, payload) -> None:
        net, req_ids, t_sub = inflight.pop(bid)
        kind = payload[0]
        if kind == "bulk":             # every row served by the primary plan
            rows = payload[1]
            results = [rows[i] for i in range(len(req_ids))]
            errors: List[Optional[str]] = [None] * len(req_ids)
            degraded = [False] * len(req_ids)
        else:
            _, results, errors, degraded = payload
        ext = [i for i, r in enumerate(req_ids) if r is not None]
        if ext:
            reply_q.put(("reply", idx, [req_ids[i] for i in ext],
                         [results[i] for i in ext],
                         [errors[i] for i in ext],
                         [degraded[i] for i in ext]))
        job = drives.get(net)
        if job is not None:
            mine = sum(1 for r in req_ids if r is None)
            if mine:
                lat = time.perf_counter() - t_sub
                for i, r in enumerate(req_ids):
                    if r is not None:
                        continue
                    job["resolved"] += 1
                    if errors[i] is not None:
                        key = ("rejected" if "rejected" in errors[i]
                               else "failed")
                        job[key] += 1
                    elif degraded[i]:
                        job["degraded"] += 1
                        job["served"] += 1
                    else:
                        job["served"] += 1
                job["latency_sum"] += lat * mine
            if job["resolved"] >= job["requests"]:
                job["seconds"] = time.perf_counter() - job["t0"]
                done = dict(job)
                done.pop("t0", None)
                reply_q.put(("drove", idx, net, done))
                del drives[net]

    try:
        while True:
            if stop and not inflight and not pending:
                break
            busy = pump_drive()
            dl = window_deadline()
            if dl is not None:
                timeout = max(dl - time.perf_counter(), 0.0) + 1e-4
            elif busy:
                timeout = 0.0
            elif stop:
                timeout = 0.05         # only waiting on in-flight results
            else:
                timeout = None         # idle: block, no spinning
            try:
                msg = (inbox.get_nowait() if timeout == 0.0
                       else inbox.get(timeout=timeout))
            except pyqueue.Empty:
                msg = None
            if msg is not None:
                kind = msg[0]
                if kind == "cfg":
                    _, net, c = msg
                    cfg[net] = c
                    rng = np.random.default_rng(1000 + idx)
                    templates[net] = rng.standard_normal(
                        c["image_shape"]).astype(np.float32)
                elif kind == "req":
                    _, req_id, net, payload = msg
                    add_row(net, np.asarray(payload, np.float32), req_id)
                elif kind == "drive":
                    _, net, n, seed = msg
                    rng = np.random.default_rng(seed)
                    templates[net] = rng.standard_normal(
                        cfg[net]["image_shape"]).astype(np.float32)
                    drives[net] = {"requests": int(n), "to_generate": int(n),
                                   "resolved": 0, "served": 0, "degraded": 0,
                                   "failed": 0, "rejected": 0,
                                   "latency_sum": 0.0, "seconds": 0.0,
                                   "t0": time.perf_counter()}
                elif kind == "done":
                    handle_done(msg[1], msg[2])
                elif kind == "stop":
                    stop = True
            # expired windows flush even when the inbox stays quiet
            now = time.perf_counter()
            for net in [n for n, p in pending.items()
                        if now - p.t0 >= cfg[n]["wait_s"]]:
                _flush(pools[net], outbox, idx, seq, inflight, net,
                       pending.pop(net))
    except BaseException:
        reply_q.put(("fatal", idx, traceback.format_exc()))
    finally:
        for pool in pools.values():
            pool.close()


# ---------------------------------------------------------------------------
# Parent-side manager
# ---------------------------------------------------------------------------

class ProcessFrontend:
    """N intake processes + a dispatcher thread feeding pre-assembled slab
    batches into an ``OptimisedServer`` (DESIGN.md §12.2).

    Two entry points:

    * ``ingest(net, xs)`` — ship request payloads to the intake processes
      (round-robin) and get parent-side tickets back; the assembly, padding
      and result slicing all happen in the children.
    * ``drive(net, requests)`` — synthetic intake: each process generates
      its share of the load locally (modelling network receivers), writes
      payloads straight into slabs, and accounts served/degraded/failed
      until every request resolves. This is the benchmark/soak loadgen.
    """

    def __init__(self, server, procs: int, *, slots: int = 16):
        if procs < 1:
            raise ValueError(f"frontend procs must be >= 1, got {procs}")
        self.server = server
        self.procs = procs
        self.slots = slots
        self._pools: Dict[str, SlabPool] = {}
        self._cfg: Dict[str, Dict] = {}
        self._inboxes = [_CTX.Queue() for _ in range(procs)]
        self._outbox = _CTX.Queue()
        self._reply_q = _CTX.Queue()
        self._children: List = []
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._rr = 0
        self._req_seq = itertools.count()
        self._pending_lock = threading.Lock()
        self._pending: Dict[int, Ticket] = {}
        self._drive_results: Dict[Tuple[int, str], Dict] = {}
        self._drive_event = threading.Condition()
        self.fatal: Optional[str] = None
        self._started = False

    # -- lifecycle ---------------------------------------------------------
    def _net_policies(self) -> Dict[str, Dict]:
        """Snapshot per-logical-net assembly policy from the server: image
        shape, batch cap (max across the route's backends), window."""
        out = {}
        with self.server._cond:
            for net, keys in self.server._routes.items():
                states = [self.server._nets[k] for k in keys
                          if k in self.server._nets]
                if not states:
                    continue
                n0 = states[0].opt.spec.nodes[0]
                out[net] = {
                    "image_shape": (n0.c, n0.im, n0.im),
                    "cap": max(s.queue.batch_cap for s in states),
                    "wait_s": max(s.queue.max_wait_s for s in states),
                }
        return out
    def start(self) -> "ProcessFrontend":
        if self._started:
            return self
        self._cfg = self._net_policies()
        if not self._cfg:
            raise RuntimeError("no networks registered: register() before "
                               "starting the process front end")
        for net, c in self._cfg.items():
            self._pools[net] = SlabPool(c["image_shape"],
                                        max_batch=c["cap"],
                                        slots=self.slots)
        pools_arg = {net: (p.spec(), p.lock)
                     for net, p in self._pools.items()}
        for i in range(self.procs):
            pr = _CTX.Process(target=_intake_main,
                              args=(i, pools_arg, self._inboxes[i],
                                    self._outbox, self._reply_q),
                              daemon=True, name=f"intake-{i}")
            pr.start()
            self._children.append(pr)
            for net, c in self._cfg.items():
                self._inboxes[i].put(("cfg", net, c))
        for fn, name in ((self._dispatch_loop, "frontend-dispatch"),
                         (self._reply_loop, "frontend-reply")):
            t = threading.Thread(target=fn, daemon=True, name=name)
            t.start()
            self._threads.append(t)
        self._started = True
        return self

    def refresh(self) -> None:
        """Re-send assembly policy (caps/windows may have moved with a
        hot_swap or bucket-policy refresh). Nets registered after start
        still need their own pools — register before starting."""
        self._cfg = {n: c for n, c in self._net_policies().items()
                     if n in self._pools}
        for i in range(self.procs):
            for net, c in self._cfg.items():
                self._inboxes[i].put(("cfg", net, c))

    def stop(self, timeout: float = 10.0) -> None:
        if not self._started:
            return
        for q in self._inboxes:
            q.put(("stop",))
        for pr in self._children:
            pr.join(timeout)
            if pr.is_alive():
                pr.terminate()
                pr.join(1.0)
        self._stop.set()
        for t in self._threads:
            t.join(timeout)
        for q in self._inboxes + [self._outbox, self._reply_q]:
            try:
                q.close()
                q.cancel_join_thread()
            except Exception:
                pass
        for pool in self._pools.values():
            pool.close()
        self._started = False

    # -- parent-side loops -------------------------------------------------
    def _dispatch_loop(self) -> None:
        server = self.server
        while not self._stop.is_set():
            try:
                msg = self._outbox.get(timeout=PARENT_POLL_S)
            except pyqueue.Empty:
                continue
            _, pi, bid, net, handle, rows = msg
            inbox = self._inboxes[pi]
            pool = self._pools[net]
            try:
                xs = pool.view(handle, pow2_ceil(rows))
            except Exception as e:
                inbox.put(("done", bid, ("rows", [None] * rows,
                                         [f"slab error: {e}"] * rows,
                                         [False] * rows)))
                continue
            on_done = self._make_on_done(pool, handle, inbox, bid, rows)
            server._submit_group(net, xs, rows, handle=handle,
                                 on_done=on_done)

    def _make_on_done(self, pool: SlabPool, handle: SlabHandle, inbox,
                      bid: int, rows: int) -> Callable:
        def on_done(tickets: List[Ticket],
                    out: Optional[np.ndarray]) -> None:
            try:
                pool.free(handle)
            except Exception:
                pass
            try:
                if out is not None and all(t.error is None and not t.degraded
                                           for t in tickets):
                    payload = ("bulk", np.ascontiguousarray(out[:rows]))
                else:
                    payload = ("rows",
                               [t.result for t in tickets],
                               [t.error for t in tickets],
                               [t.degraded for t in tickets])
                inbox.put(("done", bid, payload))
            except Exception:
                pass
        return on_done

    def _reply_loop(self) -> None:
        while not self._stop.is_set():
            try:
                msg = self._reply_q.get(timeout=PARENT_POLL_S)
            except pyqueue.Empty:
                continue
            if msg[0] == "reply":
                _, _pi, req_ids, results, errors, degraded = msg
                with self._pending_lock:
                    tickets = [self._pending.pop(r, None) for r in req_ids]
                for t, res, err, deg in zip(tickets, results, errors,
                                            degraded):
                    if t is None:
                        continue
                    if err is not None:
                        t.finish(error=err, rejected="rejected" in err)
                    else:
                        t.finish(result=res, degraded=deg)
            elif msg[0] == "drove":
                _, pi, net, stats = msg
                with self._drive_event:
                    self._drive_results[(pi, net)] = stats
                    self._drive_event.notify_all()
            elif msg[0] == "fatal":
                self.fatal = msg[2]
                with self._drive_event:
                    self._drive_event.notify_all()

    # -- request entry -----------------------------------------------------
    def ingest(self, net: str, xs) -> List[Ticket]:
        """Ship request payloads to the intake processes; returns tickets
        finished by the reply loop as batches settle. The payload crosses
        into an intake once (the ingress hop a networked front end would
        pay at its socket) and is written exactly once into a slab."""
        self.start()
        clock = self.server._clock
        tickets = []
        for x in xs:
            x = np.asarray(x, np.float32)
            rid = next(self._req_seq)
            t = Ticket(net=net, x=x, submitted_s=clock(), clock=clock)
            with self._pending_lock:
                self._pending[rid] = t
            self._inboxes[self._rr].put(("req", rid, net, x))
            self._rr = (self._rr + 1) % self.procs
            tickets.append(t)
        return tickets

    def drive(self, net: str, requests: int, *, seed: int = 0,
              timeout: float = 180.0) -> Dict:
        """Synthetic intake: split ``requests`` across the intake processes,
        each generating and submitting its share locally. Blocks until all
        resolve; returns aggregated accounting (requests, served, degraded,
        failed, rejected, img/s)."""
        self.start()
        share = [requests // self.procs] * self.procs
        for i in range(requests % self.procs):
            share[i] += 1
        expect = []
        for i, n in enumerate(share):
            if n <= 0:
                continue
            self._inboxes[i].put(("drive", net, n, seed + i))
            expect.append((i, net))
        deadline = time.perf_counter() + timeout
        with self._drive_event:
            while any(k not in self._drive_results for k in expect):
                if self.fatal is not None:
                    raise RuntimeError(f"intake process died:\n{self.fatal}")
                left = deadline - time.perf_counter()
                if left <= 0:
                    raise TimeoutError(f"drive({net!r}, {requests}) not "
                                       f"resolved within {timeout:.0f}s")
                self._drive_event.wait(min(left, 0.25))
            stats = [self._drive_results.pop(k) for k in expect]
        agg = {k: sum(s[k] for s in stats)
               for k in ("requests", "served", "degraded", "failed",
                         "rejected", "latency_sum")}
        agg["seconds"] = max(s["seconds"] for s in stats)
        agg["images_per_s"] = (agg["served"] / agg["seconds"]
                               if agg["seconds"] > 0 else 0.0)
        agg["latency_mean_ms"] = (agg["latency_sum"] / agg["requests"] * 1e3
                                  if agg["requests"] else 0.0)
        agg.pop("latency_sum")
        return agg
