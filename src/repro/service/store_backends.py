"""Pluggable storage backends for the artifact store (DESIGN.md §14.1).

The ``ArtifactStore`` addresses artifacts by content digest; *where* the
bytes live is this module's concern. A backend is a flat key/value space
of opaque slash-separated keys with four verbs — put/get/list/delete —
plus streaming reads, so the store's publish protocol (staged upload,
manifest committed last; §14.2) composes over any of them.

Two implementations:

- ``LocalDirBackend`` — the original on-disk layout, one file per key
  under a root directory. ``put`` is atomic via tmp-file + ``os.replace``;
  ``local_path`` exposes the real file so model/dataset loads stay
  zero-copy.
- ``ObjectStoreBackend`` — a simulated object store (S3/GCS-shaped): an
  in-memory bucket shared between any number of handle views
  (``share()``), per-op injectable latency, and a fault hook that can
  raise, tear a write in half, lose a read, or fail *after* the write
  landed — the failure modes the crash-consistency suite drives
  (tests/test_store_backends.py). Keys are atomic: a reader sees the old
  bytes or the new bytes, never a mix, unless a "torn" fault was
  explicitly injected.

Fault hooks are callables ``(op, key) -> Optional[str]`` evaluated before
each operation; ``ScriptedFaults`` builds deterministic one-shot
schedules from them. A backend failure surfaces as ``BackendError``,
a subclass of ``OSError`` so the store's existing fault-tolerance
contract (caching failures cost the cache, not the training) covers
remote backends for free.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple


class BackendError(OSError):
    """A storage-backend operation failed (network, fault injection, …)."""


# A fault hook inspects (op, key) and returns None (no fault) or one of:
#   "raise"       fail before any side effect
#   "raise_after" (put only) write lands, then the call fails — the
#                 ambiguous-ack case behind duplicate publishes
#   "torn"        (put only) roughly half the bytes land, then the call
#                 fails — a torn payload a checksum must catch
#   "lost"        (get only) pretend the key is missing
FaultHook = Callable[[str, str], Optional[str]]


class ScriptedFaults:
    """Deterministic one-shot fault schedule.

    ``entries`` is a list of ``(match, action)`` pairs; each fires at most
    once, in order. ``match`` is an op name (``"put"``), an
    ``(op, key_substring)`` pair, or a predicate ``(op, key) -> bool``.
    Thread-safe: concurrent hosts sharing a schedule consume entries
    exactly once.
    """

    def __init__(self, entries: Iterable[Tuple[object, str]]):
        self._entries: List[Optional[Tuple[object, str]]] = list(entries)
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, str, str]] = []

    def __call__(self, op: str, key: str) -> Optional[str]:
        with self._lock:
            for i, entry in enumerate(self._entries):
                if entry is None:
                    continue
                match, action = entry
                if callable(match):
                    hit = bool(match(op, key))
                elif isinstance(match, tuple):
                    hit = op == match[0] and match[1] in key
                else:
                    hit = op == match
                if hit:
                    self._entries[i] = None
                    self.fired.append((op, key, action))
                    return action
        return None

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(e is not None for e in self._entries)


class StoreBackend:
    """Flat key/value storage behind :class:`ArtifactStore`.

    Keys are opaque ``/``-separated strings. ``put`` must be atomic per
    key (barring injected torn writes); there is no atomicity across
    keys — the store's manifest-last protocol provides that.
    """

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> Optional[bytes]:
        raise NotImplementedError

    def get_stream(self, key: str,
                   chunk_size: int = 1 << 20) -> Optional[Iterator[bytes]]:
        """Key-at-a-time streaming read (the modelzoo streaming-checkpoint
        idiom): an iterator of chunks, or None if the key is missing.
        Subclasses with real streaming override; the default chunks one
        ``get``."""
        data = self.get(key)
        if data is None:
            return None
        return (data[i:i + chunk_size]
                for i in range(0, max(len(data), 1), chunk_size))

    def list(self, prefix: str = "") -> List[str]:
        """All keys under ``prefix``, sorted."""
        raise NotImplementedError

    def delete(self, key: str) -> bool:
        raise NotImplementedError

    def delete_prefix(self, prefix: str) -> int:
        """Remove every key under ``prefix``; returns how many went."""
        n = 0
        for key in self.list(prefix):
            if self.delete(key):
                n += 1
        return n

    def mtime(self, key: str) -> Optional[float]:
        """Last-modified time, for age-gated GC of staged uploads."""
        raise NotImplementedError

    def local_path(self, key: str) -> Optional[str]:
        """A filesystem path holding this key's bytes, when the backend has
        one (fast path for .npz loads); None for remote backends."""
        return None


class LocalDirBackend(StoreBackend):
    """Keys are relative file paths under ``root`` — the store's original
    on-disk layout, unchanged, so pre-backend stores read back as-is."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, *key.split("/"))

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.put.{os.getpid()}.{threading.get_ident()}"
        try:
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def get(self, key: str) -> Optional[bytes]:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except (FileNotFoundError, IsADirectoryError):
            return None

    def get_stream(self, key: str,
                   chunk_size: int = 1 << 20) -> Optional[Iterator[bytes]]:
        path = self._path(key)
        if not os.path.isfile(path):
            return None

        def chunks() -> Iterator[bytes]:
            with open(path, "rb") as f:
                while True:
                    chunk = f.read(chunk_size)
                    if not chunk:
                        return
                    yield chunk
        return chunks()

    def list(self, prefix: str = "") -> List[str]:
        out = []
        for dirpath, dirnames, filenames in os.walk(self.root):
            rel = os.path.relpath(dirpath, self.root)
            base = "" if rel == "." else rel.replace(os.sep, "/") + "/"
            if not dirnames and not filenames and base:
                # an empty directory (e.g. a crashed writer's bare tmp dir)
                # is still listable garbage — surface it as a pseudo-key so
                # sweep() can age it out
                key = base
                if key.startswith(prefix):
                    out.append(key)
            for name in filenames:
                key = base + name
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key: str) -> bool:
        path = self._path(key)
        try:
            if key.endswith("/"):
                os.rmdir(path)
            else:
                os.unlink(path)
            return True
        except OSError:
            return False

    def delete_prefix(self, prefix: str) -> int:
        import shutil
        n = len([k for k in self.list(prefix) if not k.endswith("/")])
        target = self._path(prefix.rstrip("/"))
        if os.path.isdir(target):
            shutil.rmtree(target, ignore_errors=True)
            return n
        return super().delete_prefix(prefix)

    def mtime(self, key: str) -> Optional[float]:
        try:
            return os.path.getmtime(self._path(key.rstrip("/")))
        except OSError:
            return None

    def local_path(self, key: str) -> Optional[str]:
        path = self._path(key)
        return path if os.path.isfile(path) else None


class ObjectStoreBackend(StoreBackend):
    """Simulated object store: a dict bucket of ``key -> (bytes, mtime)``
    behind one lock, shareable between host views.

    ``share()`` returns a new handle over the *same* bucket with its own
    fault schedule and latency — the multi-host fleet tests give every
    simulated host its own view of one shared store. ``latency_s`` sleeps
    (via the injectable ``sleep``) once per operation; ``clock`` stamps
    mtimes, so age-gated GC works under a fake clock.
    """

    def __init__(self, bucket: Optional[Dict[str, Tuple[bytes, float]]] = None,
                 *, latency_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep,
                 clock: Callable[[], float] = time.time,
                 faults: Optional[FaultHook] = None,
                 lock: Optional[threading.RLock] = None):
        self._bucket: Dict[str, Tuple[bytes, float]] = (
            bucket if bucket is not None else {})
        self._lock = lock if lock is not None else threading.RLock()
        self.latency_s = latency_s
        self._sleep = sleep
        self._clock = clock
        self.faults = faults
        self.op_counts: Dict[str, int] = {}

    def share(self, *, faults: Optional[FaultHook] = None,
              latency_s: Optional[float] = None) -> "ObjectStoreBackend":
        """A new view over the same bucket (another host's handle)."""
        return ObjectStoreBackend(
            self._bucket, lock=self._lock,
            latency_s=self.latency_s if latency_s is None else latency_s,
            sleep=self._sleep, clock=self._clock, faults=faults)

    def _enter(self, op: str, key: str) -> Optional[str]:
        self.op_counts[op] = self.op_counts.get(op, 0) + 1
        if self.latency_s:
            self._sleep(self.latency_s)
        action = self.faults(op, key) if self.faults is not None else None
        if action == "raise":
            raise BackendError(f"injected fault: {op} {key}")
        return action

    def put(self, key: str, data: bytes) -> None:
        action = self._enter("put", key)
        data = bytes(data)
        with self._lock:
            if action == "torn":
                self._bucket[key] = (data[:max(1, len(data) // 2)],
                                     self._clock())
                raise BackendError(f"injected fault: torn put {key}")
            self._bucket[key] = (data, self._clock())
        if action == "raise_after":
            raise BackendError(f"injected fault: put acked late {key}")

    def get(self, key: str) -> Optional[bytes]:
        action = self._enter("get", key)
        if action == "lost":
            return None
        with self._lock:
            entry = self._bucket.get(key)
        return entry[0] if entry is not None else None

    def list(self, prefix: str = "") -> List[str]:
        self._enter("list", prefix)
        with self._lock:
            return sorted(k for k in self._bucket if k.startswith(prefix))

    def delete(self, key: str) -> bool:
        self._enter("delete", key)
        with self._lock:
            return self._bucket.pop(key, None) is not None

    def mtime(self, key: str) -> Optional[float]:
        with self._lock:
            entry = self._bucket.get(key)
        return entry[1] if entry is not None else None


def get_backend(spec: str, root: str) -> StoreBackend:
    """CLI-facing factory: ``"local"`` (directory at ``root``) or
    ``"object"`` (fresh in-process simulated object store — a demo stand-in
    for a real bucket client)."""
    if spec == "local":
        return LocalDirBackend(root)
    if spec == "object":
        return ObjectStoreBackend()
    raise ValueError(f"unknown store backend {spec!r} "
                     f"(expected 'local' or 'object')")
