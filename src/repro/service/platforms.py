"""Platform abstraction — service layer L1 (DESIGN.md §7.1).

The paper's promise is that porting a CNN to a *new* computing system costs
seconds: profile a small sample, transfer the performance model (§4.4),
re-solve the PBQP. Before this layer, every example and benchmark hand-wired
``simulate_*_dataset`` → ``fit_perf_model`` → provider → ``select``; this
module makes "a platform" a first-class object with exactly three verbs:

  * ``profile(configs)`` / ``profile_dlt(pairs)`` — the expensive truth
    source (analytic simulator or real host CPU, same matrix contract);
  * ``cost_provider()`` — ground-truth costs for selection/scoring;
  * ``calibrate(base_model, budget)`` — the §4.4 transfer path: profile a
    ``budget``-sized sample, factor-correct or fine-tune ``base_model``,
    return models ready for a ``ModelProvider``.

``pretrain()`` covers the native path (train from this platform's full
dataset). Both consult an ``ArtifactStore`` when given one, so repeat runs
warm-start in milliseconds instead of retraining (Table 4, operational).
"""
from __future__ import annotations

import abc
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.perfmodel import PerfModel, factor_correct, fit_perf_model
from repro.core.selection import (CostProvider, MeasuredProvider,
                                  ModelProvider, SimulatedProvider)
from repro.profiler.dataset import (PerfDataset, simulate_dlt_dataset,
                                    simulate_primitive_dataset)


# ---------------------------------------------------------------------------
# Model bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlatformModels:
    """A (primitive, DLT) performance-model pair bound to a platform —
    everything selection needs, plus provenance for artifact keying."""

    prim: PerfModel
    dlt: PerfModel
    platform: str                 # fingerprint of the platform they model
    mode: str                     # "native" | "factor" | "finetune"
    budget: Optional[float] = None   # calibration sample budget (None = full)
    warm: bool = False            # True = loaded from the artifact store
    seconds: float = 0.0          # wall time of pretrain()/calibrate()
    # how the calibration sample was composed when served observations were
    # reused (DESIGN.md §8.5): served vs freshly-profiled row counts etc.
    sample_info: Optional[Dict] = None

    def provider(self, columns: Optional[Sequence[str]] = None) -> ModelProvider:
        return ModelProvider(self.prim, self.dlt, columns=columns)

    def fingerprint(self) -> str:
        return f"{self.prim.fingerprint()}-{self.dlt.fingerprint()}"


# ---------------------------------------------------------------------------
# Platform interface
# ---------------------------------------------------------------------------

class Platform(abc.ABC):
    """One optimisation target: profile it (dearly), provide ground-truth
    costs, and calibrate a transferred performance model onto it."""

    name: str

    # -- profiling ---------------------------------------------------------
    @property
    @abc.abstractmethod
    def columns(self) -> List[str]:
        """Primitive columns this platform can profile."""

    @abc.abstractmethod
    def profile(self, configs: np.ndarray) -> np.ndarray:
        """(L, 5) configs -> (L, P) runtimes (NaN = inapplicable)."""

    @abc.abstractmethod
    def profile_dlt(self, pairs: np.ndarray) -> np.ndarray:
        """(M, 2) (c, im) pairs -> (M, 6) non-identity DLT runtimes."""

    @abc.abstractmethod
    def primitive_dataset(self) -> PerfDataset:
        """Full profiled primitive dataset (cached per instance)."""

    @abc.abstractmethod
    def dlt_dataset(self) -> PerfDataset:
        """Full profiled DLT dataset (cached per instance)."""

    # -- selection ---------------------------------------------------------
    @abc.abstractmethod
    def cost_provider(self) -> CostProvider:
        """Ground-truth cost provider (plays 'profiled on the device')."""

    @abc.abstractmethod
    def fingerprint(self) -> str:
        """Stable identity for artifact keys (config, not measurements)."""

    def pool_fingerprint(self) -> str:
        """Drift-invariant hardware identity for fleet calibration pooling
        (DESIGN.md §14.3). ``fingerprint()`` may deliberately move when the
        platform drifts (so post-drift calibration artifacts do not collide
        with pre-drift addresses); the pool key must NOT move, or a drifted
        host would publish evidence its healthy peers never find. Platforms
        whose fingerprint encodes drift state override this to return the
        stable part."""
        return self.fingerprint()

    def base_column(self, column: str) -> str:
        """Map one of this platform's columns onto the base-registry
        primitive a foreign base model would know it as. Identity for plain
        platforms; tile-column platforms strip the tile suffix so a wide
        base model expands onto their (primitive, tile) columns
        (``PerfModel.subset_columns(base_of=...)``)."""
        return column

    # -- model path (shared) ----------------------------------------------
    def _model_fields(self, role: str, kind: str, **extra) -> dict:
        # ``backend`` (the platform's short name) is part of every model
        # address: two backends optimising the same network must never
        # collide on an artifact even if their fingerprints ever coincide
        ds = self.primitive_dataset() if role == "prim" else self.dlt_dataset()
        return {"platform": self.fingerprint(), "backend": self.name,
                "columns": list(ds.columns),
                "dataset": ds.fingerprint(), "model_kind": kind,
                "role": role, **extra}

    def pretrain_prim(self, kind: str = "nn2", *, store=None, seed: int = 0,
                      max_iters: int = 4000,
                      patience: int = 250) -> "Tuple[PerfModel, bool]":
        """Native primitive model: (model, warm). This is THE artifact
        address for a natively trained primitive model on this platform —
        benchmarks and ``pretrain`` route through it, so the same logical
        model is stored exactly once (ROADMAP "one keying scheme")."""

        def train() -> PerfModel:
            tr, va, _ = self.primitive_dataset().split()
            return fit_perf_model(kind, tr.feats, tr.times, va.feats, va.times,
                                  columns=self.primitive_dataset().columns,
                                  seed=seed, max_iters=max_iters,
                                  patience=patience)

        return _get_or_train(
            store, self._model_fields("prim", kind, seed=seed,
                                      max_iters=max_iters, patience=patience,
                                      mode="native"),
            train)

    def pretrain_dlt(self, kind: str = "lin", *, store=None, seed: int = 0,
                     max_iters: int = 1500) -> "Tuple[PerfModel, bool]":
        """Native DLT model: (model, warm) — same single-address contract as
        ``pretrain_prim``."""
        return self._native_dlt(kind, seed, max_iters, store)

    def pretrain(self, kind: str = "nn2", *, store=None, seed: int = 0,
                 max_iters: int = 4000, patience: int = 250,
                 dlt_kind: str = "lin", dlt_max_iters: int = 1500) -> PlatformModels:
        """Native path: train (or warm-load) performance models from this
        platform's full profiled dataset."""
        t0 = time.perf_counter()
        prim, prim_warm = self.pretrain_prim(kind, store=store, seed=seed,
                                             max_iters=max_iters,
                                             patience=patience)
        dlt, dlt_warm = self.pretrain_dlt(dlt_kind, store=store, seed=seed,
                                          max_iters=dlt_max_iters)
        return PlatformModels(prim, dlt, self.fingerprint(), "native",
                              warm=prim_warm and dlt_warm,
                              seconds=time.perf_counter() - t0)

    def calibrate(self, base: Union[PerfModel, PlatformModels],
                  budget: float = 0.01, *, mode: str = "auto", store=None,
                  sample=None, served=None, pooled=None, sample_n: int = 16,
                  seed: int = 0, max_iters: int = 2000,
                  patience: int = 150, dlt_kind: str = "lin",
                  dlt_max_iters: int = 1500) -> PlatformModels:
        """Transfer path (§4.4): profile a ``budget`` sample of this platform
        (fraction if < 1, row count if >= 1), then correct ``base`` onto it.

        ``mode``: "factor" multiplies per-primitive geometric-mean ratios
        (cheapest), "finetune" continues training at 10x-lowered LR, "auto"
        picks finetune when the sample is big enough to not overfit, and
        "scratch" ignores ``base`` and trains on the sample alone (the
        paper's transfer-study control).

        ``sample``: a caller-supplied ``PerfDataset`` of fresh measurements
        — the serving drift loop calibrates from what it just observed (see
        ``measure_sample``) instead of re-profiling the platform's cached
        pool, so a drifted platform is corrected from *post-drift* truth.
        ``budget`` is ignored when a sample is given.

        ``served``: attributed served-traffic observations
        (``observations_to_dataset``) — composed into the calibration sample
        via ``compose_sample`` (fresh profiling only for the ≤ ``sample_n``
        configs the serving buffer misses; ZERO profiling at full coverage).
        Served rows only measure assigned primitives, so "auto" resolves to
        factor correction with the pooled factor extended to unmeasured
        columns (``factor_correct(fill_missing=True)``).

        ``pooled``: fleet evidence — other hosts' published served-traffic
        datasets for this platform fingerprint
        (``ArtifactStore.pooled_drift``, DESIGN.md §14.3). Merged with
        ``served`` via ``merge_served`` before composition, so a host that
        observed nothing itself still calibrates from what the fleet saw.
        Deterministic: the merged sample's fingerprint keys the artifact,
        so two hosts pooling identical evidence warm-load byte-identical
        corrected models.
        """
        t0 = time.perf_counter()
        sample_info = None
        pooled = [d for d in (pooled or []) if d is not None and d.n]
        if pooled:
            if sample is not None:
                raise ValueError("pass either sample= or pooled=, not both")
            from repro.profiler.dataset import merge_served
            merged = merge_served([served, *pooled] if served is not None
                                  else pooled)
            pool_info = {"pooled_sources": len(pooled),
                         "pooled_rows": int(sum(d.n for d in pooled))}
            served = merged
        else:
            pool_info = None
        if served is not None:
            if sample is not None:
                raise ValueError("pass either sample= or served=, not both")
            sample, sample_info = self.compose_sample(served, n=sample_n,
                                                      seed=seed)
            if pool_info:
                sample_info.update(pool_info)
            if mode == "auto":
                # finetune on rows that are NaN outside the assigned columns
                # would re-initialise every unmeasured head; the factor path
                # with fill_missing is the estimator that matches the data
                mode = "factor"
        base_prim = base.prim if isinstance(base, PlatformModels) else base
        # a wide base (e.g. the 49-column simulator model) transfers onto a
        # platform that profiles fewer primitives by slicing its output head
        # to this platform's columns — positions must match the sample matrix
        target_cols = (list(sample.columns) if sample is not None
                       else list(self.primitive_dataset().columns))
        if list(base_prim.columns) != target_cols:
            # base_of lets a plain-primitive base model expand onto this
            # platform's tile columns (each tile head starts as its base
            # primitive's head; calibration then differentiates the tiles)
            base_prim = base_prim.subset_columns(target_cols,
                                                 base_of=self.base_column)
        if sample is None:
            tr, va, _ = self.primitive_dataset().split()
            frac = budget if budget < 1 else min(1.0, budget / max(tr.n, 1))
            sample = tr.subsample(frac, seed=seed)
            va_feats, va_times = va.feats, va.times
        else:
            # fresh-measurement path: the sample doubles as the early-stop
            # set (re-profiling a validation pool would defeat its cheapness)
            budget = None
            va_feats, va_times = sample.feats, sample.times
        if mode == "auto":
            mode = "finetune" if sample.n >= 24 else "factor"
        if mode not in ("factor", "finetune", "scratch"):
            raise ValueError(f"unknown calibration mode {mode!r}")

        fill = sample_info is not None

        def train_prim() -> PerfModel:
            if mode == "factor":
                return factor_correct(base_prim, sample.feats, sample.times,
                                      fill_missing=fill)
            # fine-tuning continues gradient training, so a factor-corrected
            # base unwraps to the underlying trained network
            from repro.core.perfmodel import FactorCorrectedModel
            ft_base = (base_prim.base if isinstance(base_prim, FactorCorrectedModel)
                       else base_prim)
            return fit_perf_model(ft_base.kind, sample.feats, sample.times,
                                  va_feats, va_times,
                                  columns=target_cols,
                                  seed=seed,
                                  base=None if mode == "scratch" else ft_base,
                                  max_iters=max_iters, patience=patience)

        extra = dict(seed=seed, mode=mode, budget=budget,
                     sample=sample.fingerprint(), fill=fill,
                     base=None if mode == "scratch" else base_prim.fingerprint(),
                     max_iters=max_iters, patience=patience)
        if budget is None:
            # caller-supplied sample: key off the sample itself — touching
            # primitive_dataset() here would re-profile the platform pool
            fields = {"platform": self.fingerprint(), "backend": self.name,
                      "columns": target_cols,
                      "dataset": sample.fingerprint(),
                      "model_kind": base_prim.kind, "role": "prim", **extra}
        else:
            fields = self._model_fields("prim", base_prim.kind, **extra)
        prim, prim_warm = _get_or_train(store, fields, train_prim)
        # the DLT model is 2-feature/6-column — native training is cheap, so
        # it is not worth transferring; it is also independent of the
        # calibration sample, hence trained at a fixed seed and memoised
        dlt, dlt_warm = self._native_dlt(dlt_kind, 0, dlt_max_iters, store)
        return PlatformModels(prim, dlt, self.fingerprint(), mode,
                              budget=budget, warm=prim_warm and dlt_warm,
                              seconds=time.perf_counter() - t0,
                              sample_info=sample_info)

    def _sample_pool(self) -> Sequence:
        """Configs ``measure_sample`` may draw from — the platform's own
        profiling pool, so drift samples stay in-distribution for the model
        being corrected."""
        from repro.profiler import pools
        return pools.config_pool()

    def measure_sample(self, n: int = 16, seed: int = 0,
                       exclude: Optional[Sequence[Tuple]] = None) -> PerfDataset:
        """Freshly profile ``n`` layer configs drawn from this platform's
        pool — bypasses every dataset cache, so the measurements reflect the
        platform *as it is now*. This is the drift-recalibration input:
        cheap (n ≈ 16 ≈ the paper's 1% budget) and honest about drift.

        ``exclude``: config tuples to skip — the served-observation top-up
        path profiles only configs the serving buffer does NOT already
        cover. When fewer than ``n`` configs remain, all of them are taken.
        """
        cfgs = np.array(self._sample_pool(), np.int64)
        if exclude:
            skip = {tuple(map(int, c)) for c in exclude}
            keep = [i for i in range(len(cfgs))
                    if tuple(map(int, cfgs[i])) not in skip]
            cfgs = cfgs[keep]
            if not len(cfgs):
                raise ValueError("measure_sample: every pool config excluded")
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(cfgs), size=min(n, len(cfgs)), replace=False)
        sel = cfgs[np.sort(idx)]
        times = self.profile(sel)
        return PerfDataset(np.asarray(sel, np.float64), times,
                           list(self.columns), ["k", "c", "im", "s", "f"],
                           self.name)

    def compose_sample(self, served: PerfDataset, *, n: int = 16,
                       seed: int = 0) -> Tuple[PerfDataset, Dict]:
        """Build a calibration sample from served-traffic observations,
        topping up with fresh ``measure_sample`` profiling only for configs
        the serving buffer does not cover (DESIGN.md §8.5).

        ``served`` is the ``observations_to_dataset`` output: rows over the
        served network's layer configs, finite only at the assigned columns.
        Its columns are embedded into this platform's full column set;
        ``n - covered`` additional configs (if any) are freshly profiled from
        the pool, excluding the covered ones. When the buffer already covers
        ``n`` distinct configs the sample costs ZERO profiling.

        Returns ``(sample, info)`` where info records the served/fresh row
        mix — surfaced through ``PlatformModels.sample_info`` and the serving
        stats so the recalibration economics are observable.
        """
        cols = list(self.columns)
        unknown = sorted(set(served.columns) - set(cols))
        if unknown:
            raise ValueError(f"served columns {unknown} unknown to platform "
                             f"{self.fingerprint()!r}")
        embed = np.full((served.n, len(cols)), np.nan)
        for j, c in enumerate(served.columns):
            embed[:, cols.index(c)] = served.times[:, j]
        covered = {tuple(map(int, row)) for row in
                   np.asarray(served.feats, np.int64)}
        missing = max(int(n) - len(covered), 0)
        fresh_rows = 0
        feats, times = np.asarray(served.feats, np.float64), embed
        if missing > 0:
            fresh = self.measure_sample(missing, seed=seed,
                                        exclude=sorted(covered))
            fresh_rows = fresh.n
            feats = np.concatenate([feats, fresh.feats])
            times = np.concatenate([times, fresh.times])
        sample = PerfDataset(feats, times, cols,
                             ["k", "c", "im", "s", "f"], self.name)
        total = served.n + fresh_rows
        info = {"served_rows": int(served.n), "fresh_rows": int(fresh_rows),
                "served_fraction": served.n / total,
                "covered_configs": len(covered), "requested_n": int(n)}
        # surface the batch-shape mix the served rows came from (attached by
        # observations_to_dataset): recalibration reports can then show which
        # pow2 buckets — and how much per-bucket drift — fed the sample
        served_info = getattr(served, "served_info", None)
        if served_info:
            info["served"] = dict(served_info)
        return sample, info

    def invalidate_datasets(self) -> None:
        """Drop cached profiled datasets AND the DLT-model memo so the next
        profiling/calibration pass re-measures — e.g. after the platform is
        known to have drifted. (The memoised DLT models were trained on the
        pre-drift dataset; keeping them would skew the primitive-vs-DLT cost
        balance of every re-solved PBQP.)"""
        self._prim_ds = None
        self._dlt_ds = None
        self._dlt_models = {}

    def _native_dlt(self, kind: str, seed: int, max_iters: int, store):
        """Native DLT model, memoised per platform instance (one training
        per (kind, seed, iters) no matter how many calibrations ask)."""
        memo = getattr(self, "_dlt_models", None)
        if memo is None:
            memo = self._dlt_models = {}
        key = (kind, seed, max_iters)
        if key in memo:
            return memo[key], True

        def train() -> PerfModel:
            ds = self.dlt_dataset()
            tr, va, _ = ds.split()
            return fit_perf_model(kind, tr.feats, tr.times, va.feats,
                                  va.times, columns=ds.columns, seed=seed,
                                  max_iters=max_iters)

        model, warm = _get_or_train(
            store, self._model_fields("dlt", kind, seed=seed,
                                      max_iters=max_iters, mode="native"),
            train)
        memo[key] = model
        return model, warm


def _get_or_train(store, fields: dict, train_fn):
    """(model, warm) — through the artifact store when one is given."""
    if store is None:
        return train_fn(), False
    return store.get_or_train(fields, train_fn)


# ---------------------------------------------------------------------------
# Concrete platforms
# ---------------------------------------------------------------------------

class SimulatedPlatform(Platform):
    """Analytic platform simulator (intel/amd/arm) behind the Platform
    interface — full-scale datasets, deterministic noise, instant profiling."""

    def __init__(self, name: str, *, noisy: bool = True,
                 max_triplets: Optional[int] = None,
                 time_scale: float = 1.0,
                 faults=None):
        from repro.profiler.simulators import PLATFORMS
        if name not in PLATFORMS:
            raise KeyError(f"unknown simulated platform {name!r}; "
                           f"have {sorted(PLATFORMS)}")
        self.name = name
        self.noisy = noisy
        self.max_triplets = max_triplets
        # uniform slowdown applied to every simulated measurement — the
        # drift-experiment knob ("the machine got slower"). Mutable: bump it
        # mid-run, invalidate_datasets(), and re-profiling observes the
        # drifted platform. Relative primitive costs (and hence the optimal
        # assignment) are unchanged; absolute predictions scale.
        self.time_scale = time_scale
        # deterministic fault injection into the MEASUREMENT rig (DESIGN.md
        # §11): a ``serving.faults.FaultInjector`` whose ``profile`` hook
        # (key ``"profile:<name>"``) can fail or corrupt profiling calls —
        # the poisoned-recalibration test knob
        self.faults = faults
        self._plat = PLATFORMS[name]
        self._prim_ds: Optional[PerfDataset] = None
        self._dlt_ds: Optional[PerfDataset] = None

    @property
    def columns(self) -> List[str]:
        from repro.primitives.conv import PRIMITIVE_NAMES
        return list(PRIMITIVE_NAMES)

    def profile(self, configs: np.ndarray) -> np.ndarray:
        from repro.profiler.simulators import primitive_time_batch
        times = self.time_scale * primitive_time_batch(
            self._plat, np.asarray(configs, np.int64), noisy=self.noisy)
        if self.faults is not None:
            times = self.faults.profile(self.name, times)
        return times

    def profile_dlt(self, pairs: np.ndarray) -> np.ndarray:
        from repro.profiler.simulators import dlt_time_batch
        times = self.time_scale * dlt_time_batch(
            self._plat, np.asarray(pairs, np.int64), noisy=self.noisy)
        if self.faults is not None:
            times = self.faults.profile(self.name, times)
        return times

    def primitive_dataset(self) -> PerfDataset:
        if self._prim_ds is None:
            ds = simulate_primitive_dataset(
                self.name, max_triplets=self.max_triplets, noisy=self.noisy)
            if self.time_scale != 1.0:
                ds = dataclasses.replace(ds, times=ds.times * self.time_scale)
            self._prim_ds = ds
        return self._prim_ds

    def dlt_dataset(self) -> PerfDataset:
        if self._dlt_ds is None:
            ds = simulate_dlt_dataset(self.name, noisy=self.noisy)
            if self.time_scale != 1.0:
                ds = dataclasses.replace(ds, times=ds.times * self.time_scale)
            self._dlt_ds = ds
        return self._dlt_ds

    def _sample_pool(self):
        from repro.profiler import pools
        return pools.config_pool(max_triplets=self.max_triplets)

    def cost_provider(self) -> SimulatedProvider:
        # note: unscaled — a uniform time_scale does not move the argmin, so
        # ground-truth *selection* is scale-invariant
        return SimulatedProvider(self.name, noisy=self.noisy)

    def fingerprint(self) -> str:
        fp = self.pool_fingerprint()
        if self.time_scale != 1.0:        # keep pre-drift addresses stable
            fp += f"/ts={self.time_scale:g}"
        return fp

    def pool_fingerprint(self) -> str:
        # drift (time_scale) moves the artifact fingerprint, not the machine
        # identity — fleet pooling keys off the stable part (§14.3)
        return f"sim/{self.name}/noisy={int(self.noisy)}/mt={self.max_triplets}"


class PallasPlatform(Platform):
    """The Pallas kernel backend behind the Platform interface (DESIGN.md
    §9): profiling is autotune-backed — every column is a (runnable base
    primitive, matmul tile config) pair priced by ``core.autotune``'s
    analytic TPU cost surface, so the NN2 model and the PBQP select tile
    configs exactly like primitives. On real TPU hardware the analytic
    profiler is replaced by timed Pallas dispatches; every other verb
    (``calibrate``, ``pretrain``, ``cost_provider``) is inherited unchanged
    — the paper's porting story applied to an accelerator backend."""

    def __init__(self, *, bases: Optional[Sequence[str]] = None,
                 variants: Optional[Sequence[str]] = None,
                 noisy: bool = True,
                 max_triplets: Optional[int] = None,
                 time_scale: float = 1.0,
                 name: str = "tpu"):
        from repro.core.autotune import PALLAS_CONV_BASES, pallas_columns
        self.name = name
        self.noisy = noisy
        self.max_triplets = max_triplets
        self.time_scale = time_scale   # drift knob, as on SimulatedPlatform
        self._bases = list(bases) if bases is not None else list(PALLAS_CONV_BASES)
        self._variants = list(variants) if variants is not None else None
        self._columns = pallas_columns(self._bases, self._variants)
        self._prim_ds: Optional[PerfDataset] = None
        self._dlt_ds: Optional[PerfDataset] = None

    @property
    def columns(self) -> List[str]:
        return list(self._columns)

    def base_column(self, column: str) -> str:
        from repro.primitives.conv import split_tile
        return split_tile(column)[0]

    def profile(self, configs: np.ndarray) -> np.ndarray:
        from repro.core.autotune import conv_tile_time_batch
        return conv_tile_time_batch(np.asarray(configs, np.int64),
                                    self._columns, noisy=self.noisy,
                                    time_scale=self.time_scale)

    def profile_dlt(self, pairs: np.ndarray) -> np.ndarray:
        from repro.core.autotune import pallas_dlt_time_batch
        return pallas_dlt_time_batch(np.asarray(pairs, np.int64),
                                     noisy=self.noisy,
                                     time_scale=self.time_scale)

    def _sample_pool(self):
        from repro.profiler import pools
        return pools.config_pool(max_triplets=self.max_triplets)

    def primitive_dataset(self) -> PerfDataset:
        if self._prim_ds is None:
            cfgs = np.asarray(self._sample_pool(), np.int64)
            self._prim_ds = PerfDataset(
                cfgs.astype(np.float64), self.profile(cfgs),
                list(self._columns), ["k", "c", "im", "s", "f"], self.name)
        return self._prim_ds

    def dlt_dataset(self) -> PerfDataset:
        if self._dlt_ds is None:
            from repro.primitives import layouts as L
            from repro.profiler import pools
            pairs = np.asarray(pools.dlt_pool(), np.int64)
            cols = [L.dlt_name(s, d) for (s, d) in L.dlt_pairs() if s != d]
            self._dlt_ds = PerfDataset(
                pairs.astype(np.float64), self.profile_dlt(pairs),
                cols, ["c", "im"], self.name)
        return self._dlt_ds

    def cost_provider(self):
        from repro.core.autotune import PallasTileProvider
        # unscaled, as on SimulatedPlatform: uniform drift moves no argmin
        return PallasTileProvider(self._columns, noisy=self.noisy)

    def fingerprint(self) -> str:
        import hashlib
        cols = hashlib.sha256("|".join(self._columns).encode()).hexdigest()[:8]
        fp = (f"pallas/{self.name}/cols={cols}/noisy={int(self.noisy)}"
              f"/mt={self.max_triplets}")
        if self.time_scale != 1.0:
            fp += f"/ts={self.time_scale:g}"
        return fp


class HostPlatform(Platform):
    """This container's real CPU behind the Platform interface — reduced
    scale, genuinely expensive profiling (the cost the paper eliminates)."""

    name = "host"

    def __init__(self, *, configs: Optional[Sequence] = None,
                 dlt_pairs: Optional[Sequence] = None,
                 primitives: Optional[Sequence[str]] = None,
                 repeats: int = 9, store=None):
        from repro.primitives.conv import RUNNABLE
        self.repeats = repeats
        # datasets persist through this store keyed by (pool, repeats,
        # machine id): real-CPU runs warm-start across process restarts
        # instead of re-measuring every primitive (ROADMAP)
        self.store = store
        self._primitives = list(primitives) if primitives is not None else list(RUNNABLE)
        self._configs = [tuple(map(int, c)) for c in configs] if configs is not None else None
        self._dlt_pairs = [tuple(map(int, p)) for p in dlt_pairs] if dlt_pairs is not None else None
        self._prim_ds: Optional[PerfDataset] = None
        self._dlt_ds: Optional[PerfDataset] = None

    @property
    def columns(self) -> List[str]:
        return list(self._primitives)

    def profile(self, configs: np.ndarray) -> np.ndarray:
        from repro.profiler import host
        return host.profile_primitive_batch(np.asarray(configs, int),
                                            self._primitives,
                                            repeats=self.repeats)

    def profile_dlt(self, pairs: np.ndarray) -> np.ndarray:
        from repro.profiler import host
        return host.profile_dlt_batch(np.asarray(pairs, int),
                                      repeats=self.repeats)

    def _default_pools(self):
        from repro.profiler import pools
        configs = self._configs if self._configs is not None else \
            pools.config_pool(max_triplets=12)
        dlt_pairs = self._dlt_pairs if self._dlt_pairs is not None else \
            pools.dlt_pool(max_pairs=12)
        return configs, dlt_pairs

    def _sample_pool(self):
        return self._default_pools()[0]

    def _dataset_fields(self, role: str, pool) -> dict:
        """Measurement-independent dataset address: the pool that would be
        profiled, the repeat count, and the machine identity — NOT the
        measured times (those are what the address retrieves)."""
        return {"artifact": "perf_dataset", "role": role,
                "machine": host_machine_id(), "repeats": self.repeats,
                "pool": [list(map(int, p)) for p in pool],
                "primitives": self._primitives if role == "prim" else None}

    def _measured_dataset(self, role: str, pool) -> PerfDataset:
        from repro.profiler import host
        fields = self._dataset_fields(role, pool) if self.store else None
        if self.store is not None:
            ds = self.store.get_dataset(fields)
            if ds is not None:
                return ds
        if role == "prim":
            ds = host.profile_primitive_dataset(
                pool, primitives=self._primitives, repeats=self.repeats)
        else:
            ds = host.profile_dlt_dataset(pool, repeats=self.repeats)
        if self.store is not None:
            self.store.put_dataset(fields, ds)
        return ds

    def primitive_dataset(self) -> PerfDataset:
        if self._prim_ds is None:
            configs, _ = self._default_pools()
            self._prim_ds = self._measured_dataset("prim", configs)
        return self._prim_ds

    def dlt_dataset(self) -> PerfDataset:
        if self._dlt_ds is None:
            _, dlt_pairs = self._default_pools()
            self._dlt_ds = self._measured_dataset("dlt", dlt_pairs)
        return self._dlt_ds

    def invalidate_datasets(self) -> None:
        """Also drop the PERSISTED datasets: their address is
        measurement-independent, so without this the next profiling pass
        would warm-load the stale pre-drift measurements from the store."""
        super().invalidate_datasets()
        if self.store is not None:
            configs, dlt_pairs = self._default_pools()
            self.store.delete("datasets", self._dataset_fields("prim", configs))
            self.store.delete("datasets", self._dataset_fields("dlt", dlt_pairs))

    def cost_provider(self) -> MeasuredProvider:
        return MeasuredProvider(repeats=self.repeats, columns=self._primitives)

    def fingerprint(self) -> str:
        import hashlib
        cols = hashlib.sha256("|".join(self._primitives).encode()).hexdigest()[:8]
        return f"host-cpu/r={self.repeats}/cols={cols}"


def host_machine_id() -> str:
    """Stable identity of THIS machine for host-dataset addressing: a
    profiled dataset is only valid on hardware that looks like the one that
    measured it (hostname + core count + machine arch)."""
    import platform as _stdlib_platform
    u = _stdlib_platform.uname()
    import os as _os
    return f"{u.node}/{u.machine}/cpus={_os.cpu_count()}"


def get_platform(spec: Union[str, Platform], **kwargs) -> Platform:
    """'intel' / 'amd' / 'arm' -> SimulatedPlatform, 'host' -> HostPlatform,
    'tpu' / 'pallas' -> PallasPlatform; a Platform instance passes through
    (kwargs then disallowed)."""
    if isinstance(spec, Platform):
        if kwargs:
            raise TypeError("cannot re-configure an existing Platform")
        return spec
    if spec == "host":
        return HostPlatform(**kwargs)
    if spec in ("tpu", "pallas"):
        return PallasPlatform(**kwargs)
    return SimulatedPlatform(spec, **kwargs)
