"""Platform abstraction — service layer L1 (DESIGN.md §7.1).

The paper's promise is that porting a CNN to a *new* computing system costs
seconds: profile a small sample, transfer the performance model (§4.4),
re-solve the PBQP. Before this layer, every example and benchmark hand-wired
``simulate_*_dataset`` → ``fit_perf_model`` → provider → ``select``; this
module makes "a platform" a first-class object with exactly three verbs:

  * ``profile(configs)`` / ``profile_dlt(pairs)`` — the expensive truth
    source (analytic simulator or real host CPU, same matrix contract);
  * ``cost_provider()`` — ground-truth costs for selection/scoring;
  * ``calibrate(base_model, budget)`` — the §4.4 transfer path: profile a
    ``budget``-sized sample, factor-correct or fine-tune ``base_model``,
    return models ready for a ``ModelProvider``.

``pretrain()`` covers the native path (train from this platform's full
dataset). Both consult an ``ArtifactStore`` when given one, so repeat runs
warm-start in milliseconds instead of retraining (Table 4, operational).
"""
from __future__ import annotations

import abc
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.perfmodel import PerfModel, factor_correct, fit_perf_model
from repro.core.selection import (CostProvider, MeasuredProvider,
                                  ModelProvider, SimulatedProvider)
from repro.profiler.dataset import (PerfDataset, simulate_dlt_dataset,
                                    simulate_primitive_dataset)


# ---------------------------------------------------------------------------
# Model bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlatformModels:
    """A (primitive, DLT) performance-model pair bound to a platform —
    everything selection needs, plus provenance for artifact keying."""

    prim: PerfModel
    dlt: PerfModel
    platform: str                 # fingerprint of the platform they model
    mode: str                     # "native" | "factor" | "finetune"
    budget: Optional[float] = None   # calibration sample budget (None = full)
    warm: bool = False            # True = loaded from the artifact store
    seconds: float = 0.0          # wall time of pretrain()/calibrate()

    def provider(self, columns: Optional[Sequence[str]] = None) -> ModelProvider:
        return ModelProvider(self.prim, self.dlt, columns=columns)

    def fingerprint(self) -> str:
        return f"{self.prim.fingerprint()}-{self.dlt.fingerprint()}"


# ---------------------------------------------------------------------------
# Platform interface
# ---------------------------------------------------------------------------

class Platform(abc.ABC):
    """One optimisation target: profile it (dearly), provide ground-truth
    costs, and calibrate a transferred performance model onto it."""

    name: str

    # -- profiling ---------------------------------------------------------
    @property
    @abc.abstractmethod
    def columns(self) -> List[str]:
        """Primitive columns this platform can profile."""

    @abc.abstractmethod
    def profile(self, configs: np.ndarray) -> np.ndarray:
        """(L, 5) configs -> (L, P) runtimes (NaN = inapplicable)."""

    @abc.abstractmethod
    def profile_dlt(self, pairs: np.ndarray) -> np.ndarray:
        """(M, 2) (c, im) pairs -> (M, 6) non-identity DLT runtimes."""

    @abc.abstractmethod
    def primitive_dataset(self) -> PerfDataset:
        """Full profiled primitive dataset (cached per instance)."""

    @abc.abstractmethod
    def dlt_dataset(self) -> PerfDataset:
        """Full profiled DLT dataset (cached per instance)."""

    # -- selection ---------------------------------------------------------
    @abc.abstractmethod
    def cost_provider(self) -> CostProvider:
        """Ground-truth cost provider (plays 'profiled on the device')."""

    @abc.abstractmethod
    def fingerprint(self) -> str:
        """Stable identity for artifact keys (config, not measurements)."""

    # -- model path (shared) ----------------------------------------------
    def _model_fields(self, role: str, kind: str, **extra) -> dict:
        ds = self.primitive_dataset() if role == "prim" else self.dlt_dataset()
        return {"platform": self.fingerprint(), "columns": list(ds.columns),
                "dataset": ds.fingerprint(), "model_kind": kind,
                "role": role, **extra}

    def pretrain(self, kind: str = "nn2", *, store=None, seed: int = 0,
                 max_iters: int = 4000, patience: int = 250,
                 dlt_kind: str = "lin", dlt_max_iters: int = 1500) -> PlatformModels:
        """Native path: train (or warm-load) performance models from this
        platform's full profiled dataset."""
        t0 = time.perf_counter()

        def train_prim() -> PerfModel:
            tr, va, _ = self.primitive_dataset().split()
            return fit_perf_model(kind, tr.feats, tr.times, va.feats, va.times,
                                  columns=self.primitive_dataset().columns,
                                  seed=seed, max_iters=max_iters,
                                  patience=patience)

        prim, prim_warm = _get_or_train(
            store, self._model_fields("prim", kind, seed=seed,
                                      max_iters=max_iters, patience=patience,
                                      mode="native"),
            train_prim)
        dlt, dlt_warm = self._native_dlt(dlt_kind, seed, dlt_max_iters, store)
        return PlatformModels(prim, dlt, self.fingerprint(), "native",
                              warm=prim_warm and dlt_warm,
                              seconds=time.perf_counter() - t0)

    def calibrate(self, base: Union[PerfModel, PlatformModels],
                  budget: float = 0.01, *, mode: str = "auto", store=None,
                  seed: int = 0, max_iters: int = 2000, patience: int = 150,
                  dlt_kind: str = "lin",
                  dlt_max_iters: int = 1500) -> PlatformModels:
        """Transfer path (§4.4): profile a ``budget`` sample of this platform
        (fraction if < 1, row count if >= 1), then correct ``base`` onto it.

        ``mode``: "factor" multiplies per-primitive geometric-mean ratios
        (cheapest), "finetune" continues training at 10x-lowered LR, "auto"
        picks finetune when the sample is big enough to not overfit, and
        "scratch" ignores ``base`` and trains on the sample alone (the
        paper's transfer-study control).
        """
        t0 = time.perf_counter()
        base_prim = base.prim if isinstance(base, PlatformModels) else base
        # a wide base (e.g. the 49-column simulator model) transfers onto a
        # platform that profiles fewer primitives by slicing its output head
        # to this platform's columns — positions must match the sample matrix
        target_cols = list(self.primitive_dataset().columns)
        if list(base_prim.columns) != target_cols:
            base_prim = base_prim.subset_columns(target_cols)
        tr, va, _ = self.primitive_dataset().split()
        frac = budget if budget < 1 else min(1.0, budget / max(tr.n, 1))
        sample = tr.subsample(frac, seed=seed)
        if mode == "auto":
            mode = "finetune" if sample.n >= 24 else "factor"
        if mode not in ("factor", "finetune", "scratch"):
            raise ValueError(f"unknown calibration mode {mode!r}")

        def train_prim() -> PerfModel:
            if mode == "factor":
                return factor_correct(base_prim, sample.feats, sample.times)
            # fine-tuning continues gradient training, so a factor-corrected
            # base unwraps to the underlying trained network
            from repro.core.perfmodel import FactorCorrectedModel
            ft_base = (base_prim.base if isinstance(base_prim, FactorCorrectedModel)
                       else base_prim)
            return fit_perf_model(ft_base.kind, sample.feats, sample.times,
                                  va.feats, va.times,
                                  columns=self.primitive_dataset().columns,
                                  seed=seed,
                                  base=None if mode == "scratch" else ft_base,
                                  max_iters=max_iters, patience=patience)

        fields = self._model_fields(
            "prim", base_prim.kind, seed=seed, mode=mode, budget=budget,
            sample=sample.fingerprint(),
            base=None if mode == "scratch" else base_prim.fingerprint(),
            max_iters=max_iters, patience=patience)
        prim, prim_warm = _get_or_train(store, fields, train_prim)
        # the DLT model is 2-feature/6-column — native training is cheap, so
        # it is not worth transferring; it is also independent of the
        # calibration sample, hence trained at a fixed seed and memoised
        dlt, dlt_warm = self._native_dlt(dlt_kind, 0, dlt_max_iters, store)
        return PlatformModels(prim, dlt, self.fingerprint(), mode,
                              budget=budget, warm=prim_warm and dlt_warm,
                              seconds=time.perf_counter() - t0)

    def _native_dlt(self, kind: str, seed: int, max_iters: int, store):
        """Native DLT model, memoised per platform instance (one training
        per (kind, seed, iters) no matter how many calibrations ask)."""
        memo = getattr(self, "_dlt_models", None)
        if memo is None:
            memo = self._dlt_models = {}
        key = (kind, seed, max_iters)
        if key in memo:
            return memo[key], True

        def train() -> PerfModel:
            ds = self.dlt_dataset()
            tr, va, _ = ds.split()
            return fit_perf_model(kind, tr.feats, tr.times, va.feats,
                                  va.times, columns=ds.columns, seed=seed,
                                  max_iters=max_iters)

        model, warm = _get_or_train(
            store, self._model_fields("dlt", kind, seed=seed,
                                      max_iters=max_iters, mode="native"),
            train)
        memo[key] = model
        return model, warm


def _get_or_train(store, fields: dict, train_fn):
    """(model, warm) — through the artifact store when one is given."""
    if store is None:
        return train_fn(), False
    return store.get_or_train(fields, train_fn)


# ---------------------------------------------------------------------------
# Concrete platforms
# ---------------------------------------------------------------------------

class SimulatedPlatform(Platform):
    """Analytic platform simulator (intel/amd/arm) behind the Platform
    interface — full-scale datasets, deterministic noise, instant profiling."""

    def __init__(self, name: str, *, noisy: bool = True,
                 max_triplets: Optional[int] = None):
        from repro.profiler.simulators import PLATFORMS
        if name not in PLATFORMS:
            raise KeyError(f"unknown simulated platform {name!r}; "
                           f"have {sorted(PLATFORMS)}")
        self.name = name
        self.noisy = noisy
        self.max_triplets = max_triplets
        self._plat = PLATFORMS[name]
        self._prim_ds: Optional[PerfDataset] = None
        self._dlt_ds: Optional[PerfDataset] = None

    @property
    def columns(self) -> List[str]:
        from repro.primitives.conv import PRIMITIVE_NAMES
        return list(PRIMITIVE_NAMES)

    def profile(self, configs: np.ndarray) -> np.ndarray:
        from repro.profiler.simulators import primitive_time_batch
        return primitive_time_batch(self._plat, np.asarray(configs, np.int64),
                                    noisy=self.noisy)

    def profile_dlt(self, pairs: np.ndarray) -> np.ndarray:
        from repro.profiler.simulators import dlt_time_batch
        return dlt_time_batch(self._plat, np.asarray(pairs, np.int64),
                              noisy=self.noisy)

    def primitive_dataset(self) -> PerfDataset:
        if self._prim_ds is None:
            self._prim_ds = simulate_primitive_dataset(
                self.name, max_triplets=self.max_triplets, noisy=self.noisy)
        return self._prim_ds

    def dlt_dataset(self) -> PerfDataset:
        if self._dlt_ds is None:
            self._dlt_ds = simulate_dlt_dataset(self.name, noisy=self.noisy)
        return self._dlt_ds

    def cost_provider(self) -> SimulatedProvider:
        return SimulatedProvider(self.name, noisy=self.noisy)

    def fingerprint(self) -> str:
        return f"sim/{self.name}/noisy={int(self.noisy)}/mt={self.max_triplets}"


class HostPlatform(Platform):
    """This container's real CPU behind the Platform interface — reduced
    scale, genuinely expensive profiling (the cost the paper eliminates)."""

    name = "host"

    def __init__(self, *, configs: Optional[Sequence] = None,
                 dlt_pairs: Optional[Sequence] = None,
                 primitives: Optional[Sequence[str]] = None,
                 repeats: int = 9):
        from repro.primitives.conv import RUNNABLE
        self.repeats = repeats
        self._primitives = list(primitives) if primitives is not None else list(RUNNABLE)
        self._configs = [tuple(map(int, c)) for c in configs] if configs is not None else None
        self._dlt_pairs = [tuple(map(int, p)) for p in dlt_pairs] if dlt_pairs is not None else None
        self._prim_ds: Optional[PerfDataset] = None
        self._dlt_ds: Optional[PerfDataset] = None

    @property
    def columns(self) -> List[str]:
        return list(self._primitives)

    def profile(self, configs: np.ndarray) -> np.ndarray:
        from repro.profiler import host
        return host.profile_primitive_batch(np.asarray(configs, int),
                                            self._primitives,
                                            repeats=self.repeats)

    def profile_dlt(self, pairs: np.ndarray) -> np.ndarray:
        from repro.profiler import host
        return host.profile_dlt_batch(np.asarray(pairs, int),
                                      repeats=self.repeats)

    def _default_pools(self):
        from repro.profiler import pools
        configs = self._configs if self._configs is not None else \
            pools.config_pool(max_triplets=12)
        dlt_pairs = self._dlt_pairs if self._dlt_pairs is not None else \
            pools.dlt_pool(max_pairs=12)
        return configs, dlt_pairs

    def primitive_dataset(self) -> PerfDataset:
        if self._prim_ds is None:
            from repro.profiler import host
            configs, _ = self._default_pools()
            self._prim_ds = host.profile_primitive_dataset(
                configs, primitives=self._primitives, repeats=self.repeats)
        return self._prim_ds

    def dlt_dataset(self) -> PerfDataset:
        if self._dlt_ds is None:
            from repro.profiler import host
            _, dlt_pairs = self._default_pools()
            self._dlt_ds = host.profile_dlt_dataset(dlt_pairs,
                                                    repeats=self.repeats)
        return self._dlt_ds

    def cost_provider(self) -> MeasuredProvider:
        return MeasuredProvider(repeats=self.repeats, columns=self._primitives)

    def fingerprint(self) -> str:
        import hashlib
        cols = hashlib.sha256("|".join(self._primitives).encode()).hexdigest()[:8]
        return f"host-cpu/r={self.repeats}/cols={cols}"


def get_platform(spec: Union[str, Platform], **kwargs) -> Platform:
    """'intel' / 'amd' / 'arm' -> SimulatedPlatform, 'host' -> HostPlatform;
    a Platform instance passes through (kwargs then disallowed)."""
    if isinstance(spec, Platform):
        if kwargs:
            raise TypeError("cannot re-configure an existing Platform")
        return spec
    if spec == "host":
        return HostPlatform(**kwargs)
    return SimulatedPlatform(spec, **kwargs)
