"""The profile → model → select pipeline as one call (DESIGN.md §7).

``optimise(net, platform)`` is the deployment loop the paper argues for:
arrive on a platform, obtain performance models (warm-loaded, natively
trained, or calibrated from another platform's base model), solve the PBQP,
and hand back an assignment ready for the plan compiler / serving front end.
Everything an example used to hand-wire in ~40 lines is this one function.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

from repro.core.perfmodel import PerfModel
from repro.core.selection import SelectionResult, select
from repro.models import cnn_zoo
from repro.models.cnn_zoo import CNNSpec
from repro.service.artifacts import ArtifactStore
from repro.service.platforms import (Platform, PlatformModels, get_platform)


@dataclasses.dataclass
class OptimisedNetwork:
    """Everything downstream layers need about one optimised network."""

    net: str
    spec: CNNSpec
    platform: Platform
    models: PlatformModels
    assignment: Dict[int, str]        # node idx -> primitive / layout
    columns: List[str]                # columns selection chose from
    predicted_cost_s: float           # model-predicted per-image runtime
    selection: Optional[SelectionResult]   # None when warm-loaded
    warm_models: bool
    warm_selection: bool
    seconds: float                    # total optimise() wall time

    @property
    def warm(self) -> bool:
        return self.warm_models and self.warm_selection

    def predict_per_image(self, bucket: Optional[int] = None,
                          head=None) -> float:
        """Model-predicted per-image runtime, optionally conditioned on the
        pow2 batch ``bucket`` through a fitted
        :class:`~repro.core.perfmodel.BucketScaleHead` (DESIGN.md §12.3).
        Without a head (or bucket) this is ``predicted_cost_s`` — the
        batch-size-invariant prediction the PBQP optimised for."""
        import math
        cost = self.predicted_cost_s
        if head is not None and bucket is not None and math.isfinite(cost):
            cost *= head.scale(bucket)
        return cost

    @classmethod
    def from_assignment(cls, spec: CNNSpec, assignment: Dict[int, str], *,
                        net: Optional[str] = None,
                        platform: Optional[Platform] = None,
                        models: Optional[PlatformModels] = None,
                        predicted_cost_s: float = float("nan"),
                        columns: Optional[List[str]] = None) -> "OptimisedNetwork":
        """Wrap an externally-produced assignment (heuristic baselines,
        hand-written plans) so it can be registered with the server."""
        return cls(net=net or spec.name, spec=spec, platform=platform,
                   models=models, assignment=dict(assignment),
                   columns=list(columns) if columns else [],
                   predicted_cost_s=predicted_cost_s, selection=None,
                   warm_models=False, warm_selection=False, seconds=0.0)


def safe_assignment(spec: CNNSpec) -> Dict[int, str]:
    """The *fallback* plan for serving degradation (DESIGN.md §11.1): a
    reference-only assignment — naive direct summation for every conv (the
    dedicated pointwise GEMM for 1x1 layers, their reference lowering),
    ``chw`` joins, no layout tricks. Deliberately the dumbest runnable choice: when
    an optimised plan is failing, the fallback's job is to share as little
    machinery with it as possible, not to be fast. Executed through the
    interpreted per-image path (``executor.execute(compiled=False)``), it
    also avoids the whole-graph jit/compile pipeline the optimised plan
    runs on."""
    from repro.models.cnn_zoo import ConvLayer
    asg: Dict[int, str] = {}
    for i, node in enumerate(spec.nodes):
        if isinstance(node, ConvLayer):
            asg[i] = "conv-1x1-gemm-ab-ki" if node.f == 1 else "direct-sum2d"
        else:
            asg[i] = "chw"
    return asg


def _spec_fingerprint(spec: CNNSpec) -> str:
    """Content hash of the network topology — selection artifacts must go
    stale when a zoo net's definition changes, not just when models do."""
    import hashlib
    blob = repr((spec.name, [dataclasses.astuple(n) for n in spec.nodes],
                 sorted(spec.edges)))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _executable_columns(model: PerfModel) -> List[str]:
    # is_runnable (not RUNNABLE membership): tile columns like
    # "winograd-2x2-3x3@mm-256x128x128" execute through their base
    # primitive's impl, so they are servable on this host too
    from repro.primitives.conv import is_runnable
    cols = [c for c in model.columns if is_runnable(c)]
    if not cols:
        raise ValueError("model has no runnable columns; cannot build an "
                         "executable assignment")
    return cols


def optimise(net: Union[str, CNNSpec],
             platform: Union[str, Platform],
             *,
             store: Optional[ArtifactStore] = None,
             models: Optional[PlatformModels] = None,
             base: Optional[Union[PerfModel, PlatformModels]] = None,
             budget: float = 0.01,
             mode: str = "auto",
             kind: str = "nn2",
             executable: bool = False,
             seed: int = 0,
             max_iters: Optional[int] = None,
             **platform_kwargs) -> OptimisedNetwork:
    """Optimise ``net`` for ``platform`` end to end.

    * ``models`` given => reuse already-obtained performance models.
    * ``base`` given => transfer path: ``platform.calibrate(base, budget,
      mode)`` (paper §4.4) instead of native pretraining.
    * ``store`` given => models AND the selection warm-start from disk when
      the same (platform, columns, dataset, model) was optimised before.
    * ``executable=True`` restricts selection to runnable primitives so the
      assignment can be compiled and served on this host.
    """
    t0 = time.perf_counter()
    platform = get_platform(platform, **platform_kwargs)
    spec = cnn_zoo.get(net) if isinstance(net, str) else net
    net_name = spec.name

    # max_iters=None defers to each verb's own default (pretrain 4000,
    # calibrate 2000); an explicit value is honoured verbatim
    iters = {} if max_iters is None else {"max_iters": max_iters}
    if models is None:
        if base is not None:
            models = platform.calibrate(base, budget, mode=mode, store=store,
                                        seed=seed, **iters)
        else:
            models = platform.pretrain(kind, store=store, seed=seed, **iters)

    columns = _executable_columns(models.prim) if executable else list(models.prim.columns)
    provider = models.provider(columns=columns if executable else None)

    sel_fields = {"artifact": "selection", "net": net_name,
                  "spec": _spec_fingerprint(spec),
                  "platform": platform.fingerprint(),
                  "backend": platform.name,
                  "models": models.fingerprint(), "columns": columns}
    stored = store.get_json("selections", sel_fields) if store else None
    if stored is not None:
        assignment = {int(k): v for k, v in stored["assignment"].items()}
        return OptimisedNetwork(
            net=net_name, spec=spec, platform=platform, models=models,
            assignment=assignment, columns=columns,
            predicted_cost_s=stored["predicted_cost_s"], selection=None,
            warm_models=models.warm, warm_selection=True,
            seconds=time.perf_counter() - t0)

    sel = select(spec, provider)
    if store is not None:
        store.put_json("selections", sel_fields, {
            "assignment": {str(k): v for k, v in sel.assignment.items()},
            "predicted_cost_s": sel.solver_cost,
            "optimal": sel.optimal,
            "estimate_seconds": sel.estimate_seconds,
            "solver_seconds": sel.solver_seconds,
        })
    return OptimisedNetwork(
        net=net_name, spec=spec, platform=platform, models=models,
        assignment=sel.assignment, columns=columns,
        predicted_cost_s=sel.solver_cost, selection=sel,
        warm_models=models.warm, warm_selection=False,
        seconds=time.perf_counter() - t0)


def reoptimise(opt: OptimisedNetwork,
               *,
               sample=None,
               served=None,
               pooled=None,
               sample_n: int = 16,
               budget: float = 0.05,
               mode: str = "auto",
               store: Optional[ArtifactStore] = None,
               seed: int = 0,
               max_iters: Optional[int] = None,
               executable: Optional[bool] = None) -> OptimisedNetwork:
    """Re-optimise an already-optimised network from fresh measurements —
    the serving drift loop's entry point (DESIGN.md §8.3, §8.5).

    ``sample``: a ``PerfDataset`` of *fresh* target measurements (e.g.
    ``platform.measure_sample()`` taken after drift was detected); when
    given, ``platform.calibrate`` corrects the current models onto it
    without touching any cached profiling pool. Without a sample this is a
    plain re-calibration at ``budget`` against the platform's dataset.

    ``served``: attributed served-traffic observations
    (``profiler.dataset.observations_to_dataset``) — the zero-cost path:
    ``platform.calibrate`` composes the calibration sample from them,
    freshly profiling only the ≤ ``sample_n`` configs the serving buffer
    does not cover. The composition mix lands in
    ``result.models.sample_info``.

    ``pooled``: other hosts' published served-traffic datasets for the
    same platform fingerprint (``ArtifactStore.pooled_drift``) — merged
    with ``served`` so a host recalibrates from fleet evidence without
    profiling anything itself (DESIGN.md §14.3).

    ``executable``: None infers it from ``opt`` (a selection restricted to
    fewer columns than its models was an ``executable=True`` optimise).
    """
    if opt.platform is None or opt.models is None:
        raise ValueError("reoptimise needs an OptimisedNetwork produced by "
                         "optimise() — platform and models must be attached")
    iters = {} if max_iters is None else {"max_iters": max_iters}
    models = opt.platform.calibrate(opt.models, budget, mode=mode,
                                    sample=sample, served=served,
                                    pooled=pooled, sample_n=sample_n,
                                    store=store, seed=seed, **iters)
    if executable is None:
        executable = list(opt.columns) != list(opt.models.prim.columns)
    return optimise(opt.spec, opt.platform, models=models, store=store,
                    executable=executable)
