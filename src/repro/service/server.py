"""Multi-network serving front end — service layer L3 (DESIGN.md §7.3).

``OptimisedServer`` owns a request queue over any number of registered
networks and dispatches through the whole-graph compiled plan cache
(``repro.primitives.plan``). Two policies make it a serving system rather
than a loop:

  * **Perf-model-predicted batching.** Each network's batch cap is derived
    from its model-predicted per-image runtime and a latency budget:
    ``cap = budget / predicted_per_image`` (clamped to [1, max_batch] and
    rounded down to a power of two so the plan cache stays small). Partial
    batches are padded up to the next power-of-two bucket; the pad rows are
    sliced off before results are delivered.
  * **Hot swap.** When a platform recalibrates (new measurements arrive, the
    model is corrected, the PBQP re-solved), ``hot_swap`` atomically replaces
    a network's assignment between dispatches. In-flight queue entries are
    unaffected; the next dispatch compiles (or cache-hits) the new plan.

CLI — the documented CNN serving command (the LM decode demo lives at
``repro.launch.lm_decode``):

    python -m repro.service.server --net edge_cnn --platform arm
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.service.pipeline import OptimisedNetwork, optimise


def _pow2_floor(n: int) -> int:
    return 1 << (max(n, 1).bit_length() - 1)


def _pow2_ceil(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


@dataclasses.dataclass
class Ticket:
    """One queued inference request; ``result`` (or ``error``) is set by the
    pump — a failed dispatch marks its tickets instead of losing them."""
    net: str
    x: np.ndarray                      # (c, im, im)
    result: Optional[np.ndarray] = None
    done: bool = False
    error: Optional[str] = None


@dataclasses.dataclass
class _NetState:
    opt: OptimisedNetwork
    weights: Dict
    batch_cap: int
    generation: int = 0                # bumped by hot_swap
    dispatches: int = 0
    images: int = 0
    padded: int = 0
    busy_s: float = 0.0


class OptimisedServer:
    def __init__(self, *, max_batch: int = 32,
                 latency_budget_ms: float = 50.0):
        self.max_batch = max_batch
        self.latency_budget_ms = latency_budget_ms
        self._nets: Dict[str, _NetState] = {}
        self._queue: Deque[Ticket] = deque()

    # -- registration ------------------------------------------------------
    def _batch_cap(self, predicted_cost_s: float,
                   budget_ms: Optional[float]) -> int:
        budget_s = (budget_ms if budget_ms is not None
                    else self.latency_budget_ms) * 1e-3
        if not np.isfinite(predicted_cost_s) or predicted_cost_s <= 0:
            return _pow2_floor(self.max_batch)
        cap = int(np.clip(budget_s / predicted_cost_s, 1, self.max_batch))
        return _pow2_floor(cap)

    def register(self, opt: OptimisedNetwork, *, weights: Optional[Dict] = None,
                 latency_budget_ms: Optional[float] = None) -> _NetState:
        """Register an optimised network for serving. ``weights`` defaults to
        fresh ``make_weights(spec)`` (serving demo weights)."""
        from repro.primitives.executor import make_weights
        state = _NetState(
            opt=opt,
            weights=weights if weights is not None else make_weights(opt.spec),
            batch_cap=self._batch_cap(opt.predicted_cost_s, latency_budget_ms))
        self._nets[opt.net] = state
        return state

    def hot_swap(self, net: str, opt: OptimisedNetwork, *,
                 latency_budget_ms: Optional[float] = None) -> None:
        """Atomically replace ``net``'s assignment (platform recalibrated).
        Weights are kept; the next dispatch uses the new plan."""
        state = self._nets[net]
        if opt.spec.name != state.opt.spec.name:
            raise ValueError(f"hot_swap topology mismatch: {opt.spec.name!r} "
                             f"vs {state.opt.spec.name!r}")
        state.opt = opt
        state.batch_cap = self._batch_cap(opt.predicted_cost_s,
                                          latency_budget_ms)
        state.generation += 1

    # -- request path ------------------------------------------------------
    def submit(self, net: str, x: np.ndarray) -> Ticket:
        if net not in self._nets:
            raise KeyError(f"network {net!r} not registered")
        x = np.asarray(x, np.float32)
        n0 = self._nets[net].opt.spec.nodes[0]
        if x.shape != (n0.c, n0.im, n0.im):
            raise ValueError(f"{net!r} expects one ({n0.c}, {n0.im}, "
                             f"{n0.im}) image per request, got {x.shape}")
        t = Ticket(net=net, x=x)
        self._queue.append(t)
        return t

    def pump(self) -> int:
        """Drain the queue: group by network, dispatch perf-model-sized
        batches through the compiled plan. Returns the dispatch count."""
        import jax
        import jax.numpy as jnp
        from repro.primitives.plan import compile_plan

        by_net: Dict[str, List[Ticket]] = {}
        while self._queue:
            t = self._queue.popleft()
            by_net.setdefault(t.net, []).append(t)

        dispatches = 0
        for net, tickets in by_net.items():
            state = self._nets[net]
            spec, asg = state.opt.spec, state.opt.assignment
            i = 0
            while i < len(tickets):
                take = min(len(tickets) - i, state.batch_cap)
                group = tickets[i:i + take]
                i += take
                b = _pow2_ceil(take)           # pad to the plan-cache bucket
                xs = np.stack([t.x for t in group])
                if b != take:
                    pad = np.broadcast_to(xs[-1:], (b - take,) + xs.shape[1:])
                    xs = np.concatenate([xs, pad])
                t0 = time.perf_counter()
                try:
                    plan = compile_plan(spec, asg, (b,) + xs.shape[1:])
                    out = plan(jnp.asarray(xs), state.weights)[plan.sinks[-1]]
                    out = np.asarray(jax.block_until_ready(out))
                except Exception as e:   # mark this batch failed, keep going
                    for t in group:
                        t.error, t.done = str(e), True
                    continue
                state.busy_s += time.perf_counter() - t0
                for j, t in enumerate(group):
                    t.result = out[j]
                    t.done = True
                state.dispatches += 1
                state.images += take
                state.padded += b - take
                dispatches += 1
        return dispatches

    def serve(self, net: str, xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Submit a burst of requests and pump until done (sync convenience).
        Raises if any dispatch failed."""
        tickets = [self.submit(net, x) for x in xs]
        self.pump()
        failed = [t.error for t in tickets if t.error]
        if failed:
            raise RuntimeError(f"{len(failed)} request(s) failed: {failed[0]}")
        return [t.result for t in tickets]

    # -- introspection -----------------------------------------------------
    def stats(self, net: str) -> Dict:
        s = self._nets[net]
        return {"batch_cap": s.batch_cap, "generation": s.generation,
                "dispatches": s.dispatches, "images": s.images,
                "padded": s.padded, "busy_s": s.busy_s,
                "images_per_s": (s.images / s.busy_s if s.busy_s else 0.0)}

    @property
    def networks(self) -> List[str]:
        return sorted(self._nets)


# ---------------------------------------------------------------------------
# CLI: optimise-on-arrival, then serve
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Optimise a CNN for a platform and serve it.")
    ap.add_argument("--net", default="edge_cnn")
    ap.add_argument("--platform", default="arm",
                    help="intel | amd | arm (simulated) | host (real CPU)")
    ap.add_argument("--transfer-from", default=None, metavar="PLATFORM",
                    help="calibrate from this platform's pretrained model "
                         "(the paper's §4.4 path) instead of native training")
    ap.add_argument("--calib-budget", type=float, default=0.01,
                    help="calibration sample budget (fraction or row count)")
    ap.add_argument("--store", default="artifacts",
                    help="artifact store root ('' disables warm-start)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--budget-ms", type=float, default=50.0,
                    help="per-dispatch latency budget (sets the batch cap)")
    ap.add_argument("--max-triplets", type=int, default=60,
                    help="simulated profiling pool size")
    ap.add_argument("--max-iters", type=int, default=2000)
    ap.add_argument("--hot-swap", action="store_true",
                    help="recalibrate mid-run and hot-swap the assignment")
    args = ap.parse_args(argv)

    from repro.service.artifacts import ArtifactStore
    from repro.service.platforms import get_platform

    store = ArtifactStore(args.store) if args.store else None
    plat_kw = {} if args.platform == "host" else \
        {"max_triplets": args.max_triplets}
    platform = get_platform(args.platform, **plat_kw)

    base = None
    if args.transfer_from:
        base_plat = get_platform(args.transfer_from,
                                 max_triplets=args.max_triplets)
        base = base_plat.pretrain("nn2", store=store,
                                  max_iters=args.max_iters)
        print(f"[serve] base model: {args.transfer_from} "
              f"({'warm' if base.warm else 'cold'}, {base.seconds:.2f}s)")

    opt = optimise(args.net, platform, store=store, base=base,
                   budget=args.calib_budget, executable=True,
                   max_iters=args.max_iters)
    print(f"[serve] optimised {opt.net} for {platform.fingerprint()}: "
          f"{'warm' if opt.warm else 'cold'} in {opt.seconds:.2f}s, "
          f"predicted {opt.predicted_cost_s*1e3:.3f} ms/img")

    server = OptimisedServer(latency_budget_ms=args.budget_ms)
    server.register(opt)
    print(f"[serve] batch cap {server.stats(opt.net)['batch_cap']} "
          f"(budget {args.budget_ms:.0f} ms)")

    n0 = opt.spec.nodes[0]
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((args.requests, n0.c, n0.im, n0.im)).astype(np.float32)
    server.serve(opt.net, xs[: min(4, args.requests)])   # warm the plan
    t0 = time.perf_counter()
    server.serve(opt.net, xs)
    dt = time.perf_counter() - t0
    s = server.stats(opt.net)
    print(f"[serve] {args.requests} requests in {dt*1e3:.0f} ms "
          f"({args.requests/dt:.1f} img/s, {s['dispatches']} dispatches, "
          f"{s['padded']} padded)")

    if args.hot_swap:
        recal = optimise(args.net, platform, store=store, base=opt.models,
                         budget=max(args.calib_budget * 5, 0.05),
                         mode="finetune", executable=True,
                         max_iters=args.max_iters)
        server.hot_swap(opt.net, recal)
        server.serve(opt.net, xs[:8])
        print(f"[serve] hot-swapped to recalibrated assignment "
              f"(generation {server.stats(opt.net)['generation']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
