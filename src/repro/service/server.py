"""Back-compat shim — the serving front end grew into the concurrent serving
core at ``repro.service.serving`` (DESIGN.md §8: per-network queues with
timed batch windows, worker-pool dispatch, drift-triggered recalibration).
This module keeps the documented entry points stable:

    python -m repro.service.server --net edge_cnn --platform arm
    from repro.service.server import OptimisedServer, Ticket
"""
from repro.service.serving.drift import DriftMonitor, DriftStats
from repro.service.serving.queues import NetQueue, Ticket
from repro.service.serving.server import (OptimisedServer, main,
                                          make_recalibrator)
from repro.service.serving.workers import WorkerPool

__all__ = [
    "DriftMonitor", "DriftStats", "NetQueue", "OptimisedServer", "Ticket",
    "WorkerPool", "main", "make_recalibrator",
]

if __name__ == "__main__":
    raise SystemExit(main())
